"""Autotune subsystem: measurement store, harvesting, stratified training,
calibration, and the (platform, backend) selector resolution order."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model as cm_mod
from repro.core.api import TuckerConfig, plan
from repro.core.cost_model import CostModel
from repro.core import selector as sel_mod
from repro.core.selector import Selector, default_selector
from repro.tune import (
    Measurement,
    RecordStore,
    fit_cost_model,
    labeled_examples,
    recording,
    train_stratified,
)
from repro.tune.records import COLLECT, HARVEST


def M(i, r, j, method, seconds, *, backend="matfree", platform="cpu",
      device="box", source=COLLECT, dtype="float32", order=3):
    return Measurement(platform=platform, backend=backend, device=device,
                       i_n=i, r_n=r, j_n=j, method=method, seconds=seconds,
                       dtype=dtype, order=order, source=source)


@pytest.fixture
def model_env(tmp_path, monkeypatch):
    """Isolated model dir + empty selector cache."""
    monkeypatch.setattr(sel_mod, "_DEFAULT_MODEL_DIR", tmp_path / "models")
    monkeypatch.setattr(sel_mod, "_DEFAULT_BY_PLATFORM", {})
    return tmp_path


def synthetic_records(*, backend="matfree", platform="cpu", als_faster_above=64,
                      n=40, seed=0):
    """Labeled-by-construction records: als wins iff i_n > threshold.
    Seconds are flop-proportional + overhead so calibration fits cleanly."""
    rng = np.random.default_rng(seed)
    out = []
    for i in np.unique(np.geomspace(4, 256, n).astype(int)):
        r = max(1, int(i) // 4)
        j = int(rng.integers(64, 1024))
        slow, fast = 2e-4, 1e-4
        te = slow if i > als_faster_above else fast
        ta = fast if i > als_faster_above else slow
        te += 1e-10 * cm_mod.eig_flops(i, r, j)
        ta += 1e-10 * cm_mod.als_flops(i, r, j)
        out.append(M(int(i), r, j, "eig", te, backend=backend,
                     platform=platform))
        out.append(M(int(i), r, j, "als", ta, backend=backend,
                     platform=platform))
    return out


class TestRecordStore:
    def test_roundtrip(self, tmp_path):
        store = RecordStore(tmp_path / "s.jsonl")
        ms = [M(10, 2, 50, "eig", 0.01), M(10, 2, 50, "als", 0.02)]
        assert store.append(ms) == 2
        got = store.load()
        assert got == ms          # frozen dataclass equality
        assert got[0].key() != got[1].key()
        assert got[0].problem_key() == got[1].problem_key()

    def test_dedup_keeps_fastest(self, tmp_path):
        store = RecordStore(tmp_path / "s.jsonl")
        store.append([M(10, 2, 50, "eig", 0.05),
                      M(10, 2, 50, "eig", 0.01),
                      M(10, 2, 50, "eig", 0.03)])
        best = store.dedup()
        assert len(best) == 1
        assert next(iter(best.values())).seconds == 0.01

    def test_digest_stable_under_order_and_duplicates(self, tmp_path):
        a = RecordStore(tmp_path / "a.jsonl")
        b = RecordStore(tmp_path / "b.jsonl")
        m1, m2 = M(10, 2, 50, "eig", 0.01), M(20, 4, 30, "als", 0.02)
        a.append([m1, m2])
        b.append([m2, m1, m1])    # reordered + an exact duplicate
        assert a.digest() == b.digest()
        b.append([M(9, 2, 9, "eig", 0.5)])
        assert a.digest() != b.digest()

    def test_filter_and_merge(self, tmp_path):
        a = RecordStore(tmp_path / "a.jsonl")
        b = RecordStore(tmp_path / "b.jsonl")
        a.append([M(10, 2, 50, "eig", 0.01, backend="matfree"),
                  M(10, 2, 50, "eig", 0.02, backend="explicit")])
        b.append([M(10, 2, 50, "eig", 0.005, backend="matfree"),   # faster
                  M(99, 9, 99, "als", 0.5, backend="matfree")])    # new
        assert len(a.filter(backend="explicit")) == 1
        assert a.merge_from(b) == 2
        assert a.dedup()[M(10, 2, 50, "eig", 0).key()].seconds == 0.005

    def test_partial_tail_line_skipped(self, tmp_path):
        store = RecordStore(tmp_path / "s.jsonl")
        store.append([M(10, 2, 50, "eig", 0.01)])
        with store.path.open("a") as f:
            f.write('{"platform": "cpu", "i_n": 5')   # interrupted append
        assert len(store.load()) == 1

    def test_compact(self, tmp_path):
        store = RecordStore(tmp_path / "s.jsonl")
        store.append([M(10, 2, 50, "eig", 0.05), M(10, 2, 50, "eig", 0.01)])
        digest = store.digest()
        assert store.compact() == 1
        assert len(store) == 1 and store.digest() == digest


class TestLabeling:
    def test_pairing_requires_both_methods(self):
        ms = [M(10, 2, 50, "eig", 0.02), M(10, 2, 50, "als", 0.01),
              M(77, 7, 70, "eig", 0.5)]         # one-sided → unlabeled
        feats, labels, times = labeled_examples(ms)
        assert len(labels) == 1
        assert labels[0] == 1                   # als was faster
        assert tuple(times[0]) == (0.02, 0.01)
        assert feats[0][0] == 10

    def test_best_of_duplicates_labels(self):
        ms = [M(10, 2, 50, "eig", 0.02), M(10, 2, 50, "eig", 0.005),
              M(10, 2, 50, "als", 0.01)]
        _, labels, times = labeled_examples(ms)
        assert labels[0] == 0                   # best eig (0.005) beats als
        assert tuple(times[0]) == (0.005, 0.01)


class TestTrainingAndResolution:
    def test_stratified_training_and_resolution_order(self, model_env):
        store = RecordStore(model_env / "s.jsonl")
        # two backends with INVERTED crossovers — one pooled tree can't
        # serve both, which is exactly why resolution is backend-first
        store.append(synthetic_records(backend="m1", als_faster_above=64))
        store.append(synthetic_records(backend="m2", als_faster_above=-1,
                                       seed=1))   # m2: als always wins
        written = train_stratified(store, platform="cpu")
        names = {p.split("/")[-1] for p in written}
        assert names == {"selector_cpu_m1.json", "selector_cpu_m2.json",
                         "selector_cpu.json"}
        for info in written.values():
            assert info["store_digest"] == store.digest()
            assert info["n_examples"] >= 12

        sel_mod._DEFAULT_BY_PLATFORM.clear()
        s1 = default_selector("cpu", "m1")
        s2 = default_selector("cpu", "m2")
        assert s1.backend == "m1" and s2.backend == "m2"
        assert s1(i_n=16, r_n=4, j_n=256) == "eig"   # below m1 crossover
        assert s2(i_n=16, r_n=4, j_n=256) == "als"   # m2: als everywhere
        # unknown backend → platform-pooled tree, not the cost model
        pooled = default_selector("cpu", "no_such_backend")
        assert pooled.tree is not None and pooled.backend is None
        # caching is per (platform, backend)
        assert default_selector("cpu", "m1") is s1
        assert s1 is not s2

    def test_resolution_falls_back_to_cost_model(self, model_env):
        sel = default_selector("cpu", "matfree")    # no files at all
        assert sel.tree is None
        assert sel(i_n=30648, r_n=10, j_n=2256) == "als"   # Eq.4/5 fallback

    def test_trained_model_prices_plans(self, model_env):
        """A trained+calibrated model makes plan schedules carry
        predicted_s, and traces expose predicted-vs-actual."""
        store = RecordStore(model_env / "s.jsonl")
        store.append(synthetic_records())
        train_stratified(store, platform="cpu")
        sel_mod._DEFAULT_BY_PLATFORM.clear()
        assert default_selector("cpu", "matfree").cost_model.calibrated
        p = plan((24, 16, 12), jnp.float32, TuckerConfig(ranks=(4, 4, 4)))
        assert all(s.predicted_s > 0 for s in p.schedule)
        res = p.execute(jnp.zeros((24, 16, 12), jnp.float32))
        assert all(t.predicted_s > 0 for t in res.trace)

    def test_selector_save_without_tree_raises(self, tmp_path):
        with pytest.raises(ValueError, match="no trained tree"):
            Selector(platform="cpu").save(tmp_path / "x.json")

    def test_train_and_save_platform_agreement(self, model_env, monkeypatch):
        """The passed platform labels the model, names the file, and keys
        the cache — regardless of the box it trained on."""
        import importlib

        # NB: the attribute ``repro.tune.collect`` is the collect FUNCTION
        # (re-exported in __init__), shadowing the submodule — same pattern
        # as repro.core.plan; resolve the module via import machinery
        collect_mod = importlib.import_module("repro.tune.collect")
        from repro.tune import train as train_mod

        def fake_collect(**kw):
            rng = np.random.default_rng(0)
            feats = np.stack([sel_mod.extract_features(i, r, j)
                              for i, r, j in rng.integers(2, 500, (60, 3))])
            labels = (feats[:, 0] > 100).astype(int)
            return feats, labels, np.zeros((60, 2))

        monkeypatch.setattr(collect_mod, "collect_samples", fake_collect)
        info = train_mod.train_and_save(platform="gpu")
        assert info["n_train"] > 0
        path = sel_mod.model_path("gpu")
        assert path.exists()
        loaded = Selector.load(path)
        assert loaded.platform == "gpu"
        assert sel_mod._DEFAULT_BY_PLATFORM[("gpu", None)].platform == "gpu"

    def test_v1_model_file_still_loads(self, tmp_path):
        from repro.core.dtree import DecisionTree
        t = DecisionTree(max_depth=2).fit(
            np.array([[1.0], [2.0], [3.0], [4.0]] * 5),
            np.array([0, 0, 1, 1] * 5))
        (tmp_path / "old.json").write_text(json.dumps(
            {"platform": "cpu", "tree": t.to_dict(),
             "trained_range": [[1, 1, 1], [9, 9, 9]]}))
        s = Selector.load(tmp_path / "old.json")
        assert s.backend is None and s.tree is not None
        assert s.cost_model.source == "textbook"


class TestHarvest:
    def test_recording_harvests_executed_plans(self, tmp_path, model_env):
        store = RecordStore(tmp_path / "h.jsonl")
        x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 12, 10)),
                        jnp.float32)
        with recording(store) as sink:
            for methods in ("eig", "als"):
                p = plan(x.shape, x.dtype,
                         TuckerConfig(ranks=(4, 4, 4), methods=methods))
                res = p.execute(x)          # recording context forces timing
                assert all(t.seconds > 0 for t in res.trace)
            assert len(sink.measurements) == 6
        got = store.load()
        assert len(got) == 6
        assert all(m.source == HARVEST and m.seconds > 0 for m in got)
        assert all(m.platform == jax.default_backend() for m in got)
        # eig+als ran on identical problems → records pair into labeled
        # training examples: the full online flywheel roundtrip
        feats, labels, _ = labeled_examples(got)
        assert len(labels) == 3

    def test_execute_record_matches_unrecorded(self, model_env):
        x = jnp.asarray(np.random.default_rng(1).standard_normal((12, 10, 8)),
                        jnp.float32)
        cfg = TuckerConfig(ranks=(3, 3, 3), methods="eig")
        p = plan(x.shape, x.dtype, cfg)
        plain = p.execute(x)
        rec = p.execute(x, record=True)
        assert all(t.seconds > 0 for t in rec.trace)
        assert all(t.seconds == 0 for t in plain.trace)
        np.testing.assert_allclose(np.abs(np.asarray(rec.tucker.core)),
                                   np.abs(np.asarray(plain.tucker.core)),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("variant", ["thosvd", "hooi"])
    def test_record_covers_all_variants(self, variant, model_env):
        x = jnp.asarray(np.random.default_rng(2).standard_normal((10, 9, 8)),
                        jnp.float32)
        cfg = TuckerConfig(ranks=(3, 3, 3), methods="eig", variant=variant,
                           hooi_iters=1)
        res = plan(x.shape, x.dtype, cfg).execute(x, record=True)
        assert len(res.trace) == len(plan(x.shape, x.dtype, cfg).schedule)
        assert all(t.seconds > 0 for t in res.trace)
        assert float(res.tucker.rel_error(x)) < 1.0


class TestCalibration:
    def test_fit_recovers_scales_and_constants(self):
        """Synthetic seconds generated FROM the model → fit recovers it."""
        rng = np.random.default_rng(3)
        truth = CostModel(c_eig=40.0, c_inv=2.0, c_qr=1.0,
                          eig_scale=2e-10, als_scale=1e-10,
                          eig_overhead_s=3e-4, als_overhead_s=8e-4,
                          source="calibrated")
        ms = []
        for _ in range(40):
            i = int(rng.integers(8, 300))
            r = max(1, i // 4)
            j = int(rng.integers(64, 4096))
            ms.append(M(i, r, j, "eig",
                        truth.predict_seconds("eig", i, r, j)))
            ms.append(M(i, r, j, "als",
                        truth.predict_seconds("als", i, r, j)))
        cm = fit_cost_model(ms)
        assert cm is not None and cm.calibrated
        assert cm.c_eig == pytest.approx(40.0, rel=0.05)
        assert cm.eig_scale == pytest.approx(2e-10, rel=0.05)
        assert cm.als_overhead_s == pytest.approx(8e-4, rel=0.1)

    def test_calibration_flips_predicted_best(self):
        """Measurements where EIG FLOPs are pathologically slow flip the
        analytic choice at a query the textbook model calls for EIG."""
        q = (6, 5, 30648 * 376)                     # textbook: eig wins big
        assert cm_mod.predicted_best(*q) == "eig"
        ms = []
        rng = np.random.default_rng(4)
        for _ in range(20):
            i = int(rng.integers(4, 64))
            r = max(1, i // 4)
            j = int(rng.integers(1024, 1 << 16))
            # eig FLOPs cost 1000× als FLOPs on this "hardware"
            ms.append(M(i, r, j, "eig", 1e-7 * cm_mod.eig_flops(i, r, j)))
            ms.append(M(i, r, j, "als", 1e-10 * cm_mod.als_flops(i, r, j)))
        cm = fit_cost_model(ms)
        assert cm is not None and cm.calibrated
        assert cm.predicted_best(*q) == "als"

    def test_fit_returns_none_when_starved(self):
        assert fit_cost_model([M(8, 2, 64, "eig", 0.1)]) is None

    def test_out_of_range_guardrail_uses_calibrated_model(self):
        """In-range queries hit the tree; out-of-range queries defer to the
        selector's EMBEDDED calibrated cost model, not the textbook one."""
        from repro.core.dtree import DecisionTree
        feats = np.stack([sel_mod.extract_features(i, 4, 64)
                          for i in range(8, 64)])
        tree = DecisionTree(max_depth=1).fit(feats,
                                             np.zeros(len(feats), int))
        calibrated = CostModel(eig_scale=1e-3, als_scale=1e-12,
                               source="calibrated")   # als wins everywhere
        sel = Selector(tree=tree, platform="cpu", backend="matfree",
                       trained_range=((8, 4, 64), (63, 4, 64)),
                       cost_model=calibrated)
        assert sel(i_n=32, r_n=4, j_n=64) == "eig"          # tree, in range
        q = dict(i_n=6, r_n=5, j_n=30648 * 376)             # out of range
        assert Selector(tree=tree, platform="cpu",
                        trained_range=sel.trained_range)(**q) == "eig"
        assert sel(**q) == "als"                            # calibrated

    def test_calibrate_store_writes_per_backend_files(self, model_env):
        store = RecordStore(model_env / "s.jsonl")
        store.append(synthetic_records(backend="matfree"))
        store.append(synthetic_records(backend="explicit", seed=5))
        from repro.tune import calibrate_store
        written = calibrate_store(store, platform="cpu")
        names = {p.split("/")[-1] for p in written}
        assert names == {"cost_cpu_matfree.json", "cost_cpu_explicit.json"}
        sel_mod._DEFAULT_BY_PLATFORM.clear()
        # no tree model on disk → fallback selector picks up the calibration
        sel = default_selector("cpu", "matfree")
        assert sel.tree is None and sel.cost_model.calibrated


class TestCLI:
    def test_collect_train_report_roundtrip(self, tmp_path, model_env,
                                            capsys):
        from repro.tune.cli import main
        store = str(tmp_path / "cli.jsonl")
        assert main(["collect", "--store", store, "--n-tensors", "4",
                     "--min-dim", "6", "--max-dim", "20", "--reps", "1",
                     "--quiet"]) == 0
        assert main(["harvest", "--store", store, "--smoke"]) == 0
        mdir = str(tmp_path / "m")
        assert main(["train", "--store", store, "--platform", "cpu",
                     "--model-dir", mdir, "--min-examples", "6"]) == 0
        sel = Selector.load(next(iter(
            Path(mdir).glob("selector_cpu.json"))))
        assert sel.tree is not None
        assert sel.meta["store_digest"] == RecordStore(store).digest()
        assert main(["report", "--store", store, "--model-dir", mdir]) == 0
        out = capsys.readouterr().out
        assert "selector_cpu.json" in out
