"""Decision tree (pure-numpy CART) + adaptive selector (paper Sec. IV)."""

import json

import numpy as np
import pytest

from repro.core import cost_model
from repro.core.dtree import DecisionTree, grid_search_cv
from repro.core.selector import (FEATURE_NAMES, Selector, extract_features,
                                 train_selector)


class TestDTree:
    def test_learns_axis_aligned_rule(self):
        rng = np.random.default_rng(0)
        x = rng.uniform(0, 1, (400, 3))
        y = (x[:, 1] > 0.6).astype(int)
        t = DecisionTree(max_depth=2).fit(x, y)
        assert t.score(x, y) > 0.98
        assert t.nodes[0].feature == 1
        assert abs(t.nodes[0].threshold - 0.6) < 0.05

    def test_learns_conjunction(self):
        rng = np.random.default_rng(1)
        x = rng.uniform(0, 1, (600, 2))
        y = ((x[:, 0] > 0.5) & (x[:, 1] > 0.5)).astype(int)
        t = DecisionTree(max_depth=3).fit(x, y)
        assert t.score(x, y) > 0.95

    def test_class_weight_balanced(self):
        rng = np.random.default_rng(2)
        x = rng.uniform(0, 1, (500, 1))
        y = (x[:, 0] > 0.95).astype(int)        # 5% positives
        tb = DecisionTree(max_depth=3, class_weight="balanced").fit(x, y)
        pos = x[y == 1]
        assert tb.predict(pos).mean() > 0.9     # recalls the minority class

    def test_depth_limit(self):
        rng = np.random.default_rng(3)
        x = rng.uniform(0, 1, (200, 2))
        y = rng.integers(0, 2, 200)
        t = DecisionTree(max_depth=1).fit(x, y)
        assert t.n_nodes <= 3

    def test_serialization_roundtrip(self):
        rng = np.random.default_rng(4)
        x = rng.uniform(0, 1, (300, 4))
        y = (x[:, 2] > 0.3).astype(int)
        t = DecisionTree(max_depth=4).fit(x, y)
        t2 = DecisionTree.from_dict(json.loads(json.dumps(t.to_dict())))
        np.testing.assert_array_equal(t.predict(x), t2.predict(x))

    def test_grid_search(self):
        rng = np.random.default_rng(5)
        x = rng.uniform(0, 1, (300, 2))
        y = (x[:, 0] > 0.5).astype(int)
        tree, info = grid_search_cv(x, y, max_depths=range(1, 4), n_folds=2)
        assert info["cv_accuracy"] > 0.9
        assert tree.score(x, y) > 0.95


class TestSelector:
    def test_features_match_table1(self):
        f = extract_features(100, 10, 5000)
        assert len(f) == len(FEATURE_NAMES) == 10
        assert f[0] == 100 and f[1] == 10 and f[2] == 5000
        assert f[3] == 100 ** 2 and f[6] == 100 / 100  # R²/I = 1
        assert np.all(np.isfinite(f))

    def test_cost_model_fallback(self):
        sel = Selector()                         # no tree
        # huge I_n: eigh(I²) explodes → ALS must win (paper's Air tensor)
        assert sel(i_n=30648, r_n=10, j_n=376 * 6) == "als"
        # tiny I_n, huge J_n: Gram is one cheap pass → EIG wins
        assert sel(i_n=6, r_n=5, j_n=30648 * 376) == "eig"

    def test_cost_model_consistency(self):
        assert cost_model.predicted_best(30648, 10, 2256) == "als"
        assert cost_model.eig_flops(100, 10, 1000) > 0
        assert cost_model.als_flops(100, 10, 1000) > 0

    def test_train_selector_pipeline(self):
        rng = np.random.default_rng(0)
        feats = np.stack([extract_features(i, r, j) for i, r, j in
                          rng.integers(2, 500, (200, 3))])
        labels = (feats[:, 0] > 100).astype(int)   # synthetic ground truth
        sel, info = train_selector(feats, labels)
        assert info["test_accuracy"] > 0.9
        assert sel(i_n=400, r_n=10, j_n=50) == "als"
        assert sel(i_n=10, r_n=4, j_n=50) == "eig"

    def test_default_selector_platform_keyed(self, tmp_path, monkeypatch):
        """CPU and GPU model files resolve independently in one process."""
        from repro.core import selector as sel_mod
        monkeypatch.setattr(sel_mod, "_DEFAULT_MODEL_DIR", tmp_path)
        monkeypatch.setattr(sel_mod, "_DEFAULT_BY_PLATFORM", {})
        rng = np.random.default_rng(2)
        feats = np.stack([extract_features(i, r, j) for i, r, j in
                          rng.integers(2, 500, (100, 3))])
        trained, _ = train_selector(feats, (feats[:, 0] > 100).astype(int))
        trained.save(tmp_path / "selector_gpu.json")

        gpu = sel_mod.default_selector("gpu")
        cpu = sel_mod.default_selector("cpu")     # no file → cost-model fallback
        assert gpu.tree is not None
        assert cpu.tree is None
        # cached per platform, not one global
        assert sel_mod.default_selector("gpu") is gpu
        assert sel_mod.default_selector("cpu") is cpu
        assert gpu is not cpu

    def test_save_load(self, tmp_path):
        rng = np.random.default_rng(1)
        feats = np.stack([extract_features(i, r, j) for i, r, j in
                          rng.integers(2, 500, (100, 3))])
        labels = (feats[:, 1] > 50).astype(int)
        sel, _ = train_selector(feats, labels)
        p = tmp_path / "sel.json"
        sel.save(p)
        sel2 = Selector.load(p)
        for i, r, j in rng.integers(2, 500, (20, 3)):
            assert sel(i_n=i, r_n=r, j_n=j) == sel2(i_n=i, r_n=r, j_n=j)
