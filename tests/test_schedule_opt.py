"""Schedule search (subset DP), memory caps, and donated sweeps.

Covers the plan-time optimizer end to end: DP-vs-brute-force exactness over
all N! orders (with per-step solver choice), cap feasibility agreement and
the binding-step error, plan JSON roundtrips of the new config fields,
donated-sweep bitwise parity + the measured live-array high-water win, and
the runtime cap smoke used by the tier-2 CI job.
"""

import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DEFAULT_COST_MODEL,
    MemoryCapError,
    TuckerConfig,
    TuckerPlan,
    optimize_schedule,
    plan,
    resolve_schedule,
    sthosvd,
)
from repro.core.api import donation_supported
from repro.core.plan import _step_peak_bytes, resolve_mode_order
from repro.core.schedule_opt import SEARCH_METHODS, step_cost


def lowrank(dims, ranks, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    from repro.core import tensor_ops as T
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    rms = float(jnp.sqrt(jnp.mean(x ** 2)))
    return x + noise * rms * jnp.asarray(rng.standard_normal(dims), jnp.float32)


def brute_force(shape, ranks, *, methods=None, als_iters=5, itemsize=4,
                cap=None, cm=DEFAULT_COST_MODEL):
    """Reference: enumerate every order x every per-step solver assignment."""
    n = len(shape)
    best = None
    for order in itertools.permutations(range(n)):
        cands = [([methods[m]] if methods is not None
                  else list(SEARCH_METHODS)) for m in order]
        for meths in itertools.product(*cands):
            cur, cost, ok = list(shape), 0.0, True
            for m, meth in zip(order, meths):
                i_n, r_n = cur[m], ranks[m]
                j_n = math.prod(cur) // i_n
                if cap is not None and \
                        _step_peak_bytes(meth, i_n, r_n, j_n, itemsize) > cap:
                    ok = False
                    break
                cost += step_cost(cm, meth, i_n, r_n, j_n, als_iters)
                cur[m] = r_n
            if ok and (best is None or cost < best[0]):
                best = (cost, order, meths)
    return best


# ---------------------------------------------------------------------------
# DP exactness vs brute force
# ---------------------------------------------------------------------------

class TestDPOptimality:
    @pytest.mark.parametrize("shape,ranks", [
        ((30, 8, 22), (3, 6, 4)),
        ((16, 16, 16), (4, 4, 4)),
        ((40, 6, 12, 9), (5, 4, 3, 2)),
    ])
    def test_equal_totals_auto_methods(self, shape, ranks):
        search = optimize_schedule(shape, ranks)
        ref = brute_force(shape, ranks)
        assert math.isclose(search.total_cost, ref[0], rel_tol=1e-9)

    def test_equal_totals_pinned_methods(self):
        shape, ranks = (24, 10, 18), (4, 5, 3)
        search = optimize_schedule(shape, ranks, methods=["eig"] * 3)
        ref = brute_force(shape, ranks, methods=["eig"] * 3)
        assert math.isclose(search.total_cost, ref[0], rel_tol=1e-9)
        assert search.methods == ("eig",) * 3

    def test_beats_or_matches_every_fixed_order(self):
        shape, ranks = (40, 6, 12, 9), (5, 4, 3, 2)
        search = optimize_schedule(shape, ranks, methods=["eig"] * 4)
        for order in itertools.permutations(range(4)):
            cur, cost = list(shape), 0.0
            for m in order:
                j_n = math.prod(cur) // cur[m]
                cost += step_cost(DEFAULT_COST_MODEL, "eig", shape[m],
                                  ranks[m], j_n, 5)
                cur[m] = ranks[m]
            assert search.total_cost <= cost + 1e-9 * cost

    @pytest.mark.parametrize("frac", [0.35, 0.6, 0.9])
    def test_cap_feasibility_agreement(self, frac):
        shape, ranks = (30, 8, 22), (3, 6, 4)
        # cap as a fraction of the worst single-step peak seen uncapped
        worst = max(_step_peak_bytes(m, shape[i], ranks[i],
                                     math.prod(shape) // shape[i], 4)
                    for i in range(3) for m in SEARCH_METHODS)
        cap = int(worst * frac)
        ref = brute_force(shape, ranks, cap=cap)
        if ref is None:
            with pytest.raises(MemoryCapError):
                optimize_schedule(shape, ranks, memory_cap_bytes=cap)
        else:
            search = optimize_schedule(shape, ranks, memory_cap_bytes=cap)
            assert math.isclose(search.total_cost, ref[0], rel_tol=1e-9)

    def test_cap_forces_smaller_solver(self):
        # uncapped, ALS wins mode 0 on FLOPs — but its R-tensor scratch
        # (2·R·J in fp32) outweighs EIG's I² Gram here, so a cap just below
        # ALS's peak forces the slower-but-smaller EIG on that step
        shape, ranks = (80, 64, 64), (4, 32, 32)
        free = resolve_schedule(shape, ranks, mode_order="opt",
                                cost_model=DEFAULT_COST_MODEL)
        worst = max(free, key=lambda s: s.peak_bytes)
        assert worst.method == "als"
        capped = resolve_schedule(shape, ranks, mode_order="opt",
                                  cost_model=DEFAULT_COST_MODEL,
                                  memory_cap_bytes=worst.peak_bytes - 1)
        flip = next(s for s in capped if s.mode == worst.mode)
        assert flip.method == "eig"
        assert flip.peak_bytes < worst.peak_bytes
        assert sum(s.flops for s in capped) > sum(s.flops for s in free)
        assert all(s.peak_bytes < worst.peak_bytes for s in capped)


# ---------------------------------------------------------------------------
# Infeasible caps fail at plan time, naming the binding step
# ---------------------------------------------------------------------------

class TestCapErrors:
    def test_opt_infeasible_names_binding_step(self):
        with pytest.raises(MemoryCapError) as e:
            optimize_schedule((96, 16, 64), (4, 12, 8),
                              memory_cap_bytes=1000)
        msg = str(e.value)
        assert "mode" in msg and "1,000" in msg and "bytes" in msg

    def test_fixed_order_schedule_checked_too(self):
        with pytest.raises(MemoryCapError) as e:
            resolve_schedule((96, 16, 64), (4, 12, 8), methods="eig",
                             memory_cap_bytes=1000)
        assert "step 0" in str(e.value) and "mode_order='opt'" in str(e.value)

    def test_plan_level_cap_error(self):
        cfg = TuckerConfig(ranks=(4, 12, 8), mode_order="opt",
                           memory_cap_bytes=1000)
        with pytest.raises(MemoryCapError):
            plan((96, 16, 64), jnp.float32, cfg)

    def test_sthosvd_entry_point_cap(self):
        x = lowrank((24, 20, 16), (3, 3, 3))
        with pytest.raises(MemoryCapError):
            sthosvd(x, (3, 3, 3), methods="eig", memory_cap_bytes=1000)

    def test_feasible_cap_respected_in_plan(self):
        # natural order's bottleneck (mode 0 barely compresses, so mode 1's
        # solve still sees a huge J) is avoidable by reordering: a cap below
        # it is infeasible for the natural order but fine for the DP
        shape, ranks = (16, 96, 64), (12, 4, 8)
        free = plan(shape, jnp.float32, TuckerConfig(ranks=ranks))
        cap = int(max(s.peak_bytes for s in free.schedule) * 0.8)
        p = plan(shape, jnp.float32,
                 TuckerConfig(ranks=ranks, mode_order="opt",
                              memory_cap_bytes=cap))
        assert all(s.peak_bytes <= cap for s in p.schedule)
        # and the plan executes correctly under the cap
        x = lowrank(shape, ranks)
        assert float(p.execute(x).tucker.rel_error(x)) < 0.05

    def test_config_validation(self):
        with pytest.raises(ValueError):
            TuckerConfig(ranks=(2, 2, 2), mode_order="fastest")
        with pytest.raises(ValueError):
            TuckerConfig(ranks=(2, 2, 2), memory_cap_bytes=0)
        with pytest.raises(ValueError):
            resolve_mode_order((4, 4, 4), (2, 2, 2), "opt")


# ---------------------------------------------------------------------------
# Plan integration: correctness, JSON roundtrip, modeled-cost ordering
# ---------------------------------------------------------------------------

class TestOptPlans:
    def test_opt_plan_executes_correctly(self):
        shape, ranks = (40, 12, 30), (4, 6, 5)
        x = lowrank(shape, ranks)
        p = plan(shape, jnp.float32,
                 TuckerConfig(ranks=ranks, mode_order="opt"))
        res = p.execute(x)
        assert float(res.tucker.rel_error(x)) < 0.05
        # the schedule visits every mode exactly once
        assert sorted(s.mode for s in p.schedule) == [0, 1, 2]

    def test_opt_never_worse_than_fixed_orders_modeled(self):
        shape, ranks = (96, 16, 64), (4, 12, 8)
        opt = resolve_schedule(shape, ranks, methods="eig",
                               mode_order="opt",
                               cost_model=DEFAULT_COST_MODEL)
        for order in ([0, 1, 2], [2, 0, 1], "shrink"):
            ref = resolve_schedule(shape, ranks, methods="eig",
                                   mode_order=order,
                                   cost_model=DEFAULT_COST_MODEL)
            assert sum(s.flops for s in opt) <= sum(s.flops for s in ref) * \
                (1 + 1e-9)

    def test_plan_json_roundtrip(self, tmp_path):
        cfg = TuckerConfig(ranks=(4, 6, 5), mode_order="opt",
                           memory_cap_bytes=10_000_000, donate_input=True)
        p = plan((40, 12, 30), jnp.float32, cfg)
        path = tmp_path / "plan.json"
        p.save(path)
        q = TuckerPlan.load(path)
        assert q.config.mode_order == "opt"
        assert q.config.memory_cap_bytes == 10_000_000
        assert q.config.donate_input is True
        assert [s.to_dict() for s in q.schedule] == \
            [s.to_dict() for s in p.schedule]
        # donate_input=True means execute CONSUMES its array — use a copy
        # per call (the override donate=False path is covered elsewhere)
        xn = np.asarray(lowrank((40, 12, 30), (4, 6, 5)))
        np.testing.assert_array_equal(
            np.asarray(p.execute(jnp.asarray(xn)).tucker.core),
            np.asarray(q.execute(jnp.asarray(xn)).tucker.core))

    def test_total_predicted_s_surfaced(self):
        p = plan((40, 12, 30), jnp.float32,
                 TuckerConfig(ranks=(4, 6, 5), mode_order="opt"))
        assert p.total_predicted_s == sum(s.predicted_s for s in p.schedule)
        assert "TuckerPlan" in p.describe() and "step 0" in p.describe()

    def test_trace_reports_predicted_vs_actual(self):
        x = lowrank((24, 20, 16), (3, 3, 3))
        res = sthosvd(x, (3, 3, 3), methods="eig", block_until_ready=True)
        rep = res.report()
        assert "seconds" in rep and "total" in rep
        for t in res.trace:
            assert t.delta_s == t.seconds - t.predicted_s


# ---------------------------------------------------------------------------
# Donated sweeps
# ---------------------------------------------------------------------------

def _live_bytes():
    return sum(a.nbytes for a in jax.live_arrays())


class TestDonation:
    SHAPE, RANKS = (64, 48, 40), (6, 8, 5)

    def _plan(self, **kw):
        return plan(self.SHAPE, jnp.float32,
                    TuckerConfig(ranks=self.RANKS, methods="eig", **kw))

    def test_bitwise_parity_donated_vs_undonated(self):
        p = self._plan()
        xn = np.asarray(lowrank(self.SHAPE, self.RANKS))
        r0 = p.execute(jnp.asarray(xn), donate=False)
        r1 = p.execute(jnp.asarray(xn), donate=True)
        np.testing.assert_array_equal(np.asarray(r0.tucker.core),
                                      np.asarray(r1.tucker.core))
        for u0, u1 in zip(r0.tucker.factors, r1.tucker.factors):
            np.testing.assert_array_equal(np.asarray(u0), np.asarray(u1))

    def test_donated_input_is_consumed(self):
        p = self._plan()
        x = jnp.asarray(np.asarray(lowrank(self.SHAPE, self.RANKS)))
        res = p.execute(x, donate=True)
        jax.block_until_ready(res.tucker.core)
        assert x.is_deleted()

    def test_auto_policy_never_invalidates_caller_array(self):
        p = self._plan()   # donate_input=None (auto)
        x = jnp.asarray(np.asarray(lowrank(self.SHAPE, self.RANKS)))
        res = p.execute(x)
        jax.block_until_ready(res.tucker.core)
        assert not x.is_deleted()
        np.testing.assert_allclose(float(jnp.sum(x)), float(jnp.sum(x)))

    def test_auto_policy_donates_host_inputs(self):
        if not donation_supported(jax.default_backend()):
            pytest.skip("platform has no buffer donation")
        p = self._plan()
        xn = np.asarray(lowrank(self.SHAPE, self.RANKS))
        base = _live_bytes()
        res = p.execute(xn)          # device copy created AND donated inside
        jax.block_until_ready(res.tucker.core)
        held = _live_bytes() - base  # results only, no dead copy of X
        assert held < xn.nbytes

    def test_live_array_high_water_below_undonated(self):
        if not donation_supported(jax.default_backend()):
            pytest.skip("platform has no buffer donation")
        p = self._plan()
        xn = np.asarray(lowrank(self.SHAPE, self.RANKS))

        def high_water(donate):
            base = _live_bytes()
            x = jnp.asarray(xn)
            res = p.execute(x, donate=donate)
            jax.block_until_ready(res.tucker.core)
            hw = _live_bytes() - base
            del x, res
            return hw

        undonated, donated = high_water(False), high_water(True)
        assert donated < undonated
        assert undonated - donated == xn.nbytes

    def test_env_escape_hatch(self, monkeypatch):
        p = self._plan()
        monkeypatch.setenv("ATUCKER_NO_DONATE", "1")
        x = jnp.asarray(np.asarray(lowrank(self.SHAPE, self.RANKS)))
        res = p.execute(x, donate=True)
        jax.block_until_ready(res.tucker.core)
        assert not x.is_deleted()

    def test_config_false_wins_over_auto(self):
        p = self._plan(donate_input=False)
        assert p.donates is False
        # and the modeled peak charges the undonated input copy
        assert p.peak_bytes >= self._plan(donate_input=True).peak_bytes

    def test_interpret_mode_backend_never_donates(self):
        p = plan(self.SHAPE, jnp.float32,
                 TuckerConfig(ranks=self.RANKS, methods="eig",
                              impl="pallas", donate_input=True))
        if jax.default_backend() == "tpu":
            pytest.skip("pallas is native on TPU; guard targets interpret mode")
        assert p.donates is False


# ---------------------------------------------------------------------------
# Runtime cap smoke (the tier-2 CI job body)
# ---------------------------------------------------------------------------

class TestRuntimeCapSmoke:
    def test_capped_plan_high_water_stays_bounded(self):
        """Plan under a tight cap, execute eagerly step by step, and sample
        jax.live_arrays between steps: the extra footprint beyond the held
        input must stay within the cap the plan promised."""
        from repro.core.plan import solve_step

        shape, ranks = (20, 96, 56), (16, 4, 7)
        free = plan(shape, jnp.float32, TuckerConfig(ranks=ranks))
        cap = int(max(s.peak_bytes for s in free.schedule) * 0.8)
        p = plan(shape, jnp.float32,
                 TuckerConfig(ranks=ranks, mode_order="opt",
                              memory_cap_bytes=cap))
        assert all(s.peak_bytes <= cap for s in p.schedule)

        x = lowrank(shape, ranks)
        jax.block_until_ready(x)
        base = _live_bytes()
        y, high = x, 0
        for step in p.schedule:
            res = solve_step(y, step, als_iters=p.config.als_iters)
            jax.block_until_ready(res.y_new)
            y = res.y_new
            high = max(high, _live_bytes() - base)
        # boundary samples see the shrunken tensor + factors, never the
        # busted-cap working set the uncapped plan would have carried
        assert high <= cap


# ---------------------------------------------------------------------------
# TuckerBatchEngine cap pinning
# ---------------------------------------------------------------------------

class TestEngineCapPin:
    def test_engine_pins_cap_onto_request_configs(self):
        from repro.serve.engine import TuckerBatchEngine, TuckerRequest

        shape, ranks = (16, 96, 64), (12, 4, 8)
        nat = plan(shape, jnp.float32, TuckerConfig(ranks=ranks))
        cap = int(max(s.peak_bytes for s in nat.schedule) * 0.8)
        eng = TuckerBatchEngine(memory_cap_bytes=cap)
        reqs = [TuckerRequest(x=lowrank(shape, ranks, seed=s),
                              config=TuckerConfig(ranks=ranks,
                                                  mode_order="opt"))
                for s in range(3)]
        eng.run(reqs)
        assert all(r.result is not None for r in reqs)
        (plan_built,) = eng._plans.values()
        assert plan_built.config.memory_cap_bytes == cap
        assert all(s.peak_bytes <= cap for s in plan_built.schedule)

    def test_request_keeps_tighter_cap(self):
        from repro.serve.engine import TuckerBatchEngine

        eng = TuckerBatchEngine(memory_cap_bytes=10**9)
        cfg = TuckerConfig(ranks=(2, 2, 2), memory_cap_bytes=10**8)
        assert eng._pinned(cfg).memory_cap_bytes == 10**8
        loose = TuckerConfig(ranks=(2, 2, 2))
        assert eng._pinned(loose).memory_cap_bytes == 10**9

    def test_infeasible_engine_cap_fails_at_plan_time(self):
        from repro.serve.engine import TuckerBatchEngine, TuckerRequest

        eng = TuckerBatchEngine(memory_cap_bytes=1000)
        req = TuckerRequest(x=lowrank((16, 12, 10), (2, 2, 2)),
                            config=TuckerConfig(ranks=(2, 2, 2),
                                                mode_order="opt"))
        with pytest.raises(MemoryCapError):
            eng.run([req])


# ---------------------------------------------------------------------------
# Review regressions
# ---------------------------------------------------------------------------

class TestReviewRegressions:
    def test_als_zero_iters_rejected(self):
        from repro.core.solvers import als_solve

        x = lowrank((10, 8, 6), (3, 3, 3))
        with pytest.raises(ValueError, match="num_iters"):
            als_solve(x, 0, 3, num_iters=0)

    def test_undonated_plan_cap_counts_held_input(self):
        # every step fits the cap, but an UNDONATED sweep also keeps the
        # dead input copy alive through steps 1..N-1 — the plan must refuse
        shape, ranks = (32, 24, 20), (4, 4, 4)
        donated = plan(shape, jnp.float32,
                       TuckerConfig(ranks=ranks, donate_input=True))
        cap = donated.peak_bytes + 1   # fits per step and when donated
        assert plan(shape, jnp.float32,
                    TuckerConfig(ranks=ranks, donate_input=True,
                                 memory_cap_bytes=cap)).peak_bytes <= cap
        with pytest.raises(MemoryCapError, match="undonated"):
            plan(shape, jnp.float32,
                 TuckerConfig(ranks=ranks, donate_input=False,
                              memory_cap_bytes=cap))

    def test_per_call_donate_overrides_config_false(self):
        if not donation_supported(jax.default_backend()):
            pytest.skip("platform has no buffer donation")
        p = plan((32, 24, 20), jnp.float32,
                 TuckerConfig(ranks=(4, 4, 4), methods="eig",
                              donate_input=False))
        x = jnp.asarray(np.asarray(lowrank((32, 24, 20), (4, 4, 4))))
        res = p.execute(x, donate=True)
        jax.block_until_ready(res.tucker.core)
        assert x.is_deleted()

    def test_input_bytes_uses_storage_dtype(self):
        # the buffer an undonated sweep holds is x AS PASSED (bf16); the
        # fp32 cast happens inside the jit and is not the held copy
        p = plan((32, 24, 20), jnp.bfloat16,
                 TuckerConfig(ranks=(4, 4, 4), methods="eig",
                              compute_dtype="float32"))
        assert p.input_bytes == 32 * 24 * 20 * 2
