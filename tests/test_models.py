"""Per-arch smoke tests + decode/prefill consistency across families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build
from repro.models.lm import cache_len, forward_hidden, logits_from_hidden

RNG = np.random.default_rng(0)


def make_batch(cfg, b=2, t=16):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, t + 1)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            RNG.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch = {"frames": jnp.asarray(RNG.standard_normal((b, t, cfg.d_model)),
                                       jnp.float32),
                 "tokens": batch["tokens"]}
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
class TestArchSmoke:
    def test_forward_loss_finite(self, arch):
        cfg = configs.get_smoke(arch)
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        loss, metrics = jax.jit(bundle.loss)(params, make_batch(cfg))
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), arch
        assert bool(jnp.isfinite(metrics["nll"]))

    def test_train_step_finite(self, arch):
        cfg = configs.get_smoke(arch)
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        (loss, _), grads = jax.jit(
            jax.value_and_grad(bundle.loss, has_aux=True))(params, make_batch(cfg))
        gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0, arch

    def test_full_config_exact_numbers(self, arch):
        """The FULL configs carry the exact assigned hyper-parameters."""
        cfg = configs.get(arch)
        assigned = {
            "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
            "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
            "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
            "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
            "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
            "phi3_mini_3p8b": (32, 3072, 32, 32, 8192, 32064),
            "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
            "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
            "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
            "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        }[configs.canonical(arch)]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == assigned, (arch, got, assigned)


DECODE_ARCHS = ["gemma3_1b", "gemma2_9b", "phi3_mini_3p8b", "minitron_4b",
                "mixtral_8x22b", "granite_moe_3b_a800m", "falcon_mamba_7b",
                "zamba2_1p2b", "internvl2_2b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_teacher_forced(arch):
    cfg = configs.get_smoke(arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    B, T = 2, 21
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)), jnp.int32)
    h, _, _ = forward_hidden(params, cfg, toks)
    ref = logits_from_hidden(params, cfg, h)
    cache = bundle.init_cache(B, T)
    step = jax.jit(lambda p, tok, c, pos: bundle.decode(p, tok, c, pos, T))
    outs = []
    for t in range(T):
        lg, cache = step(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.abs(dec - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert err < 3e-3, (arch, err)


@pytest.mark.parametrize("arch", ["phi3_mini_3p8b", "mixtral_8x22b",
                                  "falcon_mamba_7b"])
def test_prefill_then_decode(arch):
    cfg = configs.get_smoke(arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(2))
    B, T, SPLIT = 2, 24, 17
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)), jnp.int32)
    h, _, _ = forward_hidden(params, cfg, toks)
    ref = logits_from_hidden(params, cfg, h)
    cache = bundle.init_cache(B, T)
    lg, cache = jax.jit(bundle.prefill)(params, {"tokens": toks[:, :SPLIT]}, cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[:, SPLIT - 1]),
                               rtol=3e-3, atol=3e-4)
    step = jax.jit(lambda p, tok, c, pos: bundle.decode(p, tok, c, pos, T))
    lg2, _ = step(params, toks[:, SPLIT:SPLIT + 1], cache, jnp.int32(SPLIT))
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(ref[:, SPLIT]),
                               rtol=3e-3, atol=3e-4)


def test_encdec_decode_matches_teacher_forced():
    from repro.models import encdec
    cfg = configs.get_smoke("seamless_m4t_medium")
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(3))
    B, T = 2, 12
    frames = jnp.asarray(RNG.standard_normal((B, 10, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)), jnp.int32)
    memory = encdec.encode(params, cfg, frames)
    ckv = encdec.cross_kv(params, cfg, memory)
    h, _ = encdec.decode_hidden(params, cfg, toks, ckv)
    ref = logits_from_hidden(params, cfg, h)
    cache = encdec.init_cache(cfg, B, T, 10)
    _, cache = jax.jit(bundle.prefill)(
        params, {"frames": frames, "tokens": toks[:, :1]}, cache)
    outs = [None]
    step = jax.jit(lambda p, tok, c, pos: bundle.decode(p, tok, c, pos, T))
    for t in range(1, T):
        lg, cache = step(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    for t in range(1, T):
        np.testing.assert_allclose(np.asarray(outs[t]), np.asarray(ref[:, t]),
                                   rtol=3e-3, atol=3e-4)


def test_gemma3_local_global_pattern():
    cfg = configs.get("gemma3_1b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 26
    assert kinds[:6] == (1, 1, 1, 1, 1, 0)       # 5 local then 1 global
    assert sum(1 for k in kinds if k == 0) == 4


def test_zamba2_shared_sites():
    cfg = configs.get("zamba2_1p2b")
    sites = cfg.shared_attn_sites()
    assert len(sites) == 38 and sum(sites) == 6
    assert sites[5] == 1 and sites[11] == 1


def test_vocab_padding_exact_loss():
    """Padded logits tail must not leak into the softmax."""
    cfg = configs.get_smoke("phi3_mini_3p8b").with_(vocab=250)  # pads to 256
    assert cfg.vocab_padded == 256
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(4))
    toks = jnp.asarray(RNG.integers(0, 250, (2, 9)), jnp.int32)
    h, _, _ = forward_hidden(params, cfg, toks[:, :-1])
    logits = logits_from_hidden(params, cfg, h)
    assert float(logits[..., 250:].max()) < -1e29
    loss, _ = bundle.loss(params, {"tokens": toks})
    # manual loss over the true vocab only
    lp = jax.nn.log_softmax(logits[..., :250], axis=-1)
    nll = -jnp.take_along_axis(lp, toks[:, 1:][..., None], axis=-1).mean()
    np.testing.assert_allclose(float(loss), float(nll), rtol=1e-5)
