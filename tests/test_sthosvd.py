"""st-HOSVD solver correctness: exact recovery, error parity, flexibility."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (ALS, EIG, SVD, sthosvd, sthosvd_als, sthosvd_eig,
                        sthosvd_svd, tensor_ops as T)
from repro.core.solvers import als_solve, eig_solve, svd_solve

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def lowrank(dims, ranks, seed=0, noise=0.0):
    """noise is RELATIVE to the signal's per-element RMS, so the achievable
    rel-error at the true ranks is ≈ noise."""
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    if noise:
        rms = float(jnp.sqrt(jnp.mean(x ** 2)))
        x = x + noise * rms * jnp.asarray(rng.standard_normal(dims), jnp.float32)
    return x


class TestExactRecovery:
    @pytest.mark.parametrize("fn", [sthosvd_eig, sthosvd_als, sthosvd_svd])
    def test_exact_lowrank(self, fn):
        x = lowrank((12, 15, 10), (3, 4, 2))
        res = fn(x, (3, 4, 2))
        assert float(res.tucker.rel_error(x)) < 1e-4

    @given(seed=st.integers(0, 20))
    def test_eig_als_parity_on_noisy(self, seed):
        x = lowrank((10, 12, 8), (2, 3, 2), seed=seed, noise=0.05)
        e1 = float(sthosvd_eig(x, (2, 3, 2)).tucker.rel_error(x))
        e2 = float(sthosvd_als(x, (2, 3, 2)).tucker.rel_error(x))
        # paper Table III: accuracies agree to ~1e-3 relative
        assert abs(e1 - e2) < 2e-2 + 0.15 * max(e1, e2)

    def test_4th_order(self):
        x = lowrank((6, 7, 8, 5), (2, 3, 2, 2))
        res = sthosvd(x, (2, 3, 2, 2), methods="eig")
        assert float(res.tucker.rel_error(x)) < 1e-4


class TestFactors:
    @pytest.mark.parametrize("method", [EIG, ALS, SVD])
    def test_orthonormal_factors(self, method):
        x = lowrank((10, 12, 8), (3, 4, 2), noise=0.1)
        res = sthosvd(x, (3, 4, 2), methods=method)
        for u in res.tucker.factors:
            g = np.asarray(u.T @ u)
            np.testing.assert_allclose(g, np.eye(g.shape[0]), atol=2e-3)

    def test_error_decreases_with_rank(self):
        x = lowrank((12, 12, 12), (6, 6, 6), noise=0.2)
        errs = [float(sthosvd_eig(x, (r, r, r)).tucker.rel_error(x))
                for r in (1, 3, 6, 9)]
        assert all(errs[i] >= errs[i + 1] - 1e-6 for i in range(3))


class TestFlexible:
    def test_modewise_schedule(self):
        x = lowrank((10, 12, 8), (3, 4, 2), noise=0.05)
        res = sthosvd(x, (3, 4, 2), methods=("eig", "als", "eig"))
        assert res.methods == ("eig", "als", "eig")
        assert float(res.tucker.rel_error(x)) < 0.12

    def test_auto_uses_selector(self):
        calls = []

        def sel(*, i_n, r_n, j_n):
            calls.append((i_n, r_n, j_n))
            return "eig"

        x = lowrank((10, 12, 8), (3, 4, 2))
        res = sthosvd(x, (3, 4, 2), methods="auto", selector=sel)
        assert len(calls) == 3
        assert res.methods == ("eig", "eig", "eig")
        # J_n shrinks as earlier modes are truncated (st-HOSVD property)
        assert calls[1][2] == 3 * 8          # after mode-0 shrink to 3
        assert calls[2][2] == 3 * 4

    def test_mode_order_shrink(self):
        x = lowrank((20, 6, 8), (2, 3, 2), noise=0.01)
        res = sthosvd(x, (2, 3, 2), methods="eig", mode_order="shrink")
        assert {t.mode for t in res.trace} == {0, 1, 2}
        assert res.trace[0].mode == 0        # biggest shrink ratio first
        assert float(res.tucker.rel_error(x)) < 0.05

    def test_explicit_impl_parity(self):
        x = lowrank((9, 10, 8), (3, 3, 3), noise=0.02)
        a = sthosvd(x, (3, 3, 3), methods="eig", impl="matfree")
        b = sthosvd(x, (3, 3, 3), methods="eig", impl="explicit")
        np.testing.assert_allclose(float(a.tucker.rel_error(x)),
                                   float(b.tucker.rel_error(x)), atol=1e-5)

    def test_compression_ratio(self):
        x = lowrank((20, 20, 20), (4, 4, 4))
        tt = sthosvd_eig(x, (4, 4, 4)).tucker
        assert tt.ranks == (4, 4, 4)
        expected = 8000 / (64 + 3 * 80)
        assert abs(tt.compression_ratio - expected) < 1e-6

    def test_validation_errors(self):
        x = lowrank((5, 6, 7), (2, 2, 2))
        with pytest.raises(ValueError):
            sthosvd(x, (2, 2))
        with pytest.raises(ValueError):
            sthosvd(x, (2, 9, 2))
        with pytest.raises(ValueError):
            sthosvd(x, (2, 2, 2), methods=("eig",))


class TestSolversDirect:
    @given(mode=st.integers(0, 2), seed=st.integers(0, 5))
    def test_eig_vs_svd_subspace(self, mode, seed):
        x = lowrank((8, 9, 10), (3, 3, 3), seed=seed, noise=0.01)
        ue = eig_solve(x, mode, 3).u
        us = svd_solve(x, mode, 3).u
        pe, ps = np.asarray(ue @ ue.T), np.asarray(us @ us.T)
        np.testing.assert_allclose(pe, ps, atol=5e-2)

    def test_als_iterations_converge(self):
        x = lowrank((10, 11, 9), (3, 3, 3), noise=0.02)
        errs = []
        for it in (1, 3, 8):
            u, y = als_solve(x, 0, 3, num_iters=it)
            # residual of the rank-3 mode-0 approximation
            xa = T.ttm(y, u, 0)
            errs.append(float(T.fro_norm(x - xa) / T.fro_norm(x)))
        assert errs[-1] <= errs[0] + 1e-5
