"""Rank-adaptive planning: the `rand` solver family, error-targeted plans
(`TuckerConfig(error_target=...)`), the rank axis in the schedule DP, the
selector's widened candidate set, achieved-error labels in the tune store,
and adaptive configs flowing through serving."""

from dataclasses import replace

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (TuckerConfig, TuckerPlan, plan, rand_sketch,
                        rand_solve, tensor_ops as T)
from repro.core.backend import backend_ops
from repro.core.cost_model import CostModel
from repro.core.schedule_opt import optimize_schedule
from repro.core.selector import Selector
from repro.core.sthosvd import ModeTrace
from repro.tune.collect import measurements_from_traces
from repro.tune.records import Measurement
from repro.tune.train import labeled_examples


def lowrank(dims, ranks, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    if noise:
        rms = float(jnp.sqrt(jnp.mean(x ** 2)))
        x = x + noise * rms * jnp.asarray(rng.standard_normal(dims),
                                          jnp.float32)
    return x


DIMS, TRUE_RANKS, EPS = (60, 40, 24), (6, 5, 4), 0.05


class TestRandSolver:
    def test_rand_solve_recovers_lowrank_subspace(self):
        x = lowrank(DIMS, TRUE_RANKS, noise=0.0)
        y, factors = x, {}
        for mode, r in enumerate(TRUE_RANKS):
            res = rand_solve(y, mode, r)
            factors[mode] = res.u
            y = res.y_new
        # orthonormal factors, near-exact reconstruction at the true ranks
        for u in factors.values():
            eye = np.eye(u.shape[1], dtype=np.float32)
            np.testing.assert_allclose(np.asarray(u.T @ u), eye, atol=1e-4)
        xh = T.reconstruct(y, [factors[m] for m in range(len(DIMS))])
        err = float(jnp.linalg.norm(x - xh) / jnp.linalg.norm(x))
        assert err < 1e-3

    def test_sketch_tail_is_exact_for_the_used_factor(self):
        # the rank decision's tail — energy minus the top-r sketched
        # eigenvalues — must equal the true discarded energy of the factor
        # u = q·v actually built from the sketch, at ANY width
        x = lowrank((30, 20, 16), (5, 4, 3), noise=0.05)
        width = 12
        q, b, evals, vecs, energy = rand_sketch(x, 0, width)
        ev = np.asarray(evals, dtype=np.float64)
        ttm = backend_ops("matfree")[0]
        for r in (2, 4, 8):
            v = vecs[:, -r:][:, ::-1].astype(q.dtype)
            u = jnp.dot(q, v)
            resid = x - ttm(ttm(x, u.T, 0), u, 0)
            actual = float(jnp.linalg.norm(resid)) ** 2
            modeled = float(energy) - float(ev[::-1][:r].sum())
            assert actual == pytest.approx(modeled, rel=1e-3, abs=1e-2)

    def test_rand_is_exposed_as_a_solver(self):
        from repro.core import RAND
        from repro.core.solvers import SOLVERS
        assert RAND == "rand" and "rand" in SOLVERS


class TestAdaptiveConfig:
    def test_ranks_none_requires_error_target(self):
        with pytest.raises(ValueError):
            TuckerConfig()

    @pytest.mark.parametrize("eps", [0.0, 1.0, -0.1, 2.0])
    def test_error_target_range(self, eps):
        with pytest.raises(ValueError):
            TuckerConfig(error_target=eps)

    def test_error_target_rejects_incompatible_modes(self):
        with pytest.raises(ValueError):
            TuckerConfig(error_target=0.05, variant="hooi")
        with pytest.raises(ValueError):
            TuckerConfig(error_target=0.05, mode_parallel="auto")
        with pytest.raises(ValueError):
            TuckerConfig(error_target=0.05, impl="sharded")

    def test_rank_grid_requires_error_target(self):
        with pytest.raises(ValueError):
            TuckerConfig(ranks=(4, 4, 4), rank_grid=(2, 4))

    def test_rank_grid_normalization_and_roundtrip(self):
        c = TuckerConfig(error_target=0.05, rank_grid=[2, 4, 8],
                         oversample=4, power_iters=2)
        assert c.rank_grid == (2, 4, 8)
        assert TuckerConfig.from_dict(c.to_dict()) == c
        per_mode = TuckerConfig(error_target=0.05,
                                rank_grid=((2, 4), (3, 6), (2,)))
        assert TuckerConfig.from_dict(per_mode.to_dict()) == per_mode


class TestAdaptiveExecution:
    def test_error_target_met_by_refined_sweep(self):
        x = lowrank(DIMS, TRUE_RANKS)
        p = plan(DIMS, jnp.float32, TuckerConfig(error_target=EPS))
        assert p.is_adaptive
        res = p.execute(x)
        err = float(res.tucker.rel_error(x))
        assert err <= EPS
        assert res.error_bound <= EPS
        assert err <= res.error_bound * 1.05  # bound is honest, not slack
        # refined sweep ran the classic solvers; sketch cost is selection
        assert all(t.method in ("eig", "als") for t in res.trace)
        assert res.select_overhead_s > 0.0
        assert any(t.tail_err > 0.0 for t in res.trace)
        # the policy found (at most a few above) the true ranks, not I_n
        assert all(r <= 2 * t for r, t in zip(res.tucker.ranks, TRUE_RANKS))

    def test_sketch_only_execution(self):
        x = lowrank(DIMS, TRUE_RANKS)
        p = plan(DIMS, jnp.float32,
                 TuckerConfig(error_target=EPS, methods="rand"))
        res = p.execute(x)
        assert all(t.method == "rand" for t in res.trace)
        assert float(res.tucker.rel_error(x)) <= EPS
        assert res.error_bound <= EPS

    def test_rank_grid_restricts_choices(self):
        x = lowrank(DIMS, TRUE_RANKS)
        p = plan(DIMS, jnp.float32,
                 TuckerConfig(error_target=EPS, rank_grid=(4, 8)))
        res = p.execute(x)
        assert all(r in (4, 8) for r in res.tucker.ranks)
        assert float(res.tucker.rel_error(x)) <= EPS

    def test_ranks_cap_the_default_grid(self):
        x = lowrank(DIMS, TRUE_RANKS)
        p = plan(DIMS, jnp.float32,
                 TuckerConfig(ranks=(5, 4, 3), error_target=EPS))
        res = p.execute(x)
        assert all(r <= c for r, c in zip(res.tucker.ranks, (5, 4, 3)))

    def test_resolve_ranks(self):
        x = lowrank(DIMS, TRUE_RANKS)
        p = plan(DIMS, jnp.float32, TuckerConfig(error_target=EPS))
        ranks, bound = p.resolve_ranks(x)
        assert len(ranks) == 3 and all(1 <= r <= d
                                       for r, d in zip(ranks, DIMS))
        assert 0.0 <= bound <= EPS
        fixed = plan(DIMS, jnp.float32, TuckerConfig(ranks=(4, 4, 4)))
        with pytest.raises(ValueError):
            fixed.resolve_ranks(x)

    def test_execute_batch_item_by_item(self):
        xs = jnp.stack([lowrank(DIMS, TRUE_RANKS, seed=s) for s in range(2)])
        p = plan(DIMS, jnp.float32, TuckerConfig(error_target=EPS))
        out = p.execute_batch(xs)
        assert len(out) == 2
        for r, xi in zip(out, xs):
            assert float(r.tucker.rel_error(xi)) <= EPS


class TestAdaptivePlanJSON:
    def test_adaptive_plan_round_trips(self):
        p = plan(DIMS, jnp.float32,
                 TuckerConfig(error_target=EPS, rank_grid=(4, 8),
                              oversample=4, power_iters=2))
        p2 = TuckerPlan.from_json(p.to_json())
        assert p2.is_adaptive
        assert p2.config == p.config
        assert p2.describe() == p.describe()
        assert [ (s.mode, s.rank_grid, s.tau) for s in p2.schedule ] == \
               [ (s.mode, s.rank_grid, s.tau) for s in p.schedule ]
        x = lowrank(DIMS, TRUE_RANKS)
        assert float(p2.execute(x).tucker.rel_error(x)) <= EPS

    def test_describe_names_the_policy(self):
        p = plan(DIMS, jnp.float32, TuckerConfig(error_target=EPS))
        d = p.describe()
        assert "error_target=0.05" in d and "rank-adaptive" in d
        assert "grid=" in d


class TestScheduleDPRankAxis:
    def test_legacy_fixed_ranks_unchanged(self):
        rs = optimize_schedule((30, 20, 10), (8, 6, 4))
        fixed = (8, 6, 4)
        assert rs.ranks == tuple(fixed[m] for m in rs.order)

    def test_grid_opens_the_rank_axis(self):
        rs = optimize_schedule((30, 20, 10), (8, 6, 4),
                               methods=["rand"] * 3,
                               rank_grid=[(2, 8), (2, 6), (2, 4)])
        grids = {0: (2, 8), 1: (2, 6), 2: (2, 4)}
        assert all(r in grids[m] for m, r in zip(rs.order, rs.ranks))
        # with no accuracy term in the DP objective the cheapest (smallest)
        # grid rank wins every mode
        assert rs.ranks == (2, 2, 2)


class TestSelectorCandidates:
    def test_candidates_widen_the_cost_fallback(self):
        cheap = Selector(cost_model=CostModel(rand_scale=1e-12))
        kw = dict(i_n=500, r_n=8, j_n=400)
        assert cheap(**kw, candidates=("eig", "als", "rand")) == "rand"
        assert cheap(**kw) in ("eig", "als")
        dear = Selector(cost_model=CostModel(rand_scale=1e12))
        assert dear(**kw, candidates=("eig", "als", "rand")) in ("eig", "als")

    def test_rand_scale_falls_back_to_eig(self):
        assert CostModel().rand_scale_eff == CostModel().eig_scale
        assert CostModel(eig_scale=5e-12).rand_scale_eff == 5e-12
        assert CostModel(rand_scale=3e-12).rand_scale_eff == 3e-12
        assert CostModel.from_dict({}).rand_scale is None


class TestTuneAchievedErrorLabels:
    MEAS = dict(platform="cpu", backend="matfree", device="box",
                i_n=32, r_n=4, j_n=64, method="rand", seconds=0.01)

    def test_rel_err_round_trips_and_is_not_identity(self):
        m = Measurement(**self.MEAS, rel_err=0.02)
        assert Measurement.from_dict(m.to_dict()) == m
        assert m.key() == replace(m, rel_err=0.5).key()

    def test_rand_traces_harvest_with_tail_labels(self):
        traces = [
            ModeTrace(mode=0, method="rand", i_n=32, r_n=4, j_n=64,
                      seconds=0.01, tail_err=0.003),
            ModeTrace(mode=1, method="eig", i_n=16, r_n=4, j_n=128,
                      seconds=0.02),
            ModeTrace(mode=2, method="svd", i_n=8, r_n=2, j_n=64,
                      seconds=0.02),
        ]
        ms = measurements_from_traces(traces, platform="cpu",
                                      dtype="float32", order=3)
        assert [m.method for m in ms] == ["rand", "eig"]  # svd filtered
        assert ms[0].rel_err == pytest.approx(0.003)
        assert ms[1].rel_err == 0.0

    def test_labeled_examples_tolerance_drops_lossy_records(self):
        eig = Measurement(**{**self.MEAS, "method": "eig",
                             "seconds": 1.0})
        als = Measurement(**{**self.MEAS, "method": "als",
                             "seconds": 0.1}, rel_err=0.5)
        _, labels, _ = labeled_examples([eig, als])
        assert len(labels) == 1          # lossy-but-fast als wins unfiltered
        _, labels, _ = labeled_examples([eig, als], rel_err_tolerance=0.1)
        assert len(labels) == 0          # filtered: no pair survives


class TestServeAdaptive:
    def test_service_serves_error_targeted_requests(self):
        from repro.serve import TuckerService
        x = lowrank(DIMS, TRUE_RANKS)
        cfg = TuckerConfig(error_target=EPS)
        with TuckerService() as svc:
            svc.start()
            res = svc.wait(svc.submit(x, cfg))
            stats = svc.stats()
        assert float(res.tucker.rel_error(x)) <= EPS
        labels = list(stats["buckets"])
        assert any(label.endswith(f"/re{EPS:g}") for label in labels), labels
