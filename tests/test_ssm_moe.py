"""SSM scan oracles + MoE dispatch correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import _s6_scan, _ssd_scan

RNG = np.random.default_rng(0)


class TestS6:
    @pytest.mark.parametrize("chunk", [4, 8, 37])
    def test_matches_naive_recurrence(self, chunk):
        B, T, Di, N = 2, 37, 5, 4
        x = RNG.standard_normal((B, T, Di)).astype(np.float32)
        dt = np.abs(RNG.standard_normal((B, T, Di))).astype(np.float32) * 0.1
        bm = RNG.standard_normal((B, T, N)).astype(np.float32)
        cm = RNG.standard_normal((B, T, N)).astype(np.float32)
        a = -np.abs(RNG.standard_normal((Di, N))).astype(np.float32)

        h = np.zeros((B, Di, N))
        ys = []
        for t in range(T):
            da = np.exp(dt[:, t][:, :, None] * a[None])
            h = da * h + (dt[:, t] * x[:, t])[:, :, None] * bm[:, t][:, None, :]
            ys.append(np.einsum("bn,bdn->bd", cm[:, t], h))
        y_ref, h_ref = np.stack(ys, 1), h

        y, hf = _s6_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(bm),
                         jnp.asarray(cm), jnp.asarray(a), chunk=chunk)
        np.testing.assert_allclose(y, y_ref, atol=3e-4)
        np.testing.assert_allclose(hf, h_ref, atol=3e-4)

    def test_state_carry_across_calls(self):
        """Chunked prefill then continued scan == one long scan."""
        B, T, Di, N = 1, 24, 3, 2
        x = jnp.asarray(RNG.standard_normal((B, T, Di)), jnp.float32)
        dt = jnp.abs(jnp.asarray(RNG.standard_normal((B, T, Di)), jnp.float32)) * 0.1
        bm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
        cm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
        a = -jnp.abs(jnp.asarray(RNG.standard_normal((Di, N)), jnp.float32))
        y_full, h_full = _s6_scan(x, dt, bm, cm, a, chunk=8)
        y1, h1 = _s6_scan(x[:, :10], dt[:, :10], bm[:, :10], cm[:, :10], a, chunk=8)
        y2, h2 = _s6_scan(x[:, 10:], dt[:, 10:], bm[:, 10:], cm[:, 10:], a,
                          chunk=8, h0=h1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=2e-4)
        np.testing.assert_allclose(h2, h_full, atol=2e-4)


class TestSSD:
    @pytest.mark.parametrize("chunk", [4, 16])
    def test_matches_naive_recurrence(self, chunk):
        B, T, H, P, N = 2, 29, 3, 4, 5
        x = RNG.standard_normal((B, T, H, P)).astype(np.float32)
        dt = np.abs(RNG.standard_normal((B, T, H))).astype(np.float32) * 0.1
        bm = RNG.standard_normal((B, T, N)).astype(np.float32)
        cm = RNG.standard_normal((B, T, N)).astype(np.float32)
        a = -np.abs(RNG.standard_normal((H,))).astype(np.float32)

        h = np.zeros((B, H, P, N))
        ys = []
        for t in range(T):
            da = np.exp(dt[:, t] * a[None])
            h = da[:, :, None, None] * h + np.einsum(
                "bh,bhp,bn->bhpn", dt[:, t], x[:, t], bm[:, t])
            ys.append(np.einsum("bn,bhpn->bhp", cm[:, t], h))
        y_ref, h_ref = np.stack(ys, 1), h

        y, hf = _ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(bm),
                          jnp.asarray(cm), jnp.asarray(a), chunk=chunk)
        np.testing.assert_allclose(y, y_ref, atol=3e-4)
        np.testing.assert_allclose(hf, h_ref, atol=3e-4)


class TestMoE:
    def cfg(self, **kw):
        d = dict(d_model=32, d_ff=48, n_experts=4, top_k=2,
                 capacity_factor=8.0, router_aux_coef=0.01)
        d.update(kw)
        return ModelConfig(**d)

    def test_no_drop_matches_dense_reference(self):
        """At huge capacity, sort-dispatch == dense weighted expert sum."""
        cfg = self.cfg()
        p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(RNG.standard_normal((2, 9, 32)), jnp.float32)
        y, aux = moe_apply(p, x, cfg)

        # dense reference: run every token through all experts, weight by
        # renormalized top-k router probs
        logits = x.astype(jnp.float32) @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        top_p, top_e = jax.lax.top_k(probs, 2)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        gate = jnp.einsum("btd,edf->btef", x, p["w_gate"])
        up = jnp.einsum("btd,edf->btef", x, p["w_up"])
        h = jax.nn.silu(gate) * up
        all_out = jnp.einsum("btef,efd->bted", h, p["w_down"])
        mask = jnp.zeros((2, 9, 4)).at[
            jnp.arange(2)[:, None, None], jnp.arange(9)[None, :, None], top_e
        ].add(top_p)
        want = jnp.einsum("bte,bted->btd", mask, all_out)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-3, atol=2e-4)

    def test_capacity_drops_bounded(self):
        """With capacity 1.0 some tokens drop; output stays finite and the
        kept fraction is ≥ 1/topk-ish."""
        cfg = self.cfg(capacity_factor=1.0)
        p = moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
        x = jnp.asarray(RNG.standard_normal((1, 64, 32)), jnp.float32)
        y, aux = moe_apply(p, x, cfg)
        assert bool(jnp.isfinite(y).all())
        nz = float((jnp.abs(y).sum(-1) > 0).mean())
        assert nz > 0.5

    def test_aux_loss_range(self):
        cfg = self.cfg()
        p = moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
        x = jnp.asarray(RNG.standard_normal((2, 33, 32)), jnp.float32)
        _, aux = moe_apply(p, x, cfg)
        # Switch aux ≈ coef when perfectly balanced; bounded by coef·E
        assert 0 < float(aux) < cfg.router_aux_coef * cfg.n_experts

    def test_router_grads_nonzero(self):
        cfg = self.cfg()
        p = moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
        x = jnp.asarray(RNG.standard_normal((2, 9, 32)), jnp.float32)

        def loss(p_):
            y, aux = moe_apply(p_, x, cfg)
            return (y ** 2).mean() + aux

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]).sum()) > 0
        assert float(jnp.abs(g["w_down"]).sum()) > 0
