"""Plan-JSON compatibility: fixed-rank plans written before the rank-policy
axis existed (PR 7 fixtures, checked in under ``tests/data/``) must load
unchanged, describe identically, and fresh fixed-rank plans must serialize
without any adaptive keys — rank-adaptive fields are strictly additive."""

import json
from pathlib import Path

import jax.numpy as jnp

from repro.core import TuckerConfig, TuckerPlan, plan

DATA = Path(__file__).parent / "data"
FIXTURE_JSON = DATA / "plan_pr7_fixed_rank.json"
FIXTURE_DESCRIBE = DATA / "plan_pr7_describe.txt"

# the exact config the fixture was generated from (pre-rank-policy code)
FIXTURE_CFG = TuckerConfig(ranks=(40, 8, 12), methods=("eig", "als", "eig"),
                           mode_order="opt", donate_input=False)
FIXTURE_SHAPE = (48, 224, 128)


class TestLegacyPlanLoads:
    def test_fixture_loads_and_describes_identically(self):
        p = TuckerPlan.load(FIXTURE_JSON)
        assert p.shape == FIXTURE_SHAPE
        assert not p.is_adaptive
        assert p.config.error_target is None
        assert p.describe() == FIXTURE_DESCRIBE.read_text().rstrip("\n")

    def test_fixture_round_trips_byte_identically(self):
        p = TuckerPlan.load(FIXTURE_JSON)
        assert json.loads(p.to_json()) == json.loads(FIXTURE_JSON.read_text())

    def test_fresh_plan_matches_pre_rank_policy_serialization(self):
        # a plan built TODAY from the fixture's config serializes to the
        # same document the pre-PR-8 code wrote
        p = plan(FIXTURE_SHAPE, jnp.float32, FIXTURE_CFG)
        fresh, fixture = json.loads(p.to_json()), json.loads(
            FIXTURE_JSON.read_text())
        fresh.pop("select_seconds"), fixture.pop("select_seconds")
        assert fresh == fixture
        assert p.describe() == FIXTURE_DESCRIBE.read_text().rstrip("\n")

    def test_fixture_plan_still_executes(self):
        import numpy as np
        p = TuckerPlan.load(FIXTURE_JSON)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(p.shape),
                        jnp.float32)
        res = p.execute(x)
        assert res.tucker.ranks == (40, 8, 12)


class TestNoAdaptiveKeysOnFixedPlans:
    def test_config_dict_has_no_adaptive_keys(self):
        d = FIXTURE_CFG.to_dict()
        for key in ("error_target", "rank_grid", "oversample", "power_iters"):
            assert key not in d, key

    def test_plan_json_steps_have_no_adaptive_keys(self):
        doc = json.loads(plan(FIXTURE_SHAPE, jnp.float32,
                              FIXTURE_CFG).to_json())
        for key in ("error_target", "rank_grid", "oversample", "power_iters"):
            assert key not in doc["config"], key
        for step in doc["schedule"]:
            assert "rank_grid" not in step
            assert "tau" not in step
