"""Plan/execute front door: schedule resolution, compiled-sweep caching,
batched execution, JSON round-trip, and exact parity with the legacy path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (TuckerConfig, TuckerPlan, decompose, plan, sthosvd,
                        tensor_ops as T)
from repro.core import api as api_mod
from repro.core.variants import hooi, thosvd


def lowrank(dims, ranks, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    if noise:
        rms = float(jnp.sqrt(jnp.mean(x ** 2)))
        x = x + noise * rms * jnp.asarray(rng.standard_normal(dims), jnp.float32)
    return x


class TestConfig:
    def test_normalization_and_validation(self):
        c = TuckerConfig(ranks=[3, 4, 2], methods=["eig", "als", "eig"],
                         mode_order=[2, 0, 1])
        assert c.ranks == (3, 4, 2)
        assert c.methods == ("eig", "als", "eig")
        assert c.mode_order == (2, 0, 1)
        with pytest.raises(ValueError):
            TuckerConfig(ranks=(2, 2), variant="cp")
        with pytest.raises(ValueError):
            TuckerConfig(ranks=(2, 2), impl="magic")
        with pytest.raises(ValueError):
            TuckerConfig(ranks=(2, 2), als_iters=0)

    def test_dict_roundtrip(self):
        c = TuckerConfig(ranks=(3, 4, 2), variant="hooi", methods="auto",
                         mode_order="shrink", als_iters=7, hooi_iters=2,
                         compute_dtype="float32")
        assert TuckerConfig.from_dict(c.to_dict()) == c


class TestPlanning:
    def test_schedule_resolved_ahead_of_time(self):
        calls = []

        def sel(*, i_n, r_n, j_n):
            calls.append((i_n, r_n, j_n))
            return "eig"

        p = plan((10, 12, 8), jnp.float32, TuckerConfig(ranks=(3, 4, 2)),
                 selector=sel)
        # selector saw the same shrinking J_n the legacy in-loop path sees
        assert calls == [(10, 3, 96), (12, 4, 24), (8, 2, 12)]
        assert p.methods == ("eig", "eig", "eig")
        assert p.total_flops > 0 and p.peak_bytes > 0
        assert p.select_seconds >= 0.0

    def test_invalid_inputs(self):
        p = plan((10, 12, 8), jnp.float32, TuckerConfig(ranks=(3, 4, 2),
                                                        methods="eig"))
        with pytest.raises(ValueError):
            p.execute(jnp.zeros((10, 12, 9), jnp.float32))
        with pytest.raises(ValueError):
            p.execute(jnp.zeros((10, 12, 8), jnp.bfloat16))
        with pytest.raises(ValueError):
            p.execute_batch(jnp.zeros((2, 10, 12, 9), jnp.float32))
        with pytest.raises(ValueError):
            plan((10, 12), jnp.float32, TuckerConfig(ranks=(3, 4, 2)))
        with pytest.raises(ValueError):   # mode_order is meaningless there
            plan((10, 12, 8), jnp.float32,
                 TuckerConfig(ranks=(3, 4, 2), variant="thosvd",
                              mode_order=(2, 0, 1), methods="eig"))

    def test_hooi_schedule_shape(self):
        cfg = TuckerConfig(ranks=(3, 4, 2), variant="hooi", methods="eig",
                           hooi_iters=2)
        p = plan((10, 12, 8), jnp.float32, cfg)
        assert len(p.schedule) == 3 + 2 * 3       # init sweep + 2 sweeps
        # refinement steps see x projected on all other factors
        s = p.schedule[3]
        assert (s.i_n, s.r_n, s.j_n) == (10, 3, 4 * 2)


class TestExecuteParity:
    def test_execute_matches_legacy_bitwise(self):
        """Acceptance: same resolved schedule → bitwise-identical results."""
        x = lowrank((12, 15, 10), (3, 4, 2), noise=0.05)
        p = plan(x.shape, x.dtype, TuckerConfig(ranks=(3, 4, 2)))
        legacy = sthosvd(x, (3, 4, 2), methods=p.methods)
        res = p.execute(x)
        assert bool(jnp.all(res.tucker.core == legacy.tucker.core))
        for u_new, u_old in zip(res.tucker.factors, legacy.tucker.factors):
            assert bool(jnp.all(u_new == u_old))

    @pytest.mark.parametrize("variant,legacy_fn", [
        ("thosvd", lambda x, r: thosvd(x, r, methods="eig")),
        ("hooi", lambda x, r: hooi(x, r, n_iters=2, methods="eig")),
    ])
    def test_variant_plans_match_legacy(self, variant, legacy_fn):
        x = lowrank((10, 9, 8), (2, 3, 2), noise=0.05)
        cfg = TuckerConfig(ranks=(2, 3, 2), variant=variant, methods="eig",
                           hooi_iters=2)
        res = plan(x.shape, x.dtype, cfg).execute(x)
        ref = legacy_fn(x, (2, 3, 2))
        np.testing.assert_allclose(np.asarray(res.tucker.core),
                                   np.asarray(ref.tucker.core),
                                   rtol=1e-5, atol=1e-5)

    def test_mode_order_and_als_iters_respected(self):
        x = lowrank((20, 6, 8), (2, 3, 2), noise=0.01)
        cfg = TuckerConfig(ranks=(2, 3, 2), methods="als", als_iters=8,
                           mode_order="shrink")
        p = plan(x.shape, x.dtype, cfg)
        assert p.schedule[0].mode == 0            # biggest shrink first
        legacy = sthosvd(x, (2, 3, 2), methods="als", als_iters=8,
                         mode_order="shrink")
        res = p.execute(x)
        assert bool(jnp.all(res.tucker.core == legacy.tucker.core))

    def test_decompose_convenience(self):
        x = lowrank((12, 10, 8), (3, 3, 2))
        res = decompose(x, TuckerConfig(ranks=(3, 3, 2), methods="eig"))
        assert float(res.tucker.rel_error(x)) < 1e-4


class TestBatch:
    def test_execute_batch_matches_per_item_loop(self):
        xs = jnp.stack([lowrank((10, 9, 8), (2, 3, 2), seed=s, noise=0.05)
                        for s in range(4)])
        p = plan(xs.shape[1:], xs.dtype, TuckerConfig(ranks=(2, 3, 2)))
        batch = p.execute_batch(xs)
        assert len(batch) == 4
        for b, res in enumerate(batch):
            one = p.execute(xs[b])
            # batched GEMMs may round differently → allclose, not bitwise
            np.testing.assert_allclose(np.asarray(res.tucker.core),
                                       np.asarray(one.tucker.core),
                                       rtol=1e-4, atol=1e-4)
            xhat_b = res.tucker.reconstruct()
            xhat_1 = one.tucker.reconstruct()
            np.testing.assert_allclose(np.asarray(xhat_b), np.asarray(xhat_1),
                                       rtol=1e-4, atol=1e-4)


class TestCompileCache:
    def test_plan_reuse_zero_recompiles_zero_selections(self):
        """Acceptance: repeated executes on same-shaped inputs hit the cached
        compiled sweep (no retraces) and never touch the selector."""
        api_mod.clear_sweep_cache()
        selections = []

        def sel(*, i_n, r_n, j_n):
            selections.append((i_n, r_n, j_n))
            return "eig"

        x = lowrank((12, 10, 8), (3, 3, 2), noise=0.05)
        p = plan(x.shape, x.dtype, TuckerConfig(ranks=(3, 3, 2)), selector=sel)
        n_plan_selections = len(selections)
        assert n_plan_selections == 3

        p.execute(x)
        after_first = dict(api_mod.CACHE_STATS)
        assert after_first["builds"] == 1 and after_first["traces"] == 1

        for s in range(5):
            p.execute(x + float(s))
        assert api_mod.CACHE_STATS["traces"] == after_first["traces"]
        assert api_mod.CACHE_STATS["builds"] == after_first["builds"]
        assert api_mod.CACHE_STATS["hits"] == after_first["hits"] + 5
        assert len(selections) == n_plan_selections   # zero at execute time

    def test_equivalent_plans_share_compiled_sweep(self):
        api_mod.clear_sweep_cache()
        x = lowrank((10, 9, 8), (2, 3, 2))
        cfg = TuckerConfig(ranks=(2, 3, 2), methods="eig")
        plan(x.shape, x.dtype, cfg).execute(x)
        plan(x.shape, x.dtype, cfg).execute(x)     # fresh plan, same key
        assert api_mod.CACHE_STATS["builds"] == 1
        assert api_mod.CACHE_STATS["hits"] == 1
        assert api_mod.CACHE_STATS["traces"] == 1

    def test_batched_program_cached_separately(self):
        api_mod.clear_sweep_cache()
        xs = jnp.stack([lowrank((10, 9, 8), (2, 3, 2), seed=s)
                        for s in range(2)])
        p = plan(xs.shape[1:], xs.dtype,
                 TuckerConfig(ranks=(2, 3, 2), methods="eig"))
        p.execute_batch(xs)
        p.execute_batch(xs)
        assert api_mod.CACHE_STATS["builds"] == 1
        assert api_mod.CACHE_STATS["hits"] == 1
        assert api_mod.CACHE_STATS["traces"] == 1


class TestSerialization:
    def test_json_roundtrip_preserves_schedule_and_results(self, tmp_path):
        x = lowrank((12, 10, 8), (3, 3, 2), noise=0.05)
        p = plan(x.shape, x.dtype,
                 TuckerConfig(ranks=(3, 3, 2), variant="sthosvd"))
        path = tmp_path / "plan.json"
        p.save(path)
        p2 = TuckerPlan.load(path)
        assert p2.shape == p.shape and p2.dtype == p.dtype
        assert p2.config == p.config
        assert p2.schedule == p.schedule
        r1, r2 = p.execute(x), p2.execute(x)
        assert bool(jnp.all(r1.tucker.core == r2.tucker.core))

    def test_loaded_plan_never_selects(self, tmp_path):
        p = plan((10, 9, 8), jnp.float32, TuckerConfig(ranks=(2, 3, 2)),
                 selector=lambda *, i_n, r_n, j_n: "als")
        path = tmp_path / "p.json"
        p.save(path)
        p2 = TuckerPlan.load(path)
        assert p2.methods == ("als", "als", "als")  # frozen choice survives

    def test_version_guard(self):
        d = plan((4, 4, 4), jnp.float32,
                 TuckerConfig(ranks=(2, 2, 2), methods="eig")).to_dict()
        d["version"] = 999
        with pytest.raises(ValueError):
            TuckerPlan.from_dict(d)


class TestServeEngine:
    def test_groups_by_shape_and_reuses_plans(self):
        from repro.serve import TuckerBatchEngine, TuckerRequest

        eng = TuckerBatchEngine()
        cfg = TuckerConfig(ranks=(2, 3, 2), methods="eig")
        reqs = [TuckerRequest(x=lowrank((10, 9, 8), (2, 3, 2), seed=s),
                              config=cfg, rid=s) for s in range(5)]
        reqs += [TuckerRequest(x=lowrank((6, 7, 5), (2, 2, 2), seed=9),
                               config=TuckerConfig(ranks=(2, 2, 2),
                                                   methods="eig"), rid=99)]
        done = eng.run(reqs)
        assert all(r.result is not None for r in done)
        assert eng.stats["plans_built"] == 2       # one per (shape, config)
        for r in done:
            assert float(r.result.tucker.rel_error(r.x)) < 1e-3
        # second wave with the same shapes: no new plans
        wave2 = [TuckerRequest(x=lowrank((10, 9, 8), (2, 3, 2), seed=7),
                               config=cfg, rid=7)]
        eng.run(wave2)
        assert eng.stats["plans_built"] == 2
