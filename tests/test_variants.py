"""t-HOSVD and HOOI variants (paper §II-B / future-work §VIII)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sthosvd_eig, tensor_ops as T
from repro.core.variants import hooi, thosvd


def lowrank(dims, ranks, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    if noise:
        rms = float(jnp.sqrt(jnp.mean(x ** 2)))
        x = x + noise * rms * jnp.asarray(rng.standard_normal(dims), jnp.float32)
    return x


class TestTHOSVD:
    @pytest.mark.parametrize("methods", ["eig", "als"])
    def test_exact_recovery(self, methods):
        x = lowrank((12, 10, 8), (3, 3, 2))
        res = thosvd(x, (3, 3, 2), methods=methods)
        assert float(res.tucker.rel_error(x)) < 1e-4

    def test_orthonormal_and_auto(self):
        x = lowrank((12, 10, 8), (3, 3, 2), noise=0.05)
        res = thosvd(x, (3, 3, 2), methods="auto")
        for u in res.tucker.factors:
            np.testing.assert_allclose(np.asarray(u.T @ u),
                                       np.eye(u.shape[1]), atol=2e-3)
        assert float(res.tucker.rel_error(x)) < 0.12


class TestHOOI:
    def test_refines_sthosvd(self):
        """HOOI error ≤ its st-HOSVD init error (monotone refinement)."""
        x = lowrank((14, 12, 10), (3, 3, 3), noise=0.3)
        init = sthosvd_eig(x, (3, 3, 3))
        e0 = float(init.tucker.rel_error(x))
        res = hooi(x, (3, 3, 3), n_iters=2, methods="eig", init=init)
        e1 = float(res.tucker.rel_error(x))
        assert e1 <= e0 + 1e-5

    def test_exact_recovery(self):
        x = lowrank((10, 9, 8), (2, 3, 2))
        res = hooi(x, (2, 3, 2), n_iters=1, methods="eig")
        assert float(res.tucker.rel_error(x)) < 1e-4

    def test_auto_selector_runs(self):
        x = lowrank((10, 9, 8), (2, 3, 2), noise=0.05)
        res = hooi(x, (2, 3, 2), n_iters=1, methods="auto")
        assert float(res.tucker.rel_error(x)) < 0.12
        assert len(res.trace) == 3 + 3     # init sweep + 1 HOOI sweep


class TestImplAndTrace:
    """impl= must reach the solvers, and traces must carry real wall-clock."""

    @pytest.mark.parametrize("fn,kw", [
        (thosvd, {}),
        (hooi, {"n_iters": 1}),
    ])
    def test_explicit_impl_parity(self, fn, kw):
        x = lowrank((9, 10, 8), (3, 3, 3), noise=0.02)
        a = fn(x, (3, 3, 3), methods="eig", impl="matfree", **kw)
        b = fn(x, (3, 3, 3), methods="eig", impl="explicit", **kw)
        np.testing.assert_allclose(float(a.tucker.rel_error(x)),
                                   float(b.tucker.rel_error(x)), atol=1e-5)

    @pytest.mark.parametrize("fn,kw", [
        (thosvd, {}),
        (hooi, {"n_iters": 1}),
    ])
    def test_trace_records_real_seconds(self, fn, kw):
        x = lowrank((12, 10, 8), (3, 3, 2), noise=0.05)
        res = fn(x, (3, 3, 2), methods="eig", block_until_ready=True, **kw)
        assert all(t.seconds >= 0.0 for t in res.trace)
        assert any(t.seconds > 0.0 for t in res.trace)

    def test_impl_rejects_unknown(self):
        x = lowrank((6, 6, 6), (2, 2, 2))
        with pytest.raises(ValueError):
            thosvd(x, (2, 2, 2), methods="eig", impl="bogus")
