"""t-HOSVD and HOOI variants (paper §II-B / future-work §VIII)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sthosvd_eig, tensor_ops as T
from repro.core.variants import hooi, thosvd


def lowrank(dims, ranks, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    if noise:
        rms = float(jnp.sqrt(jnp.mean(x ** 2)))
        x = x + noise * rms * jnp.asarray(rng.standard_normal(dims), jnp.float32)
    return x


class TestTHOSVD:
    @pytest.mark.parametrize("methods", ["eig", "als"])
    def test_exact_recovery(self, methods):
        x = lowrank((12, 10, 8), (3, 3, 2))
        res = thosvd(x, (3, 3, 2), methods=methods)
        assert float(res.tucker.rel_error(x)) < 1e-4

    def test_orthonormal_and_auto(self):
        x = lowrank((12, 10, 8), (3, 3, 2), noise=0.05)
        res = thosvd(x, (3, 3, 2), methods="auto")
        for u in res.tucker.factors:
            np.testing.assert_allclose(np.asarray(u.T @ u),
                                       np.eye(u.shape[1]), atol=2e-3)
        assert float(res.tucker.rel_error(x)) < 0.12


class TestHOOI:
    def test_refines_sthosvd(self):
        """HOOI error ≤ its st-HOSVD init error (monotone refinement)."""
        x = lowrank((14, 12, 10), (3, 3, 3), noise=0.3)
        init = sthosvd_eig(x, (3, 3, 3))
        e0 = float(init.tucker.rel_error(x))
        res = hooi(x, (3, 3, 3), n_iters=2, methods="eig", init=init)
        e1 = float(res.tucker.rel_error(x))
        assert e1 <= e0 + 1e-5

    def test_exact_recovery(self):
        x = lowrank((10, 9, 8), (2, 3, 2))
        res = hooi(x, (2, 3, 2), n_iters=1, methods="eig")
        assert float(res.tucker.rel_error(x)) < 1e-4

    def test_auto_selector_runs(self):
        x = lowrank((10, 9, 8), (2, 3, 2), noise=0.05)
        res = hooi(x, (2, 3, 2), n_iters=1, methods="auto")
        assert float(res.tucker.rel_error(x)) < 0.12
        assert len(res.trace) == 3 + 3     # init sweep + 1 HOOI sweep
