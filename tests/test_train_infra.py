"""Trainer infrastructure: optimizer, microbatching, checkpoint, data, serve."""

import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, make_source
from repro.models import build
from repro.models.config import ShapeConfig
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.optim.grad_compress import CompressionConfig
from repro.train.train_step import _accumulate_grads, init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

RNG = np.random.default_rng(0)


class TestAdamW:
    def test_matches_numpy_reference(self):
        opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    clip_norm=None)
        p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
        g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
        st = opt.init(p)
        p1, st, _ = opt.update(g, st, p)
        gn = np.asarray(g["w"])
        m = 0.1 * gn
        v = 0.01 * gn * gn
        mh, vh = m / 0.1, v / 0.01
        want = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)

    def test_weight_decay_skips_1d(self):
        opt = AdamW(lr=0.1, weight_decay=0.5, clip_norm=None)
        p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        st = opt.init(p)
        p1, _, _ = opt.update(g, st, p)
        assert float(jnp.abs(p1["b"] - 1.0).max()) < 1e-6       # no decay
        assert float(p1["w"].max()) < 1.0                        # decayed

    def test_clipping(self):
        opt = AdamW(lr=0.0, clip_norm=1.0)
        g = {"w": jnp.full((10,), 100.0)}
        st = opt.init(g)
        _, _, m = opt.update(g, st, {"w": jnp.zeros((10,))})
        assert m["grad_norm"] > 100

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
        assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
        assert float(lr(jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
        assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


class TestMicrobatching:
    def test_grad_accumulation_equivalence(self):
        cfg = configs.get_smoke("phi3_mini_3p8b").with_(n_layers=2, remat=False)
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (8, 17)), jnp.int32)}
        g1, m1 = _accumulate_grads(bundle.loss, params, batch, 1)
        g4, m4 = _accumulate_grads(bundle.loss, params, batch, 4)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(g1), jax.tree.leaves(g4)))
        assert err < 1e-4


class TestCheckpointer:
    def test_roundtrip_and_gc(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        for step in (5, 10, 15):
            ck.save(step, jax.tree.map(lambda x: x + step, tree), blocking=True)
        assert ck.all_steps() == [10, 15]       # keep=2 gc'd step 5
        restored, step = ck.restore(tree)
        assert step == 15
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.asarray(tree["a"]) + 15)
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_ignores_uncommitted_tmp(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(3, {"x": jnp.ones(3)}, blocking=True)
        (tmp_path / "step_000000009.tmp").mkdir()   # simulated crash
        assert ck.latest_step() == 3

    def test_tucker_compressed_tier(self, tmp_path):
        ck = Checkpointer(tmp_path)
        comp = CompressionConfig(rank_fraction=0.5, min_size=10, min_ndim=3,
                                 skip_first_mode=False)
        rng = np.random.default_rng(1)
        core = rng.standard_normal((3, 3, 3))
        us = [np.linalg.qr(rng.standard_normal((12, 3)))[0] for _ in range(3)]
        w = jnp.asarray(np.einsum("abc,ia,jb,kc->ijk", core, *us), jnp.float32)
        tree = {"w": w, "small": jnp.ones((4,))}
        ck.save(1, tree, compress_cfg=comp, blocking=True)
        meta = json.loads((tmp_path / "step_000000001" / "meta.json").read_text())
        kinds = {l["kind"] for l in meta["leaves"]}
        assert kinds == {"tucker", "raw"}
        restored, _ = ck.restore(tree, step=1)
        err = float(jnp.linalg.norm(restored["w"] - w) / jnp.linalg.norm(w))
        assert err < 1e-4                        # exactly low-rank → lossless


class TestData:
    def test_deterministic_and_resumable(self):
        cfg = configs.get_smoke("phi3_mini_3p8b")
        shape = ShapeConfig("t", 16, 4, "train")
        a = make_source(DataConfig(seed=3), cfg, shape)
        b = make_source(DataConfig(seed=3), cfg, shape)
        np.testing.assert_array_equal(np.asarray(a.batch_at(7)["tokens"]),
                                      np.asarray(b.batch_at(7)["tokens"]))
        assert not np.array_equal(np.asarray(a.batch_at(7)["tokens"]),
                                  np.asarray(a.batch_at(8)["tokens"]))

    def test_elastic_reslice(self):
        """A different shard count re-derives slices of the SAME global batch."""
        cfg = configs.get_smoke("phi3_mini_3p8b")
        shape = ShapeConfig("t", 16, 8, "train")
        src = make_source(DataConfig(seed=0), cfg, shape)
        g = np.asarray(src.batch_at(3)["tokens"])
        for n_shards in (2, 4):
            per = 8 // n_shards
            slices = [g[i * per:(i + 1) * per] for i in range(n_shards)]
            np.testing.assert_array_equal(np.concatenate(slices), g)


class TestTrainerLoop:
    def test_loss_decreases_and_resumes(self, tmp_path):
        cfg = configs.get_smoke("phi3_mini_3p8b").with_(n_layers=2, remat=False)
        bundle = build(cfg)
        shape = ShapeConfig("t", 32, 8, "train")
        src = make_source(DataConfig(seed=0), cfg, shape)
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        step = make_train_step(bundle, opt)
        tc = TrainerConfig(total_steps=20, ckpt_every=10, log_every=5,
                           ckpt_dir=str(tmp_path))
        tr = Trainer(tc, step, init_state(bundle, opt, jax.random.PRNGKey(0)), src)
        hist = tr.run()
        assert hist[-1]["loss"] < hist[0]["loss"]

        # resume continues from 20 (restored), runs to 25
        tc2 = TrainerConfig(total_steps=25, ckpt_every=10, log_every=5,
                            ckpt_dir=str(tmp_path))
        tr2 = Trainer(tc2, step, init_state(bundle, opt, jax.random.PRNGKey(0)), src)
        tr2.run()
        assert int(np.asarray(tr2.state.step)) == 25


class TestServeEngine:
    def test_batched_requests_complete(self):
        from repro.serve.engine import Request, ServeEngine
        cfg = configs.get_smoke("phi3_mini_3p8b").with_(n_layers=2)
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        eng = ServeEngine(bundle, params, batch_slots=2, max_len=48)
        reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=6, rid=i)
                for i in range(5)]
        outs = eng.run(reqs)
        assert all(len(r.output) >= 6 for r in outs)
        assert all(r.done for r in outs)

    def test_engine_matches_manual_decode(self):
        from repro.serve.engine import Request, ServeEngine
        cfg = configs.get_smoke("phi3_mini_3p8b").with_(n_layers=2)
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        prompt = [5, 7, 9, 11]
        eng = ServeEngine(bundle, params, batch_slots=1, max_len=32)
        out = eng.run([Request(prompt=prompt, max_new_tokens=5)])[0].output

        # manual greedy decode
        cache = bundle.init_cache(1, 32)
        lg, cache = jax.jit(bundle.prefill)(
            params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache)
        toks = [int(jnp.argmax(lg[0, -1]))]
        pos = len(prompt)
        step = jax.jit(lambda p, tok, c, q: bundle.decode(p, tok, c, q, 32))
        for _ in range(4):
            lg, cache = step(params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
                             jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(lg[0, 0])))
            pos += 1
        assert out == toks

    def test_temperature_sampling(self):
        """temperature=0 stays greedy and key-free; temperature>0 samples
        categorically per slot (mixed-temperature batches supported)."""
        from repro.serve.engine import ServeEngine
        cfg = configs.get_smoke("phi3_mini_3p8b").with_(n_layers=2)
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        eng = ServeEngine(bundle, params, batch_slots=2, max_len=32)

        logits = jnp.zeros((2, cfg.vocab)).at[:, 7].set(5.0)
        key_before = np.asarray(eng.key).copy()
        out = eng._sample(logits, np.array([0.0, 0.0]))
        assert list(out) == [7, 7]                       # greedy
        np.testing.assert_array_equal(np.asarray(eng.key), key_before)

        # near-uniform logits at high temperature: repeated draws must vary,
        # while the temperature-0 row stays pinned to the argmax
        seen = set()
        for _ in range(20):
            out = eng._sample(logits, np.array([0.0, 8.0]))
            assert out[0] == 7
            seen.add(int(out[1]))
        assert len(seen) > 1                             # actually sampling
        assert not np.array_equal(np.asarray(eng.key), key_before)
