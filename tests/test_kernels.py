"""Per-kernel allclose sweeps vs the ref.py oracle (interpret mode on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import matmul, ops, ref

RNG = np.random.default_rng(0)


def arr(shape, dtype=jnp.float32, seed=None):
    r = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(r.standard_normal(shape), dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=4e-2, atol=4e-2)}


class TestMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                       (128, 256, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_aligned(self, m, k, n, dtype):
        a, b = arr((m, k), dtype), arr((k, n), dtype)
        got = matmul(a, b, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.matmul_ref(a, b)), **TOL[dtype])

    def test_block_shapes(self):
        a, b = arr((256, 256)), arr((256, 256))
        want = ref.matmul_ref(a, b)
        for bm, bn, bk in [(128, 128, 128), (64, 128, 256), (8, 128, 64)]:
            got = matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4)


class TestTTMOp:
    # sweep: non-divisible shapes exercise the padding path; every mode
    # position exercises a different kernel (first/last = GEMM, interior =
    # batched) — the paper's Fig. 4 structure
    @pytest.mark.parametrize("shape,mode,r", [
        ((5, 37, 19), 1, 7), ((33, 12, 50), 0, 9), ((13, 21, 40), 2, 5),
        ((4, 9, 11, 6), 2, 3), ((130, 140, 3), 0, 64), ((3, 200, 129), 1, 130),
        ((260, 7, 5), 0, 11), ((2, 3, 4, 5, 6), 2, 2),
    ])
    def test_vs_oracle(self, shape, mode, r):
        x = arr(shape, seed=1)
        u = arr((r, shape[mode]), seed=2)
        got = ops.ttm(x, u, mode)
        want = ref.ttm_full_ref(x, u, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = arr((8, 40, 24), dtype, seed=3)
        u = arr((6, 40), dtype, seed=4)
        got = ops.ttm(x, u, 1)
        want = ref.ttm_full_ref(x, u, 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL[dtype])


class TestGramTTTOps:
    @pytest.mark.parametrize("shape,mode", [
        ((5, 37, 19), 1), ((33, 12, 50), 0), ((13, 21, 40), 2),
        ((4, 9, 11, 6), 3), ((129, 6, 7), 0),
    ])
    def test_gram_vs_oracle(self, shape, mode):
        x = arr(shape, seed=5)
        got = ops.gram(x, mode)
        want = ref.gram_full_ref(x, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("shape,mode,r", [
        ((5, 37, 19), 1, 7), ((13, 21, 40), 2, 5), ((9, 8, 7), 0, 3),
    ])
    def test_ttt_vs_oracle(self, shape, mode, r):
        x = arr(shape, seed=6)
        yshape = shape[:mode] + (r,) + shape[mode + 1:]
        y = arr(yshape, seed=7)
        got = ops.ttt(x, y, mode)
        a = int(np.prod(shape[:mode])) if mode else 1
        x3 = x.reshape(a, shape[mode], -1)
        y3 = y.reshape(a, r, -1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref.ttt_ref(x3, y3)),
                                   rtol=3e-4, atol=3e-4)


class TestKernelInSolver:
    def test_sthosvd_with_pallas_gram(self):
        """The kernel path plugs into the real algorithm: one EIG mode solve
        computed with the Pallas Gram matches the jnp path."""
        from repro.core import tensor_ops as T
        x = arr((24, 30, 16), seed=8)
        s_pallas = ops.gram(x, 1)
        s_jnp = T.gram(x, 1)
        np.testing.assert_allclose(np.asarray(s_pallas), np.asarray(s_jnp),
                                   rtol=3e-4, atol=3e-4)
        wp = np.linalg.eigh(np.asarray(s_pallas))[1][:, -4:]
        wj = np.linalg.eigh(np.asarray(s_jnp))[1][:, -4:]
        np.testing.assert_allclose(wp @ wp.T, wj @ wj.T, atol=1e-3)


class TestS6ScanKernel:
    """Fused S6 selective-scan kernel vs the chunked-jnp oracle."""

    @pytest.mark.parametrize("shape,bd,bt", [
        ((2, 128, 64, 8), 32, 16),
        ((1, 64, 32, 4), 32, 64),
        ((3, 96, 16, 16), 16, 32),
    ])
    def test_vs_oracle(self, shape, bd, bt):
        from repro.kernels.s6_scan import s6_scan_fwd
        from repro.models.ssm import _s6_scan
        B, T, Di, N = shape
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((B, T, Di)), jnp.float32)
        dt = jnp.abs(jnp.asarray(r.standard_normal((B, T, Di)), jnp.float32)) * 0.1
        bm = jnp.asarray(r.standard_normal((B, T, N)), jnp.float32)
        cm = jnp.asarray(r.standard_normal((B, T, N)), jnp.float32)
        a = -jnp.abs(jnp.asarray(r.standard_normal((Di, N)), jnp.float32))
        y_ref, _ = _s6_scan(x, dt, bm, cm, a, chunk=32)
        y = s6_scan_fwd(x, dt, bm, cm, a, bd=bd, bt=bt)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4)
