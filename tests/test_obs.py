"""Observability layer: span bus, exporters, metrics registry, drift.

The load-bearing assertions: (1) one served slice captured with tracing
on yields a single Chrome trace tying the serve lifecycle (submit → wave
→ done) to the core spans underneath it (plan, compile, per-mode solves
with solver/backend/rank attrs); (2) a deliberately mis-calibrated
CostModel is flagged STALE by the drift monitor with a ``repro.tune``
repair recommendation; (3) the serve TraceWriter raises after ``close()``
instead of silently reopening its file.
"""

import json
import threading
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import TuckerConfig
from repro.core.api import _SWEEP_CACHE, plan as make_plan
from repro.core.cost_model import CostModel
from repro.obs import drift as drift_mod
from repro.obs import export as export_mod
from repro.obs import metrics as obs_metrics
from repro.obs.__main__ import main as obs_cli
from repro.obs.drift import DriftMonitor, MemoryWatch
from repro.serve import BucketPolicy, TuckerService
from repro.serve.metrics import LatencyWindow, TraceWriter

SHAPE = (16, 18, 20)
RANKS = (4, 4, 4)


@pytest.fixture(autouse=True)
def _obs_disabled_after():
    """Tracing must never leak into other test modules."""
    yield
    obs.disable()


def _x(shape=SHAPE, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# span bus
# ---------------------------------------------------------------------------

class TestTraceBus:
    def test_disabled_is_default_and_free(self):
        assert not obs.enabled()
        buf = obs.EventBuffer()
        obs.add_sink(buf)
        try:
            obs.event("cache", status="hit")
            with obs.span("execute", backend="matfree"):
                pass
            assert len(buf) == 0
        finally:
            obs.remove_sink(buf)

    def test_event_shape_and_span_nesting(self):
        with obs.capture() as buf:
            with obs.span("outer", a=1) as sp:
                obs.event("cache", status="miss")
                with obs.span("inner"):
                    pass
                sp.set(late=True)
        evs = buf.events()
        kinds = [(e["kind"], e.get("name")) for e in evs]
        # inner span exits first, point event lands before both
        assert kinds == [("cache", None), ("span", "inner"),
                         ("span", "outer")]
        cache, inner, outer = evs
        for e in evs:
            assert {"t", "kind", "pid", "tid"} <= e.keys()
        assert cache["parent"] == outer["span"]
        assert inner["parent"] == outer["span"]
        assert outer["parent"] is None
        assert outer["late"] is True and outer["a"] == 1
        assert outer["dur_s"] >= inner["dur_s"] >= 0.0

    def test_span_records_exception_and_unwinds(self):
        with obs.capture() as buf:
            with pytest.raises(RuntimeError):
                with obs.span("boom"):
                    raise RuntimeError("solver exploded")
            with obs.span("after"):
                pass
        boom, after = buf.events()
        assert "solver exploded" in boom["error"]
        assert after["parent"] is None  # contextvar fully unwound

    def test_capture_restores_enabled_state(self):
        assert not obs.enabled()
        with obs.capture():
            assert obs.enabled()
            with obs.capture():    # nested: inner exit must not disable
                pass
            assert obs.enabled()
        assert not obs.enabled()

    def test_broken_sink_warns_and_event_survives(self):
        def bad(evt):
            raise RuntimeError("sink down")
        with obs.capture() as buf:
            obs.add_sink(bad)
            try:
                with pytest.warns(RuntimeWarning, match="sink"):
                    obs.event("submit", rid=1)
            finally:
                obs.remove_sink(bad)
        assert [e["kind"] for e in buf.events()] == ["submit"]

    def test_event_buffer_is_a_ring(self):
        buf = obs.EventBuffer(maxlen=3)
        for i in range(5):
            buf({"kind": "e", "i": i})
        assert [e["i"] for e in buf.events()] == [2, 3, 4]
        buf.clear()
        assert len(buf) == 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

class TestExport:
    EVENTS = [
        {"t": 10.0, "kind": "span", "name": "solve", "dur_s": 0.5,
         "span": 1, "parent": None, "pid": 7, "tid": 9, "mode": 0,
         "solver": "eig"},
        {"t": 12.0, "kind": "wave", "wall_s": 2.0, "bucket": "16x16x16",
         "n": 4},
        {"t": 13.0, "kind": "submit", "rid": 3},
    ]

    def test_to_chrome_phases(self):
        doc = export_mod.to_chrome(self.EVENTS)
        assert doc["displayTimeUnit"] == "ms"
        sp, wave, sub = doc["traceEvents"]
        assert sp == {"name": "solve", "cat": "atucker", "ph": "X",
                      "ts": 10.0e6, "dur": 0.5e6, "pid": 7, "tid": 9,
                      "args": {"span": 1, "parent": None, "mode": 0,
                               "solver": "eig"}}
        # wave slices are rewound by wall_s so they sit where work ran
        assert wave["ph"] == "X" and wave["ts"] == 10.0e6 \
            and wave["dur"] == 2.0e6 and wave["name"] == "wave 16x16x16"
        assert sub["ph"] == "i" and sub["cat"] == "serve"

    def test_jsonl_round_trip_with_repr_fallback(self, tmp_path):
        events = [*self.EVENTS,
                  {"t": 14.0, "kind": "done", "shape": (16, 16)}]
        path = tmp_path / "ev.jsonl"
        assert export_mod.write_jsonl(events, path) == 4
        path.write_text(path.read_text() + "not json\n\n")
        back = export_mod.read_jsonl(path)
        assert len(back) == 4  # malformed + blank lines skipped
        assert back[0]["name"] == "solve"
        assert back[3]["shape"] == [16, 16] or \
            isinstance(back[3]["shape"], str)

    def test_chrome_args_jsonable(self):
        doc = export_mod.to_chrome(
            [{"t": 1.0, "kind": "span", "name": "s", "dur_s": 0.1,
              "weird": object()}])
        json.dumps(doc)  # must not raise


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_histogram_render(self):
        reg = obs_metrics.MetricsRegistry()
        c = reg.counter("atucker_requests_total", "requests")
        c.inc(service="t")
        c.inc(2, service="t")
        with pytest.raises(ValueError):
            c.inc(-1, service="t")
        g = reg.gauge("atucker_queue_depth")
        g.set(5, bucket="a")
        g.inc(bucket="a")
        h = reg.histogram("atucker_latency_s", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v, arm="svc")
        text = reg.render()
        assert "# TYPE atucker_requests_total counter" in text
        assert 'atucker_requests_total{service="t"} 3' in text
        assert 'atucker_queue_depth{bucket="a"} 6' in text
        assert '# TYPE atucker_latency_s histogram' in text
        assert 'atucker_latency_s_bucket{arm="svc",le="0.1"} 1' in text
        assert 'atucker_latency_s_bucket{arm="svc",le="1"} 2' in text
        assert 'atucker_latency_s_bucket{arm="svc",le="+Inf"} 3' in text
        assert 'atucker_latency_s_count{arm="svc"} 3' in text

    def test_registry_idempotent_and_type_guarded(self):
        reg = obs_metrics.MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        with pytest.raises(ValueError):
            reg.gauge("a")

    def test_quantile_from_histogram(self):
        reg = obs_metrics.MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 1.6, 3.0):
            h.observe(v)
        q = obs_metrics.quantile_from_histogram(h, 50.0)
        assert 1.0 <= q <= 2.0

    def test_absorb_service_stats(self):
        svc = TuckerService(policy=BucketPolicy(grid=8, wave_slots=2))
        cfg = TuckerConfig(ranks=RANKS, methods="eig")
        svc.submit(_x(), cfg)
        svc.drain()
        stats = svc.stats()
        svc.stop()
        reg = obs_metrics.MetricsRegistry()
        obs_metrics.absorb_service_stats(stats, reg)
        text = reg.render()
        assert 'atucker_serve_submitted{service="tucker"} 1' in text
        assert "atucker_serve_latency_ms" in text
        assert "atucker_bucket_completed" in text


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

class TestDrift:
    def test_centered_cell_is_not_stale(self):
        m = DriftMonitor(min_samples=5)
        rng = np.random.default_rng(0)
        for _ in range(50):
            actual = 0.01 * float(np.exp(rng.normal(0.0, 0.05)))
            m.observe(platform="cpu", backend="matfree", solver="eig",
                      predicted_s=0.01, actual_s=actual)
        rep = m.report()
        assert len(rep["cells"]) == 1
        assert not rep["cells"][0]["stale"]
        assert rep["recommendations"] == []

    def test_consistent_drift_is_stale_with_tune_recommendation(self):
        m = DriftMonitor(min_samples=5)
        rng = np.random.default_rng(1)
        for _ in range(20):   # ~3x slower than predicted, modest noise
            actual = 0.03 * float(np.exp(rng.normal(0.0, 0.1)))
            m.observe(platform="cpu", backend="matfree", solver="eig",
                      predicted_s=0.01, actual_s=actual)
        rep = m.report()
        (cell,) = rep["cells"]
        assert cell["stale"] and cell["ratio"] == pytest.approx(3.0, rel=0.3)
        cmds = [r["command"] for r in rep["recommendations"]]
        assert any("repro.tune calibrate --platform cpu "
                   "--backend matfree" in c for c in cmds)
        assert any("repro.tune train" in c for c in cmds)

    def test_small_consistent_bias_tolerated(self):
        # hugely significant z but inside the tolerance band: not stale
        m = DriftMonitor(min_samples=5, tolerance=1.5)
        rng = np.random.default_rng(2)
        for _ in range(100):
            actual = 0.012 * float(np.exp(rng.normal(0.0, 0.01)))
            m.observe(platform="cpu", backend="matfree", solver="eig",
                      predicted_s=0.01, actual_s=actual)
        (cell,) = m.report()["cells"]
        assert abs(cell["z"]) > m.z_threshold
        assert not cell["stale"]

    def test_nonpositive_pairs_ignored_and_z_clamped(self):
        m = DriftMonitor()
        m.observe(platform="cpu", backend="matfree", solver="eig",
                  predicted_s=0.0, actual_s=1.0)
        m.observe(platform="cpu", backend="matfree", solver="eig",
                  predicted_s=1.0, actual_s=0.0)
        assert m.report()["cells"] == []
        for _ in range(10):  # identical ratios: zero variance, clamped z
            m.observe(platform="cpu", backend="matfree", solver="eig",
                      predicted_s=0.01, actual_s=0.1)
        (cell,) = m.report()["cells"]
        assert cell["z"] == 99.0 and cell["stale"]

    def test_observe_traces_skips_fused_steps(self):
        class T:
            def __init__(self, s):
                self.method, self.predicted_s, self.seconds = "eig", 0.01, s
        m = DriftMonitor()
        n = m.observe_traces([T(0.02), T(0.0)], platform="cpu",
                             backend="matfree")
        assert n == 1

    def test_memory_drift_recommendation(self):
        m = DriftMonitor(tolerance=1.5)
        m.observe_memory(backend="matfree", modeled_bytes=100,
                         observed_bytes=400)
        rep = m.report()
        assert rep["memory"]["matfree"]["ratio"] == pytest.approx(4.0)
        assert any(r["cell"][0] == "memory"
                   for r in rep["recommendations"])

    def test_summary_shape(self):
        m = DriftMonitor()
        m.observe(platform="cpu", backend="matfree", solver="eig",
                  predicted_s=0.01, actual_s=0.02)
        s = m.summary()
        assert s["cells"] == 1 and s["observations"] == 1
        assert s["stale"] == []

    def test_memory_watch_sees_allocations(self):
        with MemoryWatch(interval_s=0.001) as mw:
            arrs = [jnp.zeros((128, 128), jnp.float32) for _ in range(4)]
            jax.block_until_ready(arrs[-1])
            time.sleep(0.05)
        assert mw.high_water >= 4 * 128 * 128 * 4


class TestMiscalibratedCostModel:
    def test_execute_flags_bogus_calibration(self):
        """A deliberately absurd calibrated CostModel (1 second per FLOP)
        stamps absurd predicted_s on the plan; a handful of recorded
        executes must flag the (platform, backend, eig) cell stale and
        recommend a repro.tune recalibration."""
        class BogusSelector:
            cost_model = CostModel(eig_scale=1.0, source="calibrated")

        drift_mod.MONITOR.reset()
        try:
            cfg = TuckerConfig(ranks=RANKS, methods="eig")
            p = make_plan(SHAPE, jnp.float32, cfg,
                          selector=BogusSelector())
            assert p.total_predicted_s > 1e3   # absurd by construction
            x = _x()
            for _ in range(drift_mod.MONITOR.min_samples):
                p.execute(x, record=True)
            rep = drift_mod.MONITOR.report()
            platform = jax.default_backend()
            stale = {(c["platform"], c["backend"], c["solver"])
                     for c in rep["stale"]}
            assert (platform, "matfree", "eig") in stale
            assert any("repro.tune calibrate" in r["command"]
                       for r in rep["recommendations"])
            (cell,) = [c for c in rep["cells"]
                       if c["solver"] == "eig"]
            assert cell["ratio"] < 1e-3   # wildly over-predicted
            assert cell["sources"].get("execute", 0) >= \
                drift_mod.MONITOR.min_samples
        finally:
            drift_mod.MONITOR.reset()


# ---------------------------------------------------------------------------
# core instrumentation
# ---------------------------------------------------------------------------

class TestCoreSpans:
    def test_plan_and_execute_spans(self):
        cfg = TuckerConfig(ranks=RANKS, methods="eig")
        x = _x()
        with obs.capture() as buf:
            _SWEEP_CACHE.clear()
            p = make_plan(SHAPE, jnp.float32, cfg)
            p.execute(x)
            p.execute(x)
        spans = {e["name"]: e for e in obs.iter_spans(buf.events())}
        assert {"plan", "compile", "execute"} <= spans.keys()
        assert spans["plan"]["n_steps"] == 3
        assert spans["plan"]["backend"] == "matfree"
        assert spans["execute"]["shape"] == list(SHAPE)
        cache = [e for e in buf.events() if e["kind"] == "cache"]
        assert [e["status"] for e in cache] == ["miss"]

    def test_recorded_execute_emits_solve_spans_with_attrs(self):
        cfg = TuckerConfig(ranks=RANKS, methods="eig")
        p = make_plan(SHAPE, jnp.float32, cfg)
        with obs.capture() as buf:
            p.execute(_x(), record=True)
        solves = [e for e in obs.iter_spans(buf.events())
                  if e["name"] == "solve"]
        assert [e["mode"] for e in solves] == [0, 1, 2]
        for e in solves:
            assert e["solver"] == "eig" and e["backend"] == "matfree"
            assert e["rank"] == 4 and e["dur_s"] > 0.0
            assert e["platform"] == jax.default_backend()

    def test_adaptive_execute_emits_sketch_spans(self):
        cfg = TuckerConfig(error_target=0.5)
        p = make_plan(SHAPE, jnp.float32, cfg)
        with obs.capture() as buf:
            p.execute(_x())
        sketches = [e for e in obs.iter_spans(buf.events())
                    if e["name"] == "sketch"]
        assert len(sketches) == 3
        for e in sketches:
            assert e["solver"] == "rand" and e["rank"] >= 1
            assert 0.0 <= e["tail_err"] <= 1.0


# ---------------------------------------------------------------------------
# serve: TraceWriter, LatencyWindow, service wiring
# ---------------------------------------------------------------------------

class TestTraceWriter:
    def test_event_after_close_raises(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        w = TraceWriter(path)
        w.event("submit", rid=1)
        w.close()
        with pytest.raises(ValueError, match="closed"):
            w.event("submit", rid=2)
        # the file did NOT silently reopen/grow
        lines = path.read_text().splitlines()
        assert len(lines) == 1
        w.close()  # idempotent

    def test_close_before_first_event_raises_without_creating_file(
            self, tmp_path):
        path = tmp_path / "never.jsonl"
        w = TraceWriter(path)
        w.close()
        with pytest.raises(ValueError):
            w.event("submit")
        assert not path.exists()

    def test_handle_as_bus_sink(self, tmp_path):
        path = tmp_path / "bus.jsonl"
        w = TraceWriter(path)
        obs.add_sink(w.handle)
        try:
            obs.enable()
            with obs.span("execute", backend="matfree"):
                obs.event("cache", status="miss")
        finally:
            obs.disable()
            obs.remove_sink(w.handle)
            w.close()
        evs = export_mod.read_jsonl(path)
        assert [e["kind"] for e in evs] == ["cache", "span"]
        assert evs[1]["name"] == "execute"


class TestLatencyWindow:
    def test_snapshot_percentiles_and_window_mean(self):
        w = LatencyWindow(maxlen=4)
        for s in (0.010, 0.020, 0.030, 0.040, 0.100):
            w.add(s)           # 0.010 evicted from the window
        snap = w.snapshot_ms()
        assert snap["p50_ms"] == pytest.approx(35.0)
        assert snap["p95_ms"] == pytest.approx(91.0)
        # lifetime mean over all 5; window mean over the surviving 4
        assert snap["mean_ms"] == pytest.approx(40.0)
        assert snap["window_mean_ms"] == pytest.approx(47.5)
        assert w.percentile(50.0) == pytest.approx(0.035)

    def test_empty_window(self):
        snap = LatencyWindow().snapshot_ms()
        assert snap == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                        "mean_ms": 0.0, "window_mean_ms": 0.0}


class TestServiceObservability:
    def test_stats_exposes_sweep_cache_and_drift(self):
        svc = TuckerService()
        try:
            stats = svc.stats()
            assert {"builds", "hits"} <= stats["sweep_cache"].keys()
            assert {"cells", "observations", "stale"} \
                <= stats["drift"].keys()
        finally:
            svc.stop()

    def test_serve_slice_yields_one_perfetto_trace(self, tmp_path):
        """One traced serve slice ties the whole story together: submit →
        wave → done around plan/compile/execute, with per-mode solve spans
        from a recorded wave — all in a single loadable Chrome trace."""
        cfg = TuckerConfig(ranks=RANKS, methods="eig")
        policy = BucketPolicy(grid=8, wave_slots=2, pad_mode="mask")
        with obs.capture() as buf:
            _SWEEP_CACHE.clear()
            for record in (False, True):
                with TuckerService(policy=policy, record=record) as svc:
                    for seed in range(2):
                        svc.submit(_x(seed=seed), cfg)
                    svc.drain()
        path = tmp_path / "trace.json"
        doc = export_mod.write_chrome(buf.events(), path)
        names = {e["name"].split(" ")[0] for e in doc["traceEvents"]}
        assert {"submit", "wave", "solve", "compile", "plan",
                "execute", "done"} <= names
        json.loads(path.read_text())   # loadable
        solves = [e for e in doc["traceEvents"] if e["name"] == "solve"]
        assert all(e["args"]["solver"] == "eig" and "rank" in e["args"]
                   for e in solves)

    def test_wave_drift_attribution_from_fused_serve(self):
        """Un-recorded waves amortize wave wall-clock over their jobs and
        feed the drift monitor with source="serve" when plans carry a
        calibrated prediction."""
        class BogusSelector:
            cost_model = CostModel(eig_scale=1.0, source="calibrated")

        drift_mod.MONITOR.reset()
        try:
            cfg = TuckerConfig(ranks=RANKS, methods="eig")
            with TuckerService(selector=BogusSelector(),
                               policy=BucketPolicy(grid=8,
                                                   wave_slots=2)) as svc:
                for seed in range(3):
                    svc.submit(_x(seed=seed), cfg)
                svc.drain()
            cells = drift_mod.MONITOR.cells()
            assert cells, "fused serve waves fed no drift observations"
            cell = next(iter(cells.values()))
            assert cell.sources.get("serve", 0) > 0
        finally:
            drift_mod.MONITOR.reset()

    def test_concurrent_submit_and_stats(self):
        """Hammer submit() and stats() from threads: no torn reads, no
        exceptions, and the final counters balance exactly."""
        cfg = TuckerConfig(ranks=RANKS, methods="eig")
        svc = TuckerService(policy=BucketPolicy(grid=8, wave_slots=4),
                            max_queue=None)
        svc.start()
        n_threads, per_thread = 4, 8
        errors = []
        snapshots = []
        stop = threading.Event()

        def submitter(tid):
            try:
                for i in range(per_thread):
                    svc.submit(_x(seed=tid * 100 + i), cfg)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        def reader():
            while not stop.is_set():
                s = svc.stats()
                c = s["counters"] if "counters" in s else s
                assert c["submitted"] >= c["requests"] >= 0
                assert c["failed"] == 0 and c["rejected"] == 0
                snapshots.append(c["submitted"])
                time.sleep(0.001)

        readers = [threading.Thread(target=reader) for _ in range(2)]
        writers = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        for th in readers + writers:
            th.start()
        for th in writers:
            th.join()
        svc.drain()
        stop.set()
        for th in readers:
            th.join()
        stats = svc.stats()
        svc.stop()
        assert not errors
        assert stats["submitted"] == n_threads * per_thread
        assert stats["requests"] == n_threads * per_thread
        assert stats["failed"] == 0
        # monotone non-decreasing submitted counter across reader snapshots
        assert all(a <= b for a, b in zip(snapshots, snapshots[1:]))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCli:
    def _events_file(self, tmp_path):
        events = [
            {"t": 1.0 + i, "kind": "span", "name": "solve", "dur_s": 0.03,
             "mode": i % 3, "solver": "eig", "backend": "matfree",
             "platform": "cpu", "predicted_s": 0.01}
            for i in range(6)
        ]
        events.append({"t": 9.0, "kind": "submit", "rid": 1})
        path = tmp_path / "events.jsonl"
        export_mod.write_jsonl(events, path)
        return path

    def test_report_from_events_json(self, tmp_path, capsys):
        path = self._events_file(tmp_path)
        assert obs_cli(["report", str(path), "--json"]) == 0
        out = capsys.readouterr().out
        rep = json.loads(out[out.index("{"):])
        (cell,) = rep["cells"]
        assert (cell["platform"], cell["backend"], cell["solver"]) == \
            ("cpu", "matfree", "eig")
        assert cell["n"] == 6 and cell["stale"]
        assert rep["recommendations"]

    def test_report_text_flags_stale(self, tmp_path, capsys):
        path = self._events_file(tmp_path)
        assert obs_cli(["report", str(path)]) == 0
        out = capsys.readouterr().out
        assert "STALE" in out and "repro.tune calibrate" in out

    def test_export_to_chrome(self, tmp_path, capsys):
        path = self._events_file(tmp_path)
        to = tmp_path / "trace.json"
        assert obs_cli(["export", str(path), "--to", str(to)]) == 0
        doc = json.loads(to.read_text())
        assert len(doc["traceEvents"]) == 7
        assert {e["ph"] for e in doc["traceEvents"]} == {"X", "i"}
