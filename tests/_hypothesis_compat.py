"""Fallback shim for ``hypothesis`` so the suite runs without the optional dep.

When hypothesis is installed (see requirements-dev.txt) the real library is
re-exported unchanged.  Otherwise ``@given(x=st.integers(a, b))`` degrades to
a deterministic ``pytest.mark.parametrize`` over a small sample of each
strategy's range (endpoints, midpoint, a fixed pseudo-random interior point)
— far weaker than property-based search, but it keeps every test executable
and meaningful as a smoke check.  Only the strategies this suite uses are
shimmed (``integers``, ``tuples``).
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare CI images
    import itertools

    import pytest

    HAVE_HYPOTHESIS = False
    _MAX_EXAMPLES = 12   # cap on parametrized cases per test

    class _IntegerStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self) -> list:
            span = self.hi - self.lo + 1
            vals = {self.lo, self.hi, (self.lo + self.hi) // 2,
                    self.lo + (7 * 2654435761) % span}
            return sorted(vals)

    class _TupleStrategy:
        def __init__(self, parts):
            self.parts = parts

        def sample(self) -> list:
            # zip component samples with offset cycling instead of taking the
            # full cartesian product — keeps the case count linear
            cols = [p.sample() for p in self.parts]
            n = max(len(c) for c in cols)
            return [tuple(c[(i + k) % len(c)] for k, c in enumerate(cols))
                    for i in range(n)]

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntegerStrategy:
            return _IntegerStrategy(min_value, max_value)

        @staticmethod
        def tuples(*parts) -> _TupleStrategy:
            return _TupleStrategy(parts)

    def given(**strategies):
        names = list(strategies)
        combos = list(itertools.product(
            *(s.sample() for s in strategies.values())))
        if len(combos) > _MAX_EXAMPLES:  # deterministic evenly-spaced subset
            stride = len(combos) / _MAX_EXAMPLES
            combos = [combos[int(i * stride)] for i in range(_MAX_EXAMPLES)]
        if len(names) == 1:  # parametrize expects scalars, not 1-tuples
            combos = [c[0] for c in combos]

        def deco(fn):
            return pytest.mark.parametrize(",".join(names), combos)(fn)

        return deco

    class settings:  # noqa: N801
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(name, **kwargs):
            pass

        @staticmethod
        def load_profile(name):
            pass
