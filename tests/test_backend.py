"""Ops-backend layer: registry semantics, three-way op parity
(pallas ≡ matfree ≡ explicit, interpret mode on CPU), plan-level routing,
and the dtype-aware peak_bytes model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OpsBackend,
    TuckerConfig,
    TuckerPlan,
    backend_names,
    get_backend,
    plan,
    register_backend,
    resolve_backend,
    sthosvd,
    tensor_ops as T,
)
from repro.core import api as api_mod
from repro.core.backend import unregister_backend
from repro.core.plan import ModeStep, resolve_schedule
from repro.core.solvers import svd_solve

BACKENDS = ("matfree", "explicit", "pallas")

TOL = {"float32": dict(rtol=3e-4, atol=3e-4),
       "bfloat16": dict(rtol=4e-2, atol=4e-2)}


def arr(shape, dtype=jnp.float32, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.standard_normal(shape), dtype)


def lowrank(dims, ranks, seed=0, noise=0.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
          for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    if noise:
        rms = float(jnp.sqrt(jnp.mean(x ** 2)))
        x = x + noise * rms * jnp.asarray(rng.standard_normal(dims), jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtins_registered(self):
        assert set(BACKENDS) <= set(backend_names())

    def test_get_backend_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown backend"):
            get_backend("cublas")

    def test_capability_metadata(self):
        assert get_backend("explicit").matricizes
        assert not get_backend("matfree").matricizes
        p = get_backend("pallas")
        assert p.tile_align == 128 and p.interpret_fallback
        assert not p.supports_dtype(jnp.float64)
        assert p.supports_dtype(jnp.bfloat16)

    def test_auto_resolution_per_platform(self):
        # explicit platform arg: deterministic regardless of test host
        assert resolve_backend("auto", platform="tpu").name == "pallas"
        assert resolve_backend("auto", platform="cpu").name == "matfree"
        assert resolve_backend("auto", platform="gpu").name == "matfree"
        # auto never picks a dtype the backend can't run
        assert resolve_backend("auto", platform="tpu",
                               dtype=jnp.float64).name == "matfree"

    def test_explicit_name_dtype_guard(self):
        with pytest.raises(ValueError, match="does not support dtype"):
            resolve_backend("pallas", dtype=jnp.float64)

    def test_register_custom_backend(self):
        calls = []

        def loud_ttm(x, u, mode):
            calls.append(mode)
            return T.ttm(x, u, mode)

        register_backend(OpsBackend(
            name="loud", loader=lambda: (loud_ttm, T.gram, T.ttt)))
        try:
            with pytest.raises(ValueError, match="already registered"):
                register_backend(OpsBackend(
                    name="loud", loader=lambda: (T.ttm, T.gram, T.ttt)))
            x = lowrank((8, 7, 6), (2, 2, 2))
            cfg = TuckerConfig(ranks=(2, 2, 2), methods="eig", impl="loud")
            p = plan(x.shape, x.dtype, cfg)
            assert p.backend == "loud"
            api_mod.clear_sweep_cache()
            p.execute(x)
            assert calls   # custom ops actually ran inside the sweep
        finally:
            unregister_backend("loud")
            api_mod.clear_sweep_cache()

    def test_auto_name_reserved(self):
        with pytest.raises(ValueError, match="reserved"):
            register_backend(OpsBackend(
                name="auto", loader=lambda: (T.ttm, T.gram, T.ttt)))


# ---------------------------------------------------------------------------
# Three-way op parity (the padding shims get odd shapes; pallas runs in
# interpret mode on CPU)
# ---------------------------------------------------------------------------

# first / interior / last modes, non-128-multiple dims
PARITY_CASES = [((33, 12, 17), 0, 9), ((5, 37, 19), 1, 7),
                ((13, 21, 40), 2, 5), ((4, 9, 11, 6), 2, 3)]


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
class TestOpParity:
    @pytest.mark.parametrize("shape,mode,r", PARITY_CASES)
    def test_ttm(self, shape, mode, r, dtype):
        x = arr(shape, jnp.dtype(dtype), seed=1)
        u = arr((r, shape[mode]), jnp.dtype(dtype), seed=2)
        outs = {b: get_backend(b).ops()[0](x, u, mode) for b in BACKENDS}
        for b in BACKENDS:
            assert outs[b].shape == outs["matfree"].shape
            assert outs[b].dtype == outs["matfree"].dtype, b
            np.testing.assert_allclose(
                np.asarray(outs[b], np.float32),
                np.asarray(outs["matfree"], np.float32), **TOL[dtype])

    @pytest.mark.parametrize("shape,mode", [(s, m) for s, m, _ in PARITY_CASES])
    def test_gram(self, shape, mode, dtype):
        x = arr(shape, jnp.dtype(dtype), seed=3)
        outs = {b: get_backend(b).ops()[1](x, mode) for b in BACKENDS}
        for b in BACKENDS:
            np.testing.assert_allclose(
                np.asarray(outs[b], np.float32),
                np.asarray(outs["matfree"], np.float32), **TOL[dtype])

    @pytest.mark.parametrize("shape,mode,r", PARITY_CASES)
    def test_ttt(self, shape, mode, r, dtype):
        x = arr(shape, jnp.dtype(dtype), seed=4)
        y = arr(shape[:mode] + (r,) + shape[mode + 1:], jnp.dtype(dtype), seed=5)
        outs = {b: get_backend(b).ops()[2](x, y, mode) for b in BACKENDS}
        for b in BACKENDS:
            np.testing.assert_allclose(
                np.asarray(outs[b], np.float32),
                np.asarray(outs["matfree"], np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# Plan-level routing (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestPlanBackend:
    def test_pallas_plan_matches_matfree(self):
        """plan(impl='pallas').execute ≈ matfree within fp32 accumulation
        tolerance, with the backend recorded in the plan."""
        x = lowrank((12, 15, 10), (3, 4, 2), noise=0.05)
        res = {}
        for b in BACKENDS:
            p = plan(x.shape, x.dtype,
                     TuckerConfig(ranks=(3, 4, 2), methods="eig", impl=b))
            assert p.backend == b
            assert all(s.backend == b for s in p.schedule)
            res[b] = p.execute(x)
            assert res[b].trace[0].backend == b
        for b in BACKENDS[1:]:
            np.testing.assert_allclose(np.asarray(res[b].tucker.core),
                                       np.asarray(res["matfree"].tucker.core),
                                       rtol=1e-4, atol=1e-4)
        assert float(res["pallas"].tucker.rel_error(x)) < 0.06

    def test_pallas_sweep_via_legacy_entry(self):
        x = lowrank((10, 9, 8), (2, 3, 2), noise=0.05)
        r_mf = sthosvd(x, (2, 3, 2), methods="eig", impl="matfree")
        r_pl = sthosvd(x, (2, 3, 2), methods="eig", impl="pallas")
        assert r_pl.trace[0].backend == "pallas"
        np.testing.assert_allclose(np.asarray(r_pl.tucker.core),
                                   np.asarray(r_mf.tucker.core),
                                   rtol=1e-4, atol=1e-4)

    def test_auto_impl_resolves_at_plan_time(self):
        p = plan((8, 7, 6), jnp.float32,
                 TuckerConfig(ranks=(2, 2, 2), methods="eig", impl="auto"))
        want = "pallas" if jax.default_backend() == "tpu" else "matfree"
        assert p.backend == want
        assert p.config.impl == "auto"        # config keeps the request
        d = p.to_dict()                        # ... but JSON carries both
        assert d["schedule"][0]["backend"] == want

    def test_unknown_impl_rejected_at_config(self):
        with pytest.raises(ValueError, match="unknown backend"):
            TuckerConfig(ranks=(2, 2), impl="magic")

    def test_plan_json_roundtrip_preserves_backend(self, tmp_path):
        p = plan((10, 9, 8), jnp.float32,
                 TuckerConfig(ranks=(2, 3, 2), methods="eig", impl="pallas"))
        path = tmp_path / "p.json"
        p.save(path)
        p2 = TuckerPlan.load(path)
        assert p2.backend == "pallas"
        assert p2.schedule == p.schedule

    def test_legacy_plan_json_defaults_to_matfree(self):
        d = plan((6, 5, 4), jnp.float32,
                 TuckerConfig(ranks=(2, 2, 2), methods="eig")).to_dict()
        for s in d["schedule"]:
            del s["backend"]                   # pre-backend plan files
        assert TuckerPlan.from_dict(d).backend == "matfree"

    def test_plan_reuse_zero_recompiles_per_backend(self):
        """Backend is part of the sweep-cache key: reuse hits, switch builds."""
        api_mod.clear_sweep_cache()
        x = lowrank((10, 9, 8), (2, 3, 2))
        for b in ("matfree", "pallas"):
            cfg = TuckerConfig(ranks=(2, 3, 2), methods="eig", impl=b)
            p = plan(x.shape, x.dtype, cfg)
            p.execute(x)
            p.execute(x)
        assert api_mod.CACHE_STATS["builds"] == 2     # one per backend
        assert api_mod.CACHE_STATS["traces"] == 2
        assert api_mod.CACHE_STATS["hits"] == 2       # second execute each

    def test_auto_and_explicit_name_share_compiled_sweep(self):
        api_mod.clear_sweep_cache()
        x = lowrank((8, 7, 6), (2, 2, 2))
        resolved = resolve_backend("auto").name
        plan(x.shape, x.dtype, TuckerConfig(ranks=(2, 2, 2), methods="eig",
                                            impl="auto")).execute(x)
        plan(x.shape, x.dtype, TuckerConfig(ranks=(2, 2, 2), methods="eig",
                                            impl=resolved)).execute(x)
        assert api_mod.CACHE_STATS["builds"] == 1
        assert api_mod.CACHE_STATS["hits"] == 1

    def test_execute_batch_trace_records_backend(self):
        x = lowrank((8, 7, 6), (2, 2, 2))
        p = plan(x.shape, x.dtype,
                 TuckerConfig(ranks=(2, 2, 2), methods="eig", impl="pallas"))
        res = p.execute_batch(jnp.stack([x, x]))
        assert all(t.backend == "pallas" for r in res for t in r.trace)

    def test_engine_backend_axis(self):
        from repro.serve import TuckerBatchEngine, TuckerRequest

        eng = TuckerBatchEngine(impl="pallas")
        cfg = TuckerConfig(ranks=(2, 2, 2), methods="eig")
        reqs = [TuckerRequest(x=lowrank((8, 7, 6), (2, 2, 2), seed=s),
                              config=cfg, rid=s) for s in range(3)]
        eng.run(reqs)
        assert eng.stats["backends"] == {"pallas": 3}
        assert all(r.result is not None for r in reqs)

    def test_engine_pin_merges_mixed_impl_groups(self):
        """Requests differing only in the overridden impl field batch as one
        vmapped wave under an engine-level pin."""
        from repro.serve import TuckerBatchEngine, TuckerRequest

        eng = TuckerBatchEngine(impl="matfree")
        reqs = [TuckerRequest(x=lowrank((8, 7, 6), (2, 2, 2), seed=s),
                              config=TuckerConfig(ranks=(2, 2, 2),
                                                  methods="eig", impl=impl),
                              rid=s)
                for s, impl in enumerate(("auto", "explicit", "matfree"))]
        eng.run(reqs)
        assert eng.stats["batches"] == 1
        assert eng.stats["plans_built"] == 1
        assert all(r.result is not None for r in reqs)


# ---------------------------------------------------------------------------
# Solver-level impl validation (svd_solve satellite)
# ---------------------------------------------------------------------------

class TestSolverImplValidation:
    def test_svd_solve_rejects_unknown_impl(self):
        x = arr((6, 5, 4))
        with pytest.raises(ValueError, match="unknown backend"):
            svd_solve(x, 0, 2, impl="magic")

    def test_svd_solve_accepts_all_backends(self):
        x = arr((6, 5, 4), seed=8)
        base = svd_solve(x, 0, 2, impl="matfree")
        for b in BACKENDS[1:]:
            res = svd_solve(x, 0, 2, impl=b)   # inherently matricizes anyway
            np.testing.assert_allclose(np.asarray(res.y_new),
                                       np.asarray(base.y_new),
                                       rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Dtype-aware peak_bytes (itemsize satellite)
# ---------------------------------------------------------------------------

class TestPeakBytesDtype:
    def test_float64_doubles_float32(self):
        cfg32 = TuckerConfig(ranks=(3, 3, 3), methods="eig")
        p32 = plan((16, 16, 16), jnp.float32, cfg32)
        p64 = plan((16, 16, 16), jnp.float64, cfg32)
        assert p64.peak_bytes == 2 * p32.peak_bytes

    def test_bfloat16_accounts_for_fp32_accumulation(self):
        cfg = TuckerConfig(ranks=(3, 3, 3), methods="eig")
        p32 = plan((16, 16, 16), jnp.float32, cfg)
        p16 = plan((16, 16, 16), jnp.bfloat16, cfg)
        # bf16 I/O halves, but EIG's Gram scratch stays fp32: strictly more
        # than half the fp32 plan, strictly less than the fp32 plan
        assert p32.peak_bytes / 2 < p16.peak_bytes < p32.peak_bytes

    def test_compute_dtype_governs_itemsize(self):
        cfg = TuckerConfig(ranks=(3, 3, 3), methods="eig",
                           compute_dtype="float64")
        p = plan((16, 16, 16), jnp.float32, cfg)
        ref = plan((16, 16, 16), jnp.float64,
                   TuckerConfig(ranks=(3, 3, 3), methods="eig"))
        assert p.peak_bytes == ref.peak_bytes

    def test_resolve_schedule_stamps_backend_and_itemsize(self):
        steps = resolve_schedule((8, 8, 8), (2, 2, 2), methods="eig",
                                 itemsize=8, backend="explicit")
        assert all(isinstance(s, ModeStep) and s.backend == "explicit"
                   for s in steps)
        with pytest.raises(ValueError, match="unknown backend"):
            resolve_schedule((8, 8, 8), (2, 2, 2), methods="eig",
                             backend="nope")
