"""Property tests for the matricization-free tensor ops (paper Sec. V)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import tensor_ops as T

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape), dtype)


shapes3 = st.tuples(st.integers(2, 9), st.integers(2, 9), st.integers(2, 9))
shapes4 = st.tuples(st.integers(2, 6), st.integers(2, 6), st.integers(2, 6),
                    st.integers(2, 6))


class TestTTM:
    @given(shape=shapes3, mode=st.integers(0, 2), r=st.integers(1, 7),
           seed=st.integers(0, 10))
    def test_matfree_equals_explicit(self, shape, mode, r, seed):
        x = rand(shape, seed)
        u = rand((r, shape[mode]), seed + 1)
        np.testing.assert_allclose(
            T.ttm(x, u, mode), T.ttm_explicit(x, u, mode), rtol=2e-4, atol=2e-4)

    @given(shape=shapes4, mode=st.integers(0, 3))
    def test_4th_order(self, shape, mode):
        x = rand(shape)
        u = rand((3, shape[mode]), 1)
        np.testing.assert_allclose(
            T.ttm(x, u, mode), T.ttm_explicit(x, u, mode), rtol=2e-4, atol=2e-4)

    @given(shape=shapes3, mode=st.integers(0, 2))
    def test_identity(self, shape, mode):
        x = rand(shape)
        eye = jnp.eye(shape[mode])
        np.testing.assert_allclose(T.ttm(x, eye, mode), x, rtol=1e-5, atol=1e-5)

    @given(shape=shapes3, seed=st.integers(0, 5))
    def test_distinct_modes_commute(self, shape, seed):
        x = rand(shape, seed)
        u0 = rand((3, shape[0]), seed + 1)
        u2 = rand((4, shape[2]), seed + 2)
        a = T.ttm(T.ttm(x, u0, 0), u2, 2)
        b = T.ttm(T.ttm(x, u2, 2), u0, 0)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    def test_shape_validation(self):
        x = rand((3, 4, 5))
        with pytest.raises(ValueError):
            T.ttm(x, rand((2, 99)), 1)


class TestGramTTT:
    @given(shape=shapes3, mode=st.integers(0, 2), seed=st.integers(0, 10))
    def test_gram_equals_explicit(self, shape, mode, seed):
        x = rand(shape, seed)
        np.testing.assert_allclose(
            T.gram(x, mode), T.gram_explicit(x, mode), rtol=2e-4, atol=2e-4)

    @given(shape=shapes3, mode=st.integers(0, 2))
    def test_gram_spd(self, shape, mode):
        s = np.asarray(T.gram(rand(shape), mode))
        np.testing.assert_allclose(s, s.T, rtol=1e-5, atol=1e-6)
        assert np.linalg.eigvalsh(s).min() > -1e-4

    @given(shape=shapes3, mode=st.integers(0, 2), r=st.integers(1, 6))
    def test_ttt_equals_explicit(self, shape, mode, r):
        x = rand(shape, 0)
        yshape = shape[:mode] + (r,) + shape[mode + 1:]
        y = rand(yshape, 1)
        np.testing.assert_allclose(
            T.ttt(x, y, mode), T.ttt_explicit(x, y, mode), rtol=2e-4, atol=2e-4)

    def test_gram_is_ttt_self(self):
        x = rand((4, 5, 6))
        np.testing.assert_allclose(T.gram(x, 1), T.ttt(x, x, 1), rtol=1e-5)


class TestFoldReconstruct:
    @given(shape=shapes3, mode=st.integers(0, 2))
    def test_unfold_fold_roundtrip(self, shape, mode):
        x = rand(shape)
        np.testing.assert_array_equal(
            T.fold(T.unfold(x, mode), mode, shape), x)

    def test_fro_norm_mode_invariant(self):
        x = rand((4, 5, 6))
        n = float(T.fro_norm(x))
        for mode in range(3):
            assert abs(float(jnp.linalg.norm(T.unfold(x, mode))) - n) < 1e-4

    def test_reconstruct_orthonormal_exact(self):
        rng = np.random.default_rng(0)
        core = rand((3, 4, 2), 5)
        factors = [jnp.asarray(np.linalg.qr(rng.standard_normal((d, r)))[0],
                               jnp.float32)
                   for d, r in zip((8, 9, 7), (3, 4, 2))]
        x = T.reconstruct(core, factors)
        # project back: core == X ×_n U^T
        back = x
        for m, u in enumerate(factors):
            back = T.ttm(back, u.T, m)
        np.testing.assert_allclose(back, core, rtol=1e-4, atol=1e-5)
