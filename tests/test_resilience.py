"""Fault-tolerance suite: failure taxonomy, fallback ladder, serve isolation.

Faults are injected through the deterministic chaos harness
(``src/repro/chaos``) at named seams; every test asserts one of the two
allowed outcomes — the fault is RECOVERED (degraded but correct results)
or CLASSIFIED (a ``TuckerError`` subclass naming what went wrong).  An
unclassified exception escaping ``plan.execute`` or ``TuckerService.poll``
is always a failure here.

Run under ``ATUCKER_CHAOS=numerical|oom|serve-poison`` the env-profile
test additionally exercises the shipped profiles end to end (CI's
``resilience`` job does exactly that, three times).
"""

import os
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro import chaos
from repro.core import (CancelledError, DeadlineError, InputError,
                        MemoryCapError, NumericalError, ResourceError,
                        TuckerConfig, TuckerError, check_finite,
                        classify_exception, coerce_exception, plan)
from repro.serve import BucketPolicy, TuckerService
from repro.serve.service import _Breaker
from tests._hypothesis_compat import given, settings, st

F32 = "float32"


@pytest.fixture(autouse=True)
def _clean_chaos():
    chaos.reset()
    yield
    chaos.reset()


def _rand(shape, seed=0):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


# -- taxonomy -----------------------------------------------------------------

class TestTaxonomy:
    def test_hierarchy_is_dual(self):
        # every class keeps its pre-taxonomy base so old call sites work
        assert issubclass(InputError, ValueError)
        assert issubclass(NumericalError, FloatingPointError)
        assert issubclass(DeadlineError, TimeoutError)
        assert issubclass(MemoryCapError, ResourceError)
        assert issubclass(MemoryCapError, ValueError)
        for cls in (InputError, NumericalError, ResourceError,
                    DeadlineError, CancelledError):
            assert issubclass(cls, TuckerError)
            assert issubclass(cls, RuntimeError)

    def test_classify_markers(self):
        assert isinstance(
            classify_exception(RuntimeError("RESOURCE_EXHAUSTED: oom")),
            ResourceError)
        assert isinstance(classify_exception(MemoryError()), ResourceError)
        assert isinstance(
            classify_exception(RuntimeError("Cholesky failed: matrix is "
                                            "not positive definite")),
            NumericalError)
        assert isinstance(classify_exception(ZeroDivisionError()),
                          NumericalError)
        assert classify_exception(KeyError("bug")) is None

    def test_classify_passthrough_and_cause(self):
        e = NumericalError("already classified")
        assert classify_exception(e) is e
        src = RuntimeError("Out of memory while allocating")
        wrapped = classify_exception(src)
        assert wrapped.__cause__ is src

    def test_coerce_is_total(self):
        e = coerce_exception(KeyError("bug"))
        assert isinstance(e, TuckerError)
        assert "unclassified" in str(e)
        r = ResourceError("x")
        assert coerce_exception(r) is r

    def test_check_finite_names_mode(self):
        x = _rand((6, 5, 4))
        x[:, 3, :] = np.nan     # a full mode-1 slice of NaNs
        with pytest.raises(InputError, match="mode 1"):
            check_finite(x, name="input")
        assert check_finite(_rand((4, 4)), name="input") is None


# -- solver guards ------------------------------------------------------------

class TestSolverGuards:
    def test_als_survives_rank_deficient_gram(self):
        # an exactly rank-1 tensor makes every Gram singular; the jittered
        # re-regularization ladder in _spd_inverse must keep ALS finite
        a, b, c = _rand(12, 1), _rand(10, 2), _rand(8, 3)
        x = np.einsum("i,j,k->ijk", a, b, c)
        cfg = TuckerConfig(ranks=(3, 3, 3), methods="als")
        res = plan(x.shape, F32, cfg).execute(x, validate="finite")
        assert np.all(np.isfinite(np.asarray(res.tucker.core)))

    def test_solver_breakdown_is_classified(self):
        # poison an eager (per-step) solve output: the run_schedule guard
        # must classify it as NumericalError, not let NaNs flow downstream
        from repro.core.plan import run_schedule
        from repro.core.api import plan as make_plan
        chaos.install([chaos.Rule(seam="solve_out", action="nan", at=0,
                                  times=1)])
        x = _rand((10, 9, 8))
        p = make_plan(x.shape, F32, TuckerConfig(ranks=(3, 3, 3)))
        with pytest.raises(NumericalError, match="non-finite"):
            run_schedule(jnp.asarray(x), p.schedule, sequential=True,
                         block_until_ready=True)


# -- execute-time fallback ladder --------------------------------------------

class TestFallbackLadder:
    def test_als_to_eig_on_poisoned_sweep(self):
        # fused sweep output NaN once -> ladder hops als->eig and recovers
        chaos.install([chaos.Rule(seam="sweep_out", action="nan", at=0,
                                  times=1)])
        x = _rand((12, 10, 8), seed=1)
        cfg = TuckerConfig(ranks=(3, 3, 3), methods="als")
        res = plan(x.shape, F32, cfg).execute(x, validate="finite")
        assert np.all(np.isfinite(np.asarray(res.tucker.core)))
        assert sum(chaos.fired().values()) >= 1

    def test_oom_hops_to_undonated(self):
        chaos.install([chaos.Rule(seam="sweep", action="oom", at=0,
                                  times=1)])
        x = _rand((12, 10, 8), seed=2)
        res = plan(x.shape, F32, TuckerConfig(ranks=(3, 3, 3))).execute(x)
        assert np.all(np.isfinite(np.asarray(res.tucker.core)))
        assert sum(chaos.fired().values()) == 1

    def test_persistent_oom_is_classified_and_bounded(self):
        # an OOM that never goes away must exhaust the (bounded) ladder and
        # surface as ResourceError — not loop forever, not escape raw
        chaos.install([chaos.Rule(seam="sweep", action="oom", times=None)])
        x = _rand((12, 10, 8), seed=3)
        p = plan(x.shape, F32, TuckerConfig(ranks=(3, 3, 3)))
        with pytest.raises(ResourceError):
            p.execute(x)
        assert sum(chaos.fired().values()) <= 4   # one attempt per rung, no retry storms

    def test_nan_input_rejected_by_validate(self):
        x = _rand((8, 8, 8), seed=4)
        x[2, :, :] = np.inf
        p = plan(x.shape, F32, TuckerConfig(ranks=(3, 3, 3)))
        with pytest.raises(InputError, match="mode 0"):
            p.execute(x, validate="finite")

    def test_sketch_miss_hops_to_eig(self):
        # incompressible input + a tiny capped sketch grid: the adaptive
        # pass misses its error target, and the plan refines with exact
        # eig solves instead of serving the miss silently
        chaos.reset()
        x = _rand((16, 12, 10), seed=5)
        cfg = TuckerConfig(error_target=0.05, rank_grid=(2,))
        res = plan(x.shape, F32, cfg).execute(x)
        assert np.all(np.isfinite(np.asarray(res.tucker.core)))
        assert np.asarray(res.tucker.core).shape == (2, 2, 2)
        assert res.error_bound is not None     # honest about the miss


# -- chaos harness ------------------------------------------------------------

class TestChaosHarness:
    def test_schedule_at_and_times(self):
        chaos.install([chaos.Rule(seam="s", action="raise", at=1, times=1)])
        chaos.fire("s")                       # hit 0: not due
        with pytest.raises(chaos.ChaosFault):
            chaos.fire("s")                   # hit 1: due
        chaos.fire("s")                       # times=1 budget spent
        assert sum(chaos.fired().values()) == 1

    def test_match_filters_context(self):
        chaos.install([chaos.Rule(seam="s", action="raise", times=None,
                                  match={"rid": 2})])
        chaos.fire("s", rid=0)
        chaos.fire("s", rid=1)
        with pytest.raises(chaos.ChaosFault):
            chaos.fire("s", rid=2)

    def test_probability_is_seed_deterministic(self):
        def pattern(seed):
            chaos.reset()
            chaos.install([chaos.Rule(seam="s", action="raise", p=0.5,
                                      times=None, seed=seed)])
            out = []
            for _ in range(32):
                try:
                    chaos.fire("s")
                    out.append(0)
                except chaos.ChaosFault:
                    out.append(1)
            return out

        assert pattern(7) == pattern(7)
        assert pattern(7) != pattern(8)   # astronomically unlikely to tie

    def test_synthetic_oom_classifies_as_resource(self):
        chaos.install([chaos.Rule(seam="s", action="oom", times=1)])
        with pytest.raises(chaos.SyntheticOOM) as ei:
            chaos.fire("s")
        assert isinstance(classify_exception(ei.value), ResourceError)

    def test_profiles_install_and_bad_name_is_loud(self):
        chaos.install_profile("numerical")
        assert chaos.active()
        with pytest.raises(ValueError, match="numerical"):
            chaos.install_profile("no-such-profile")


# -- serve-side isolation -----------------------------------------------------

def _mask_service(**kw):
    kw.setdefault("policy", BucketPolicy(grid=8, pad_mode="mask",
                                         wave_slots=8))
    kw.setdefault("max_queue", 64)
    return TuckerService(**kw)


_CFG = TuckerConfig(ranks=(3, 3, 3))


def _job_shapes(n):
    # mixed true shapes in one (8, 8, 8) mask bucket (>=1 padded member,
    # so waves take the fused path)
    return [(8 - (i % 2), 8, 8 - (i % 3)) for i in range(n)]


def _run_stream(svc, shapes, **submit_kw):
    tickets = [svc.submit(_rand(s, seed=100 + i), _CFG, rid=i, **submit_kw)
               for i, s in enumerate(shapes)]
    svc.drain()
    out = []
    for t in tickets:
        try:
            out.append(svc.poll(t))
        except Exception as e:  # noqa: BLE001 - collected for assertions
            out.append(e)
    return out


class TestServeIsolation:
    def test_deadline_expires_prewave(self):
        svc = _mask_service()
        t = svc.submit(_rand((7, 8, 8)), _CFG, deadline_s=0.01)
        time.sleep(0.05)
        svc.drain()
        with pytest.raises(DeadlineError):
            svc.poll(t)
        assert svc.stats()["resilience"]["deadline_expired"] == 1

    def test_deadline_validation(self):
        svc = _mask_service()
        with pytest.raises(ValueError):
            svc.submit(_rand((7, 8, 8)), _CFG, deadline_s=0.0)

    def test_cancel_before_dispatch(self):
        svc = _mask_service()
        t0 = svc.submit(_rand((7, 8, 8), seed=1), _CFG)
        t1 = svc.submit(_rand((8, 8, 7), seed=2), _CFG)
        assert svc.cancel(t0) is True
        svc.drain()
        with pytest.raises(CancelledError):
            svc.poll(t0)
        assert svc.poll(t1) is not None
        assert svc.cancel(t1) is False      # already completed
        s = svc.stats()
        assert s["resilience"]["cancelled"] == 1
        assert s["requests"] == 1

    def test_submit_rejects_nonfinite_input(self):
        svc = _mask_service()
        x = _rand((7, 8, 8))
        x[:, 2, :] = np.nan
        with pytest.raises(InputError, match="mode 1"):
            svc.submit(x, _CFG)
        # trusted traffic can opt out of the admission check
        t = svc.submit(x, _CFG, validate="none")
        svc.drain()
        with pytest.raises(TuckerError):    # classified downstream instead
            svc.poll(t)

    def test_poisoned_job_fails_alone_others_bitwise_clean(self):
        shapes = _job_shapes(5)
        clean = _run_stream(_mask_service(), shapes)
        assert all(not isinstance(r, Exception) for r in clean)
        # rid 2 raises on EVERY attempt (dispatch, bisection, isolation)
        chaos.install([chaos.Rule(seam="wave_job", action="raise",
                                  times=None, match={"rid": 2},
                                  message="synthetic poisoned request")])
        poisoned = _run_stream(_mask_service(), shapes)
        assert isinstance(poisoned[2], TuckerError)
        for i in (0, 1, 3, 4):
            assert not isinstance(poisoned[i], Exception)
            assert np.array_equal(np.asarray(clean[i].tucker.core),
                                  np.asarray(poisoned[i].tucker.core))
            for uc, up in zip(clean[i].tucker.factors,
                              poisoned[i].tucker.factors):
                assert np.array_equal(np.asarray(uc), np.asarray(up))

    @settings(max_examples=5, deadline=None)
    @given(n=st.integers(2, 6), poison=st.integers(0, 5))
    def test_bisection_bitwise_property(self, n, poison):
        poison = poison % n
        shapes = _job_shapes(n)
        chaos.reset()
        clean = _run_stream(_mask_service(), shapes)
        chaos.install([chaos.Rule(seam="wave_job", action="raise",
                                  times=None, match={"rid": poison})])
        got = _run_stream(_mask_service(), shapes)
        chaos.reset()
        assert isinstance(got[poison], TuckerError)
        for i in range(n):
            if i == poison:
                continue
            assert np.array_equal(np.asarray(clean[i].tucker.core),
                                  np.asarray(got[i].tucker.core))

    def test_nan_lane_quarantined_and_recovered(self):
        # transient data poison in ONE fused lane: that lane re-derives in
        # isolation from the intact input; nobody else re-runs
        shapes = _job_shapes(4)
        chaos.install([chaos.Rule(seam="wave_job_data", action="nan",
                                  times=1, match={"rid": 1})])
        svc = _mask_service()
        out = _run_stream(svc, shapes)
        assert all(not isinstance(r, Exception) for r in out)
        assert all(np.all(np.isfinite(np.asarray(r.tucker.core)))
                   for r in out)
        res = svc.stats()["resilience"]
        assert res["quarantined"] >= 1
        assert res["recovered"] >= 1

    def test_retry_budget_recovers_transient_fault(self):
        # the fault persists through dispatch + bisection + isolation of
        # wave 1 (3 firings), then goes away; retries=1 re-enqueues the job
        chaos.install([chaos.Rule(seam="wave_job", action="raise", times=3,
                                  match={"rid": 0})])
        svc = _mask_service()
        t = svc.submit(_rand((7, 8, 8)), _CFG, rid=0, retries=1)
        svc.drain()
        assert svc.poll(t) is not None
        assert svc.stats()["resilience"]["retried"] == 1
        assert sum(chaos.fired().values()) == 3

    def test_retry_budget_exhausts_to_classified(self):
        chaos.install([chaos.Rule(seam="wave_job", action="raise",
                                  times=None, match={"rid": 0})])
        svc = _mask_service()
        t = svc.submit(_rand((7, 8, 8)), _CFG, rid=0, retries=2)
        svc.drain()
        with pytest.raises(TuckerError):
            svc.poll(t)
        assert svc.stats()["resilience"]["retried"] == 2

    def test_breaker_trips_isolates_and_recovers(self):
        # every fused wave "fails" (recovery succeeds, but the fused path
        # itself keeps breaking) -> breaker opens after 2 waves; requests
        # keep completing through bisection and then isolation
        chaos.install([chaos.Rule(seam="wave", action="raise", times=None)])
        svc = _mask_service(breaker_threshold=2, breaker_cooldown_s=0.05)
        for wave in range(3):
            shapes = _job_shapes(2)
            out = _run_stream(svc, shapes)
            assert all(not isinstance(r, Exception) for r in out)
        s = svc.stats()
        assert s["resilience"]["breaker_trips"] == 1
        assert s["resilience"]["isolated_waves"] >= 1
        assert svc.health()["status"] == "degraded"
        # fault clears; after the cooldown one fused probe re-closes it
        chaos.reset()
        time.sleep(0.06)
        out = _run_stream(svc, _job_shapes(2))
        assert all(not isinstance(r, Exception) for r in out)
        s = svc.stats()
        assert s["resilience"]["probe_waves"] >= 1
        assert s["resilience"]["breakers_open"] == 0
        assert svc.health()["status"] == "ok"

    def test_stop_force_abandons_with_classified_error(self):
        chaos.install([chaos.Rule(seam="wave", action="slow", times=None,
                                  delay_s=0.3)])
        svc = _mask_service(breaker_cooldown_s=60.0)
        svc.start()
        tickets = [svc.submit(_rand(s, seed=i), _CFG)
                   for i, s in enumerate(_job_shapes(6))]
        time.sleep(0.05)
        svc.stop(force=True, join_timeout=5.0)
        for t in tickets:
            assert t._job.event.wait(timeout=5.0)
            with pytest.raises((ResourceError, TuckerError)):
                svc.poll(t)

    def test_stop_warns_naming_wedged_bucket(self):
        chaos.install([chaos.Rule(seam="wave", action="slow", times=None,
                                  delay_s=1.5)])
        svc = _mask_service()
        svc.start()
        worker = svc._thread
        svc.submit(_rand((7, 8, 8)), _CFG)
        time.sleep(0.3)          # let the worker enter the slow wave
        with pytest.warns(RuntimeWarning, match="8x8x8"):
            svc.stop(drain=False, force=True, join_timeout=0.2)
        # the daemonic worker was abandoned mid-wave; reap it so it is not
        # still driving the device when the interpreter tears down
        worker.join(timeout=10.0)
        assert not worker.is_alive()

    def test_worker_death_fails_jobs_classified(self):
        svc = _mask_service()
        t = svc.submit(_rand((7, 8, 8)), _CFG)
        chaos.install([chaos.Rule(seam="worker", action="raise", times=1)])
        svc.start()
        assert t._job.event.wait(timeout=10.0)
        with pytest.raises(ResourceError, match="worker died"):
            svc.poll(t)
        assert svc.health()["status"] == "unhealthy"

    def test_no_unclassified_escape_under_poison_profile(self):
        chaos.install_profile("serve-poison")
        out = _run_stream(_mask_service(), _job_shapes(5))
        for i, r in enumerate(out):
            if isinstance(r, Exception):
                assert isinstance(r, TuckerError), (
                    f"rid {i}: unclassified {type(r).__name__} escaped")
            else:
                assert np.all(np.isfinite(np.asarray(r.tucker.core)))
        assert isinstance(out[2], TuckerError)   # the profile poisons rid 2


class TestBreakerUnit:
    def test_concurrent_failures_trip_exactly_once(self):
        br = _Breaker(threshold=1, cooldown_s=10.0)
        lock = threading.RLock()
        start = threading.Barrier(8)
        def hammer():
            start.wait()
            for _ in range(200):
                with lock:
                    br.on_result(False, 0.0)
        threads = [threading.Thread(target=hammer) for _ in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=30)
            assert not th.is_alive(), "breaker hammer deadlocked"
        assert br.trips == 1
        assert br.state == "open"

    def test_probe_cycle(self):
        br = _Breaker(threshold=2, cooldown_s=1.0)
        assert br.route(0.0) == "fused"
        br.on_result(False, 0.0)
        assert br.on_result(False, 0.0) is True   # trip
        assert br.route(0.5) == "isolated"        # cooling down
        assert br.route(1.5) == "probe"           # cooldown elapsed
        assert br.route(1.6) == "isolated"        # probe slot claimed
        br.on_probe(False, 1.7)                   # probe failed: reopen
        assert br.reopens == 1 and br.trips == 1
        assert br.route(3.0) == "probe"
        br.on_probe(True, 3.1)
        assert br.state == "closed"
        assert br.route(3.2) == "fused"


# -- shipped profiles end to end (CI runs these under ATUCKER_CHAOS) ---------

PROFILE = os.environ.get("ATUCKER_CHAOS")


@pytest.mark.skipif(PROFILE is None,
                    reason="set ATUCKER_CHAOS=numerical|oom|serve-poison")
def test_env_profile_recovers_or_classifies():
    chaos.install_profile(PROFILE)   # the autouse fixture cleared the env rules
    if PROFILE == "serve-poison":
        out = _run_stream(_mask_service(), _job_shapes(5))
        for r in out:
            assert not isinstance(r, Exception) or isinstance(r, TuckerError)
        assert isinstance(out[2], TuckerError)
    else:
        x = _rand((12, 10, 8), seed=11)
        res = plan(x.shape, F32, TuckerConfig(ranks=(3, 3, 3))).execute(
            x, validate="finite")
        assert np.all(np.isfinite(np.asarray(res.tucker.core)))
        assert sum(chaos.fired().values()) >= 1
