"""Multi-(fake-)device integration: distributed st-HOSVD, compressed-psum
gradients, dryrun-lite through the real launch path, roofline parsing.

These run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single-device view (per the launch
contract in dryrun.py).
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_in_subprocess(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_distributed_sthosvd_matches_single():
    run_in_subprocess("""
        from repro.core import sthosvd_eig, tensor_ops as T
        from repro.core.distributed import sthosvd_distributed
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        G = rng.standard_normal((4,5,6))
        Us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
              for d, r in zip((24,40,16),(4,5,6))]
        X = T.reconstruct(jnp.asarray(G, jnp.float32),
                          [jnp.asarray(u, jnp.float32) for u in Us])
        X = X + 0.001*jnp.asarray(rng.standard_normal(X.shape), jnp.float32)
        ref = sthosvd_eig(X, (4,5,6))
        for methods in ("eig", "als", "auto"):
            dist = sthosvd_distributed(X, (4,5,6), mesh, methods=methods)
            e1, e2 = float(ref.tucker.rel_error(X)), float(dist.tucker.rel_error(X))
            assert abs(e1 - e2) < 1e-4, (methods, e1, e2)
        # subspace parity for the explicit shard_map EIG schedule
        dist = sthosvd_distributed(X, (4,5,6), mesh, methods="eig")
        for a, b in zip(ref.tucker.factors, dist.tucker.factors):
            pa, pb = a @ a.T, b @ b.T
            assert float(jnp.abs(pa-pb).max()) < 1e-3
        print("OK")
    """)


def test_compressed_grad_psum_exact_for_shared_subspace():
    run_in_subprocess("""
        from repro.optim import grad_compress as gc
        cfg = gc.CompressionConfig(rank_fraction=0.25, min_size=1000, refresh_every=4)
        mesh = jax.make_mesh((8,), ("pod",))
        r = np.random.default_rng(0)
        Ud = np.linalg.qr(r.standard_normal((32, 8)))[0]
        Uf = np.linalg.qr(r.standard_normal((48, 12)))[0]
        gs = []
        for i in range(8):
            core = np.random.default_rng(100+i).standard_normal((4, 8, 12))
            gs.append({"w": jnp.asarray(
                np.einsum('lcr,dc,fr->ldf', core, Ud, Uf), jnp.float32)})
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *gs)
        state0 = gc.stack_for_pods(gc.init_state(cfg, gs[0]), 8)
        sspecs = gc.state_specs(state0, "pod")
        def body(g_shard, st_in):
            g_local = jax.tree.map(lambda x: x[0], g_shard)
            red, new_st, _ = gc.compress_psum(cfg, g_local, gc.localize(st_in),
                                              refresh=True, axis_name="pod")
            return red, gc.delocalize(new_st)
        step = jax.jit(jax.shard_map(body, mesh=mesh,
            in_specs=(P("pod"), sspecs), out_specs=(P(), sspecs)))
        red, st = step(stacked, state0)
        dense = jax.tree.map(lambda x: x.mean(0), stacked)
        err = float(jnp.linalg.norm(red["w"] - dense["w"]) /
                    jnp.linalg.norm(dense["w"]))
        assert err < 1e-5, err
        print("OK", err)
    """)


def test_compressed_training_tracks_dense():
    run_in_subprocess("""
        from repro import configs
        from repro.models import build
        from repro.models.config import ShapeConfig
        from repro.data.pipeline import DataConfig, make_source
        from repro.optim.adamw import AdamW
        from repro.optim.grad_compress import CompressionConfig
        from repro.train.train_step import (init_state, make_train_step,
                                            make_compressed_train_step)
        mesh = jax.make_mesh((8,), ("pod",))
        cfg = configs.get_smoke("phi3_mini_3p8b").with_(n_layers=2, remat=False)
        bundle = build(cfg)
        shape = ShapeConfig("t", 32, 16, "train")
        src = make_source(DataConfig(seed=0), cfg, shape)
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        comp = CompressionConfig(rank_fraction=0.25, min_size=4096, refresh_every=5)
        state = init_state(bundle, opt, jax.random.PRNGKey(0),
                           compression=comp, n_pods=8)
        steps = make_compressed_train_step(bundle, opt, comp, mesh)
        state_d = init_state(bundle, opt, jax.random.PRNGKey(0))
        dense = make_train_step(bundle, opt)
        losses_c, losses_d = [], []
        for t in range(10):
            b = src.batch_at(t)
            state, m = steps[t % 5 == 0](state, b)
            state_d, md = dense(state_d, b)
            losses_c.append(float(m["loss"])); losses_d.append(float(md["loss"]))
        assert losses_c[-1] < losses_c[0]
        assert abs(losses_c[-1] - losses_d[-1]) < 0.25, (losses_c[-1], losses_d[-1])
        print("OK", losses_c[-1], losses_d[-1])
    """)


def test_dryrun_lite_all_families():
    """The real launch path (build_cell → lower → compile → roofline) on a
    small mesh with smoke configs, one arch per family, all shape kinds."""
    run_in_subprocess("""
        from repro.launch.dryrun import build_cell
        from repro.launch import mesh as M
        from repro.models import shardings as sm
        from repro.models.config import ShapeConfig
        from repro.roofline import hlo_walk
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        sm.set_activation_mesh(mesh)
        shapes = {
            "train": ShapeConfig("t", 32, 8, "train"),
            "prefill": ShapeConfig("p", 64, 4, "prefill"),
            "decode": ShapeConfig("d", 64, 4, "decode"),
        }
        for arch in ("gemma3_1b", "mixtral_8x22b", "falcon_mamba_7b",
                     "zamba2_1p2b", "seamless_m4t_medium", "internvl2_2b"):
            for kind, sh in shapes.items():
                fn, abs_args, cfg, shape = build_cell(
                    arch, "train_4k", mesh, smoke=True, shape_override=sh)
                with mesh:
                    compiled = fn.lower(*abs_args).compile()
                walked = hlo_walk.analyze(compiled.as_text())
                assert walked["flops"] > 0, (arch, kind)
                print("OK", arch, kind, f"{walked['flops']:.2e}")
    """)


def test_roofline_parser_on_known_program():
    run_in_subprocess("""
        from repro.roofline import hlo_walk
        mesh = jax.make_mesh((8,), ("data",))
        from jax.sharding import NamedSharding
        sh = NamedSharding(mesh, P("data"))
        @jax.jit
        def f(x, w):
            def body(c, _):
                return c + jax.lax.psum(x @ w, "data").sum(), None
            out, _ = jax.lax.scan(body, 0.0, None, length=5)
            return out
        import functools
        g = jax.jit(jax.shard_map(
            lambda x, w: jax.lax.psum(x @ w, "data"),
            mesh=mesh, in_specs=(P("data"), P()), out_specs=P()))
        x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
        w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
        compiled = g.lower(x, w).compile()
        r = hlo_walk.analyze(compiled.as_text())
        # matmul per device: 2 * (64/8) * 32 * 16
        assert abs(r["flops"] - 2*8*32*16) / (2*8*32*16) < 0.5, r["flops"]
        assert r["all-reduce"] >= 8*16*4, r   # psum of (8?,16) f32 at least
        print("OK", r["flops"], r["all-reduce"])
    """)
