"""Mode-parallel sweeps: group-aware shard picking, schedule resolution and
plan plumbing (pure, no devices), the grouped DP vs brute-force enumeration
over order × solver × grouping, cap-forced group splits and the binding-group
error, and end-to-end numerical parity of mode-parallel vs sequential
execution on 8 virtual CPU devices (subprocess, same launch contract as
tests/test_sharded.py)."""

import itertools
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    MemoryCapError,
    TuckerConfig,
    TuckerPlan,
    optimize_grouping,
    optimize_schedule,
    plan,
)
from repro.core.distributed import pick_shard_mode, pick_shard_mode_group
from repro.core.plan import (
    ModeStep,
    _group_peak_bytes,
    _step_peak_bytes,
    iter_groups,
    resolve_schedule,
)
from repro.core.schedule_opt import (_price_group, _priced_candidates,
                                     _relax, step_cost)
from repro.core.cost_model import DEFAULT_COST_MODEL

from test_sharded import run_in_subprocess


# ---------------------------------------------------------------------------
# Group-aware shard picking (pure function)
# ---------------------------------------------------------------------------

class TestPickShardModeGroup:
    def test_picks_largest_mode_outside_group(self):
        assert pick_shard_mode_group((64, 16, 16), (1, 2), 8) == 0
        assert pick_shard_mode_group((64, 16, 16), (0, 1), 8) == 2

    def test_group_covering_all_shardable_modes_replicates(self):
        assert pick_shard_mode_group((32, 32, 32), (0, 1, 2), 8) is None
        # the only mode outside the group does not divide
        assert pick_shard_mode_group((9, 32, 32), (1, 2), 8) is None

    def test_singleton_group_matches_pick_shard_mode(self):
        for shape in ((24, 40, 16), (64, 15, 8), (5, 7, 9), (4, 5, 16)):
            for m in range(3):
                for n in (1, 4, 8):
                    assert pick_shard_mode(shape, m, n) == \
                        pick_shard_mode_group(shape, (m,), n)


# ---------------------------------------------------------------------------
# Schedule resolution: groups, group peaks, validation
# ---------------------------------------------------------------------------

class TestGroupSchedule:
    def test_int_forces_leading_group(self):
        steps = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel=2)
        assert [s.group for s in steps] == [0, 0, None]
        g = steps[:2]
        # both members sized at the GROUP-ENTRY (un-shrunk) shape
        assert g[0].j_n == 16 * 16 and g[1].j_n == 64 * 16
        # one shard mode serves the group, chosen OUTSIDE it
        assert g[0].shard_mode == g[1].shard_mode == 2
        # the group's shared peak is stamped on every member
        assert g[0].peak_bytes == g[1].peak_bytes
        # the trailing step shrank both group modes first
        assert steps[2].j_n == 4 * 4

    def test_group_peak_is_shared_input_plus_concurrent_scratch(self):
        steps = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel=2)
        entries = [(s.method, s.i_n, s.r_n, s.j_n) for s in steps[:2]]
        in_elems = 64 * 16 * 16
        out_elems = 4 * 4 * 16   # group modes 0,1 shrink; mode 2 does not
        assert steps[0].peak_bytes == _group_peak_bytes(
            entries, in_elems, out_elems, 4, 8)

    def test_singleton_group_peak_reduces_to_step_peak(self):
        # the group model with one entry must equal the sequential model
        for meth in ("eig", "als"):
            for i_n, r_n, j_n, eff in ((64, 4, 256, 8), (33, 5, 77, 1)):
                one = _group_peak_bytes([(meth, i_n, r_n, j_n)],
                                        i_n * j_n, r_n * j_n, 4, eff)
                assert one == _step_peak_bytes(meth, i_n, r_n, j_n, 4, eff)

    def test_off_and_one_are_sequential(self):
        ref = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                               backend="sharded", n_shards=8)
        for mp in ("off", 1):
            steps = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                                     backend="sharded", n_shards=8,
                                     mode_parallel=mp)
            assert steps == ref
            assert all(s.group is None for s in steps)

    def test_auto_single_device_silently_sequential(self):
        steps = resolve_schedule((32, 32, 32), (4, 4, 4), methods="eig",
                                 mode_parallel="auto")
        assert all(s.group is None for s in steps)

    def test_int_single_device_rejected(self):
        with pytest.raises(ValueError, match="n_shards"):
            resolve_schedule((32, 32, 32), (4, 4, 4), methods="eig",
                             mode_parallel=2)

    def test_invalid_values_rejected(self):
        for bad in ("on", 0, -1, True, 2.5):
            with pytest.raises(ValueError):
                resolve_schedule((32, 32, 32), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel=bad)

    def test_non_sthosvd_rejected(self):
        for variant in ("thosvd", "hooi"):
            with pytest.raises(ValueError, match="sequential st-HOSVD"):
                resolve_schedule((32, 32, 32), (4, 4, 4), methods="eig",
                                 variant=variant, mode_parallel="auto")

    def test_svd_member_rejected_from_group(self):
        with pytest.raises(ValueError, match="svd"):
            resolve_schedule((64, 16, 16), (4, 4, 4), methods="svd",
                             backend="sharded", n_shards=8, mode_parallel=2)

    def test_auto_groups_symmetric_shape(self):
        # symmetric dims: any sequential order pays the same first full-size
        # step PLUS shrunk follow-ups; the all-modes group pays only the max
        steps = resolve_schedule((32, 32, 32), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel="auto")
        assert [s.group for s in steps] == [0, 0, 0]
        # all shardable modes are inside the group → replicated execution
        assert all(s.shard_mode is None for s in steps)

    def test_iter_groups_batches_consecutive_ids(self):
        steps = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel=2)
        batches = list(iter_groups(steps))
        assert [len(b) for b in batches] == [2, 1]
        seq = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig")
        assert [len(b) for b in iter_groups(seq)] == [1, 1, 1]


# ---------------------------------------------------------------------------
# Grouped DP vs brute force over order × solver × grouping
# ---------------------------------------------------------------------------

def _ordered_groupings(n, max_group):
    """Every sequence of disjoint groups covering {0..n-1} (group execution
    order matters; membership within a group does not)."""
    def rec(remaining):
        if not remaining:
            yield ()
            return
        rem = sorted(remaining)
        for size in range(1, min(max_group, len(rem)) + 1):
            for g in itertools.combinations(rem, size):
                for rest in rec(remaining - set(g)):
                    yield (g,) + rest
    return rec(set(range(n)))


def _initial_state_peaks(shape, ranks, n_shards, max_group=3):
    """Modeled peak of every first transition (singleton or group) out of
    the un-shrunk state — the candidates a cap must beat to be feasible."""
    n = len(shape)
    peaks = []
    for grouping in _ordered_groupings(n, max_group):
        g = grouping[0]
        if len(g) == 1:
            peaks += [p for _, p, *_ in _priced_candidates(
                shape, ranks, None, 4, n_shards, list(shape), g[0])]
        else:
            peaks += [p for *_, p in _price_group(
                shape, ranks, None, 5, 4, n_shards, list(shape), g,
                DEFAULT_COST_MODEL)]
    return peaks


def brute_force_grouped(shape, ranks, *, methods=None, als_iters=5,
                        itemsize=4, n_shards=1, cap=None,
                        cm=DEFAULT_COST_MODEL, max_group=None):
    """Reference search: enumerate every ordered grouping × per-member
    solver assignment, priced through the SAME candidate generators the DP
    uses (the DP's recursion is what is under test, not the pricing)."""
    n = len(shape)
    if max_group is None:
        max_group = n
    best = None
    for grouping in _ordered_groupings(n, max_group):
        cur, lat_total, fl_total, ok = list(shape), 0.0, 0.0, True
        meths: list[str] = []
        for g in grouping:
            if len(g) == 1:
                cands = [(meth, peak,
                          step_cost(cm, meth, i, r, j, als_iters))
                         for meth, peak, i, r, j in _priced_candidates(
                             shape, ranks, methods, itemsize, n_shards,
                             cur, g[0])
                         if cap is None or peak <= cap]
                if not cands:
                    ok = False
                    break
                meth, _, c = min(cands, key=lambda t: t[2])
                lat_total += c
                fl_total += c
                meths.append(meth)
            else:
                cands = [(assign, lat, fl)
                         for assign, lat, fl, peak in _price_group(
                             shape, ranks, methods, als_iters, itemsize,
                             n_shards, cur, g, cm)
                         if cap is None or peak <= cap]
                if not cands:
                    ok = False
                    break
                assign, lat, fl = min(cands, key=lambda t: (t[1], t[2]))
                lat_total += lat
                fl_total += fl
                meths.extend(assign)
            for m in g:
                cur[m] = ranks[m]
        if ok and (best is None or
                   (lat_total, fl_total) < (best[0], best[1])):
            best = (lat_total, fl_total, grouping, tuple(meths))
    return best


class TestGroupedDP:
    SHAPES = [((32, 32, 32), (4, 4, 4)),
              ((64, 16, 16), (4, 4, 4)),
              ((30, 8, 22), (3, 6, 4)),
              ((24, 40, 16), (4, 5, 6))]

    @pytest.mark.parametrize("shape,ranks", SHAPES)
    @pytest.mark.parametrize("n_shards", [1, 8])
    def test_matches_brute_force(self, shape, ranks, n_shards):
        search = optimize_schedule(shape, ranks, n_shards=n_shards,
                                   max_group=3)
        ref = brute_force_grouped(shape, ranks, n_shards=n_shards)
        assert math.isclose(search.total_cost, ref[0], rel_tol=1e-9)

    @pytest.mark.parametrize("frac", [0.3, 0.6, 0.9, 1.2])
    def test_cap_feasibility_and_totals_agree(self, frac):
        shape, ranks, n_shards = (64, 16, 16), (4, 4, 4), 8
        cap = int(max(_initial_state_peaks(shape, ranks, n_shards)) * frac)
        ref = brute_force_grouped(shape, ranks, n_shards=n_shards, cap=cap)
        if ref is None:
            with pytest.raises(MemoryCapError):
                optimize_schedule(shape, ranks, n_shards=n_shards,
                                  max_group=3, memory_cap_bytes=cap)
        else:
            search = optimize_schedule(shape, ranks, n_shards=n_shards,
                                       max_group=3, memory_cap_bytes=cap)
            assert math.isclose(search.total_cost, ref[0], rel_tol=1e-9)

    def test_max_group_one_is_exactly_the_sequential_dp(self):
        for shape, ranks in self.SHAPES:
            seq = optimize_schedule(shape, ranks, n_shards=8)
            one = optimize_schedule(shape, ranks, n_shards=8, max_group=1)
            assert (one.order, one.methods, one.total_cost) == \
                (seq.order, seq.methods, seq.total_cost)
            assert all(len(g) == 1 for g in one.groups)

    def test_sequential_wins_exact_ties(self):
        # lexicographic (latency, flops): a group that merely TIES the
        # sequential latency must not displace it, because groups always
        # carry more total work (sum vs the telescoped sequential FLOPs)
        best, cur = {}, (8, 8)
        _relax(best, 1, 10.0, 5.0, 0, (0,), ("eig",), (4,), cur)
        _relax(best, 1, 10.0, 7.0, 0, (0, 1), ("eig", "eig"), (4, 4), cur)
        assert best[1][3] == (0,)            # equal latency, more flops: no
        _relax(best, 1, 10.0, 4.0, 0, (0, 1), ("eig", "als"), (4, 4), cur)
        assert best[1][3] == (0, 1)          # equal latency, fewer flops
        _relax(best, 1, 9.0, 99.0, 0, (1,), ("als",), (4,), cur)
        assert best[1][:2] == (9.0, 99.0)    # lower latency always wins

    def test_cap_forces_group_split(self):
        shape, ranks, n_shards = (32, 32, 32), (4, 4, 4), 8
        free = optimize_schedule(shape, ranks, methods=["eig"] * 3,
                                 n_shards=n_shards, max_group=3)
        assert any(len(g) == 3 for g in free.groups)
        # the all-modes group runs replicated; cap it out while leaving
        # sequential (sharded) steps and 2-groups feasible
        full_peak = next(
            peak for _, _, _, peak in _price_group(
                shape, ranks, ["eig"] * 3, 5, 4, n_shards, list(shape),
                (0, 1, 2), DEFAULT_COST_MODEL))
        capped = optimize_schedule(shape, ranks, methods=["eig"] * 3,
                                   n_shards=n_shards, max_group=3,
                                   memory_cap_bytes=full_peak - 1)
        assert all(len(g) < 3 for g in capped.groups)
        steps = resolve_schedule(shape, ranks, methods="eig",
                                 backend="sharded", n_shards=n_shards,
                                 mode_order="opt", mode_parallel="auto",
                                 memory_cap_bytes=full_peak - 1)
        assert all(s.peak_bytes <= full_peak - 1 for s in steps)

    def test_infeasible_cap_names_binding_group(self):
        shape, ranks, n_shards = (4, 4, 4096), (2, 2, 2), 1
        # cap below EVERY first transition: the search is dead at mask 0 and
        # the min-peak candidate there is the (0, 1) group — its shared
        # un-shrunk input beats any singleton's separate in+out slabs
        cap = min(_initial_state_peaks(shape, ranks, n_shards)) - 1
        with pytest.raises(MemoryCapError) as ei:
            optimize_schedule(shape, ranks, max_group=3,
                              memory_cap_bytes=cap)
        msg = str(ei.value)
        assert "is infeasible" in msg
        # on this shape the min-peak candidate IS a multi-mode group (the
        # shared-input model beats any singleton's in+out slabs), so the
        # error names the group
        assert "binding group — modes" in msg

    def test_sequential_infeasible_message_unchanged(self):
        # max_group=1 keeps the historical binding-STEP phrasing
        with pytest.raises(MemoryCapError, match="binding step — mode"):
            optimize_schedule((30, 8, 22), (3, 6, 4), memory_cap_bytes=100)

    def test_optimize_grouping_fixed_order(self):
        shape, ranks, n_shards = (64, 16, 16), (4, 4, 4), 8
        order = (2, 1, 0)
        search = optimize_grouping(shape, ranks, order, n_shards=n_shards)
        assert search.order == order
        assert tuple(m for g in search.groups for m in g) == order
        # reference: contiguous segmentations of the fixed order only
        best = None
        for grouping in _segmentations(order):
            cur, lat, fl, ok = list(shape), 0.0, 0.0, True
            for g in grouping:
                if len(g) == 1:
                    cs = [(step_cost(DEFAULT_COST_MODEL, meth, i, r, j, 5))
                          for meth, _, i, r, j in _priced_candidates(
                              shape, ranks, None, 4, n_shards, cur, g[0])]
                    c = min(cs)
                    lat += c
                    fl += c
                else:
                    cs = [(l, f) for _, l, f, _ in _price_group(
                        shape, ranks, None, 5, 4, n_shards, cur, g,
                        DEFAULT_COST_MODEL)]
                    l, f = min(cs)
                    lat += l
                    fl += f
                for m in g:
                    cur[m] = ranks[m]
            if ok and (best is None or (lat, fl) < best):
                best = (lat, fl)
        assert math.isclose(search.total_cost, best[0], rel_tol=1e-9)

    def test_grouping_respects_cap(self):
        shape, ranks = (32, 32, 32), (4, 4, 4)
        full_peak = next(peak for *_, peak in _price_group(
            shape, ranks, ["eig"] * 3, 5, 4, 8, list(shape), (0, 1, 2),
            DEFAULT_COST_MODEL))
        search = optimize_grouping(shape, ranks, (0, 1, 2),
                                   methods=["eig"] * 3, n_shards=8,
                                   memory_cap_bytes=full_peak - 1)
        assert all(len(g) < 3 for g in search.groups)


def _segmentations(order):
    n = len(order)
    for cuts in itertools.product([0, 1], repeat=n - 1):
        grouping, start = [], 0
        for i, c in enumerate(cuts, start=1):
            if c:
                grouping.append(tuple(order[start:i]))
                start = i
        grouping.append(tuple(order[start:]))
        yield grouping


# ---------------------------------------------------------------------------
# Plan plumbing: config serde, plan JSON, cache key, describe, peak model
# ---------------------------------------------------------------------------

class TestPlanPlumbing:
    def test_config_roundtrip_and_validation(self):
        for mp in ("off", "auto", 2):
            c = TuckerConfig(ranks=(2, 2, 2), methods="eig",
                             mode_parallel=mp)
            assert TuckerConfig.from_dict(c.to_dict()).mode_parallel == mp
        # legacy configs (no key) default sequential
        d = TuckerConfig(ranks=(2, 2, 2), methods="eig").to_dict()
        del d["mode_parallel"]
        assert TuckerConfig.from_dict(d).mode_parallel == "off"
        for bad in ("on", 0, True, 1.5):
            with pytest.raises(ValueError):
                TuckerConfig(ranks=(2, 2, 2), mode_parallel=bad)

    def test_modestep_roundtrip_keeps_group(self):
        steps = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel=2)
        for s in steps:
            assert ModeStep.from_dict(s.to_dict()) == s
        # pre-mode-parallel plan files load as sequential steps
        d = steps[0].to_dict()
        del d["group"]
        assert ModeStep.from_dict(d).group is None

    def test_plan_single_device_auto_is_silent_int_is_loud(self):
        p = plan((16, 16, 16), jnp.float32,
                 TuckerConfig(ranks=(4, 4, 4), methods="eig",
                              mode_parallel="auto"))
        assert all(s.group is None for s in p.schedule)
        with pytest.raises(ValueError, match="mesh"):
            plan((16, 16, 16), jnp.float32,
                 TuckerConfig(ranks=(4, 4, 4), methods="eig",
                              mode_parallel=2))

    def _grouped_plan(self):
        cfg = TuckerConfig(ranks=(4, 4, 4), methods="eig", mode_parallel=2)
        steps = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                                 backend="sharded", n_shards=8,
                                 mode_parallel=2)
        return TuckerPlan(shape=(64, 16, 16), dtype="float32", config=cfg,
                          schedule=steps)

    def test_plan_json_roundtrip_keeps_groups(self):
        p = self._grouped_plan()
        p2 = TuckerPlan.from_json(p.to_json())
        assert p2.schedule == p.schedule
        assert [s.group for s in p2.schedule] == [0, 0, None]
        assert p2.config.mode_parallel == 2

    def test_cache_key_distinguishes_grouping(self):
        p = self._grouped_plan()
        seq = resolve_schedule((64, 16, 16), (4, 4, 4), methods="eig",
                               backend="sharded", n_shards=8)
        ps = TuckerPlan(shape=(64, 16, 16), dtype="float32",
                        config=p.config, schedule=seq)
        assert p._cache_key(False) != ps._cache_key(False)

    def test_describe_marks_groups(self):
        text = self._grouped_plan().describe()
        assert "∥group=0" in text
        assert "mode_parallel=2" in text

    def test_peak_bytes_charges_dead_input_after_the_leading_group(self):
        p = self._grouped_plan()   # backend "sharded" → never donates
        assert not p.donates
        steps = p.schedule
        k0_peak = max(s.peak_bytes for s in steps[:2])
        tail = max(s.peak_bytes + p.input_bytes for s in steps[2:])
        assert p.peak_bytes == max(k0_peak, tail)


# ---------------------------------------------------------------------------
# End-to-end parity on 8 virtual devices (subprocess)
# ---------------------------------------------------------------------------

def test_mode_parallel_matches_sequential_all_solvers_and_dtypes():
    """Acceptance: mode-parallel execution is numerically equal (existing
    parity tolerances) to the sequential sweep for eig/als × fp32/bf16,
    covering replicated groups, sharded groups (fused Gram psum + fused
    multi-TTM), and mixed-solver groups."""
    run_in_subprocess("""
        from repro.core import TuckerConfig, plan, tensor_ops as T
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)

        def lowrank(dims, ranks):
            G = rng.standard_normal(ranks)
            Us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
                  for d, r in zip(dims, ranks)]
            return T.reconstruct(jnp.asarray(G, jnp.float32),
                                 [jnp.asarray(u, jnp.float32) for u in Us])

        cases = [((32, 32, 32), "auto"),   # replicated all-modes group
                 ((64, 16, 16), 2),        # sharded leading group
                 ((64, 16, 16), "auto")]
        for dims, mp in cases:
            X32 = lowrank(dims, (4, 4, 4))
            for dt, tol in ((jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)):
                X = X32.astype(dt)
                for methods in ("eig", "als"):
                    ref = plan(X.shape, X.dtype,
                               TuckerConfig(ranks=(4, 4, 4),
                                            methods=methods)).execute(X)
                    p = plan(X.shape, X.dtype,
                             TuckerConfig(ranks=(4, 4, 4), methods=methods,
                                          impl="sharded", mesh=mesh,
                                          mode_parallel=mp))
                    assert any(s.group is not None for s in p.schedule), \
                        (dims, mp, methods, p.schedule)
                    res = p.execute(X)
                    a = np.asarray(res.tucker.reconstruct(), np.float32)
                    b = np.asarray(ref.tucker.reconstruct(), np.float32)
                    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)
                    # factor subspace parity, sign/rotation-invariant
                    for u, v in zip(res.tucker.factors, ref.tucker.factors):
                        u32 = u.astype(jnp.float32)
                        v32 = v.astype(jnp.float32)
                        d = float(jnp.abs(u32 @ u32.T - v32 @ v32.T).max())
                        assert d < (1e-3 if dt == jnp.float32 else 3e-2), \
                            (dims, mp, methods, dt, d)
        # mixed-solver group: eig and als members share one group
        X = lowrank((64, 16, 16), (4, 4, 4))
        ref = plan(X.shape, X.dtype,
                   TuckerConfig(ranks=(4, 4, 4),
                                methods=("eig", "als", "eig"))).execute(X)
        p = plan(X.shape, X.dtype,
                 TuckerConfig(ranks=(4, 4, 4), methods=("eig", "als", "eig"),
                              impl="sharded", mesh=mesh, mode_parallel=2))
        assert [s.group for s in p.schedule] == [0, 0, None]
        res = p.execute(X)
        np.testing.assert_allclose(np.asarray(res.tucker.reconstruct()),
                                   np.asarray(ref.tucker.reconstruct()),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)


def test_mode_parallel_plan_reuse_zero_recompile():
    run_in_subprocess("""
        from repro.core import TuckerConfig, plan
        from repro.core import api as api_mod
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        X = jnp.asarray(rng.standard_normal((64, 16, 16)), jnp.float32)
        api_mod.clear_sweep_cache()
        cfg = TuckerConfig(ranks=(4, 4, 4), methods="eig", impl="sharded",
                           mesh=mesh, mode_parallel=2)
        p = plan(X.shape, X.dtype, cfg)
        for i in range(3):
            p.execute(X + float(i))
        assert api_mod.CACHE_STATS == {"builds": 1, "hits": 2, "traces": 1}, \
            api_mod.CACHE_STATS
        # a re-built plan (same config) shares the compiled sweep
        plan(X.shape, X.dtype, cfg).execute(X)
        assert api_mod.CACHE_STATS["builds"] == 1, api_mod.CACHE_STATS
        # the sequential plan is a DIFFERENT compiled program
        p_seq = plan(X.shape, X.dtype,
                     TuckerConfig(ranks=(4, 4, 4), methods="eig",
                                  impl="sharded", mesh=mesh))
        p_seq.execute(X)
        assert api_mod.CACHE_STATS["builds"] == 2, api_mod.CACHE_STATS
        print("OK")
    """)


def test_distributed_wrapper_takes_mode_parallel():
    run_in_subprocess("""
        from repro.core.distributed import sthosvd_distributed
        from repro.core import tensor_ops as T
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        G = jnp.asarray(rng.standard_normal((4, 4, 4)), jnp.float32)
        Us = [jnp.asarray(np.linalg.qr(rng.standard_normal((d, 4)))[0],
                          jnp.float32) for d in (64, 16, 16)]
        X = T.reconstruct(G, Us)   # exact rank-(4,4,4): both sweeps recover it
        seq = sthosvd_distributed(X, (4, 4, 4), mesh, methods="eig")
        par = sthosvd_distributed(X, (4, 4, 4), mesh, methods="eig",
                                  mode_parallel=2)
        assert all(t.seconds > 0 for t in par.trace)
        e1 = float(seq.tucker.rel_error(X))
        e2 = float(par.tucker.rel_error(X))
        assert e1 < 1e-4 and e2 < 1e-4, (e1, e2)
        print("OK")
    """)
