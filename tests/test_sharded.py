"""Sharded ops backend: pick_shard_mode edges, shard-aware schedule
resolution and plan JSON (in-process, no mesh needed), and end-to-end
multi-device execution parity (subprocess with 8 virtual CPU devices, per
the launch contract in dryrun.py — the main pytest process keeps its own
device view)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.core import TuckerConfig, TuckerPlan, mesh_from_spec, mesh_spec, plan
from repro.core.backend import get_backend, resolve_backend
from repro.core.distributed import pick_shard_mode
from repro.core.plan import resolve_schedule

REPO = Path(__file__).resolve().parent.parent


def run_in_subprocess(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


# ---------------------------------------------------------------------------
# pick_shard_mode edge cases (pure function, no devices involved)
# ---------------------------------------------------------------------------

class TestPickShardMode:
    def test_picks_largest_divisible_mode(self):
        assert pick_shard_mode((24, 40, 16), exclude=0, n_shards=8) == 1

    def test_largest_mode_excluded_falls_to_next(self):
        # mode 0 is the largest but is being solved; next largest divisible
        assert pick_shard_mode((64, 16, 8), exclude=0, n_shards=8) == 1
        # largest mode excluded AND runner-up not divisible
        assert pick_shard_mode((64, 15, 8), exclude=0, n_shards=8) == 2

    def test_no_mode_divisible_replicates(self):
        assert pick_shard_mode((5, 7, 9), exclude=0, n_shards=4) is None
        # divisible mode exists but is the excluded one
        assert pick_shard_mode((8, 7, 9), exclude=0, n_shards=8) is None

    def test_late_shrunk_modes_fall_back_to_replication(self):
        # st-HOSVD end state: earlier modes already shrunk to tiny ranks
        assert pick_shard_mode((4, 5, 16), exclude=2, n_shards=8) is None

    def test_single_shard_always_shards(self):
        # n_shards=1 divides everything: largest non-excluded mode wins
        assert pick_shard_mode((3, 5, 7), exclude=2, n_shards=1) == 1


# ---------------------------------------------------------------------------
# Shard-aware schedule resolution (n_shards plumbing, per-device peak_bytes)
# ---------------------------------------------------------------------------

class TestShardedSchedule:
    def test_shard_modes_follow_the_shrinking_tensor(self):
        steps = resolve_schedule((24, 40, 16), (4, 5, 6), methods="eig",
                                 backend="sharded", n_shards=8)
        assert [s.shard_mode for s in steps] == [1, 2, None]
        assert [s.n_shards for s in steps] == [8, 8, 1]

    def test_peak_bytes_divide_by_shard_count(self):
        single = resolve_schedule((64, 48, 40), (8, 8, 8), methods="eig")
        shard = resolve_schedule((64, 48, 40), (8, 8, 8), methods="eig",
                                 backend="sharded", n_shards=8)
        s1, s8 = single[0], shard[0]
        # I/O slabs divide by 8; the replicated Gram scratch does not
        io1 = (s1.i_n * s1.j_n + s1.r_n * s1.j_n) * 4
        assert s8.peak_bytes == io1 // 8 + s1.i_n * s1.i_n * 4
        assert s8.peak_bytes < s1.peak_bytes

    def test_replicated_steps_keep_single_device_model(self):
        steps = resolve_schedule((5, 7, 9), (2, 2, 2), methods="eig",
                                 backend="sharded", n_shards=4)
        ref = resolve_schedule((5, 7, 9), (2, 2, 2), methods="eig")
        assert all(s.shard_mode is None and s.n_shards == 1 for s in steps)
        assert [s.peak_bytes for s in steps] == [s.peak_bytes for s in ref]

    def test_svd_steps_never_shard(self):
        steps = resolve_schedule((24, 40, 16), (4, 5, 6), methods="svd",
                                 backend="sharded", n_shards=8)
        assert all(s.shard_mode is None and s.n_shards == 1 for s in steps)

    def test_sharded_rejects_non_sthosvd_variants(self):
        with pytest.raises(ValueError, match="sthosvd"):
            resolve_schedule((8, 8, 8), (2, 2, 2), methods="eig",
                             variant="thosvd", backend="sharded", n_shards=4)

    def test_modestep_dict_roundtrip_keeps_shard_fields(self):
        from repro.core.plan import ModeStep
        steps = resolve_schedule((24, 40, 16), (4, 5, 6), methods="eig",
                                 backend="sharded", n_shards=8)
        for s in steps:
            assert ModeStep.from_dict(s.to_dict()) == s
        # pre-sharding plan files load with replicated defaults
        d = steps[0].to_dict()
        del d["shard_mode"], d["n_shards"]
        s = ModeStep.from_dict(d)
        assert s.shard_mode is None and s.n_shards == 1


# ---------------------------------------------------------------------------
# Backend registry + config validation (no multi-device mesh needed)
# ---------------------------------------------------------------------------

class TestShardedBackendRegistry:
    def test_registered_with_capabilities(self):
        b = get_backend("sharded")
        assert b.requires_mesh and not b.matricizes
        assert b.native_on("cpu") and b.native_on("tpu")

    def test_explicit_name_without_mesh_rejected(self):
        with pytest.raises(ValueError, match="requires a mesh"):
            resolve_backend("sharded")

    def test_auto_without_mesh_never_picks_sharded(self):
        assert resolve_backend("auto", platform="cpu").name == "matfree"

    def test_plan_without_mesh_rejected(self):
        with pytest.raises(ValueError, match="requires a mesh"):
            plan((8, 8, 8), jnp.float32,
                 TuckerConfig(ranks=(2, 2, 2), methods="eig", impl="sharded"))

    def test_shard_axis_must_be_a_mesh_axis(self):
        mesh = jax.make_mesh((1,), ("data",))
        with pytest.raises(ValueError, match="shard_axis"):
            TuckerConfig(ranks=(2, 2, 2), mesh=mesh, shard_axis="model",
                         impl="sharded")

    def test_mesh_with_single_device_impl_rejected(self):
        # a mesh the backend would silently ignore is a contradiction: the
        # user attached it precisely because one device is not enough
        mesh = jax.make_mesh((1,), ("data",))
        for impl in ("matfree", "explicit", "pallas"):
            with pytest.raises(ValueError, match="single device"):
                TuckerConfig(ranks=(2, 2, 2), mesh=mesh, impl=impl)
        # mesh-capable impls accept it
        TuckerConfig(ranks=(2, 2, 2), mesh=mesh, impl="sharded")
        TuckerConfig(ranks=(2, 2, 2), mesh=mesh, impl="auto")

    def test_engine_drops_mesh_for_single_device_pin(self):
        from repro.serve import TuckerBatchEngine
        mesh = jax.make_mesh((1,), ("data",))
        eng = TuckerBatchEngine(impl="matfree", mesh=mesh)
        cfg = eng._pinned(TuckerConfig(ranks=(2, 2, 2), methods="eig"))
        assert cfg.impl == "matfree" and cfg.mesh is None
        # no explicit impl: a mesh pins the sharded backend
        eng = TuckerBatchEngine(mesh=mesh)
        cfg = eng._pinned(TuckerConfig(ranks=(2, 2, 2), methods="eig"))
        assert cfg.impl == "sharded" and cfg.mesh is mesh

    def test_sharded_variant_guard_at_plan_time(self):
        mesh = jax.make_mesh((1,), ("data",))
        with pytest.raises(ValueError, match="sthosvd"):
            plan((8, 8, 8), jnp.float32,
                 TuckerConfig(ranks=(2, 2, 2), methods="eig", variant="thosvd",
                              impl="sharded", mesh=mesh))


# ---------------------------------------------------------------------------
# Mesh spec + plan JSON roundtrip (1-device mesh works on any host)
# ---------------------------------------------------------------------------

class TestMeshSerialization:
    def test_mesh_spec_roundtrip(self):
        mesh = jax.make_mesh((1,), ("data",))
        spec = mesh_spec(mesh)
        assert spec == {"axis_names": ["data"], "shape": [1]}
        rebuilt = mesh_from_spec(spec)
        assert rebuilt is not None
        assert rebuilt.axis_names == ("data",) and rebuilt.shape["data"] == 1
        assert mesh_spec(None) is None and mesh_from_spec(None) is None

    def test_oversized_spec_degrades_to_none(self):
        assert mesh_from_spec(
            {"axis_names": ["data"], "shape": [10 ** 6]}) is None

    def test_config_dict_roundtrip_with_mesh(self):
        mesh = jax.make_mesh((1,), ("data",))
        c = TuckerConfig(ranks=(2, 2, 2), methods="eig", impl="sharded",
                         mesh=mesh, shard_axis="data")
        c2 = TuckerConfig.from_dict(c.to_dict())
        assert c2.shard_axis == "data" and c2.impl == "sharded"
        assert mesh_spec(c2.mesh) == mesh_spec(mesh)

    def test_plan_json_roundtrip_and_execute_on_one_device_mesh(self, tmp_path):
        import numpy as np
        mesh = jax.make_mesh((1,), ("data",))
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((8, 7, 6)), jnp.float32)
        cfg = TuckerConfig(ranks=(2, 3, 2), methods="eig", impl="sharded",
                           mesh=mesh)
        p = plan(x.shape, x.dtype, cfg)
        assert p.backend == "sharded"
        path = tmp_path / "p.json"
        p.save(path)
        p2 = TuckerPlan.load(path)
        assert p2.schedule == p.schedule
        assert p2.config.shard_axis == cfg.shard_axis
        assert mesh_spec(p2.config.mesh) == mesh_spec(mesh)
        r1, r2 = p.execute(x), p2.execute(x)
        np.testing.assert_allclose(np.asarray(r1.tucker.core),
                                   np.asarray(r2.tucker.core),
                                   rtol=1e-6, atol=1e-6)
        # a 1-device mesh is degenerate sharding: parity with plain matfree
        ref = plan(x.shape, x.dtype,
                   TuckerConfig(ranks=(2, 3, 2), methods="eig")).execute(x)
        np.testing.assert_allclose(np.asarray(r1.tucker.reconstruct()),
                                   np.asarray(ref.tucker.reconstruct()),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# End-to-end on 8 virtual devices (subprocess)
# ---------------------------------------------------------------------------

def test_sharded_plan_matches_matfree_all_methods():
    """Acceptance: plan(..., impl="sharded") and impl="auto"+mesh execute on
    an 8-device mesh with results allclose to single-device matfree, zero
    recompiles on plan reuse."""
    run_in_subprocess("""
        from repro.core import TuckerConfig, plan, tensor_ops as T
        from repro.core import api as api_mod
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        G = rng.standard_normal((4,5,6))
        Us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
              for d, r in zip((24,40,16),(4,5,6))]
        X = T.reconstruct(jnp.asarray(G, jnp.float32),
                          [jnp.asarray(u, jnp.float32) for u in Us])
        X = X + 0.001*jnp.asarray(rng.standard_normal(X.shape), jnp.float32)
        for methods in ("eig", "als", "auto"):
            ref = plan(X.shape, X.dtype,
                       TuckerConfig(ranks=(4,5,6), methods=methods)).execute(X)
            p = plan(X.shape, X.dtype,
                     TuckerConfig(ranks=(4,5,6), methods=methods,
                                  impl="sharded", mesh=mesh))
            assert p.backend == "sharded"
            assert p.schedule[0].n_shards == 8, p.schedule
            res = p.execute(X)
            # same frozen solver schedule on both sides
            assert res.methods == ref.methods, (res.methods, ref.methods)
            np.testing.assert_allclose(np.asarray(res.tucker.reconstruct()),
                                       np.asarray(ref.tucker.reconstruct()),
                                       rtol=2e-3, atol=2e-3)
            e1 = float(ref.tucker.rel_error(X)); e2 = float(res.tucker.rel_error(X))
            assert abs(e1 - e2) < 1e-4, (methods, e1, e2)
            # factor subspace parity, sign/rotation-invariant
            for a, b in zip(ref.tucker.factors, res.tucker.factors):
                pa, pb = a @ a.T, b @ b.T
                assert float(jnp.abs(pa - pb).max()) < 1e-3, methods
        # impl="auto" with a mesh resolves to sharded
        p = plan(X.shape, X.dtype, TuckerConfig(ranks=(4,5,6), methods="eig",
                                                impl="auto", mesh=mesh))
        assert p.backend == "sharded"
        # zero recompiles / selections on reuse
        api_mod.clear_sweep_cache()
        p = plan(X.shape, X.dtype, TuckerConfig(ranks=(4,5,6), methods="eig",
                                                impl="sharded", mesh=mesh))
        for i in range(3):
            p.execute(X + float(i))
        assert api_mod.CACHE_STATS == {"builds": 1, "hits": 2, "traces": 1}, \
            api_mod.CACHE_STATS
        print("OK")
    """)


def test_sharded_plan_json_roundtrip_rebuilds_mesh():
    run_in_subprocess("""
        from repro.core import TuckerConfig, TuckerPlan, mesh_spec, plan
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        X = jnp.asarray(rng.standard_normal((24, 40, 16)), jnp.float32)
        p = plan(X.shape, X.dtype,
                 TuckerConfig(ranks=(4,5,6), methods="eig", impl="sharded",
                              mesh=mesh))
        p2 = TuckerPlan.from_json(p.to_json())
        assert p2.schedule == p.schedule
        assert [s.shard_mode for s in p2.schedule] == [1, 2, None]
        assert mesh_spec(p2.config.mesh) == {"axis_names": ["data"],
                                             "shape": [8]}
        r1, r2 = p.execute(X), p2.execute(X)
        np.testing.assert_allclose(np.asarray(r1.tucker.core),
                                   np.asarray(r2.tucker.core),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)


def test_distributed_wrapper_records_real_wall_clock():
    """Satellite: sthosvd_distributed no longer hardcodes 0.0 seconds."""
    run_in_subprocess("""
        from repro.core.distributed import sthosvd_distributed
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        X = jnp.asarray(rng.standard_normal((24, 40, 16)), jnp.float32)
        for methods in ("eig", "als", "auto"):
            res = sthosvd_distributed(X, (4, 5, 6), mesh, methods=methods)
            assert all(t.seconds > 0 for t in res.trace), \
                (methods, [t.seconds for t in res.trace])
            assert all(t.backend == "sharded" for t in res.trace)
            assert res.tucker.core.shape == (4, 5, 6)
        print("OK")
    """)


def test_engine_executes_sharded_with_mesh():
    run_in_subprocess("""
        from repro.core import TuckerConfig
        from repro.serve import TuckerBatchEngine, TuckerRequest
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(3)
        eng = TuckerBatchEngine(mesh=mesh)
        cfg = TuckerConfig(ranks=(4, 5, 6), methods="eig")
        reqs = [TuckerRequest(
                    x=jnp.asarray(rng.standard_normal((24, 40, 16)),
                                  jnp.float32),
                    config=cfg, rid=s) for s in range(4)]
        eng.run(reqs)
        assert all(r.result is not None for r in reqs)
        assert eng.stats["backends"] == {"sharded": 4}, eng.stats
        assert eng.stats["plans_built"] == 1    # one plan for the group
        assert eng.stats["batches"] == 1
        for r in reqs:
            assert float(r.result.tucker.rel_error(r.x)) < 1.0
        print("OK")
    """)


# ---------------------------------------------------------------------------
# Plan-time schedule search against the PER-DEVICE peak model
# ---------------------------------------------------------------------------

class TestShardedScheduleSearch:
    def test_opt_schedule_resolves_with_per_device_peaks(self):
        # no mesh needed: resolve_schedule(n_shards=8) is pure bookkeeping
        steps = resolve_schedule((64, 48, 40), (8, 6, 5), methods="eig",
                                 mode_order="opt", backend="sharded",
                                 n_shards=8)
        assert sorted(s.mode for s in steps) == [0, 1, 2]
        assert steps[0].n_shards == 8    # first step shards the full tensor

    def test_per_device_cap_feasible_only_when_sharded(self):
        from repro.core.schedule_opt import MemoryCapError, optimize_schedule

        shape, ranks = (64, 48, 40), (8, 6, 5)
        single = optimize_schedule(shape, ranks, methods=["eig"] * 3)
        # tightest single-device bottleneck: any order's worst step io
        steps1 = resolve_schedule(shape, ranks, methods="eig",
                                  mode_order="opt")
        cap = max(s.peak_bytes for s in steps1) // 4
        with pytest.raises(MemoryCapError):
            optimize_schedule(shape, ranks, methods=["eig"] * 3,
                              memory_cap_bytes=cap)
        # the same cap fits once the io slabs divide over 8 devices
        sharded = optimize_schedule(shape, ranks, methods=["eig"] * 3,
                                    n_shards=8, memory_cap_bytes=cap)
        assert sharded.order is not None
        steps8 = resolve_schedule(shape, ranks, methods="eig",
                                  mode_order="opt", backend="sharded",
                                  n_shards=8, memory_cap_bytes=cap)
        assert all(s.peak_bytes <= cap for s in steps8)

    def test_opt_plan_executes_on_mesh(self):
        run_in_subprocess("""
            from repro.core import TuckerConfig, plan, tensor_ops as T
            mesh = jax.make_mesh((8,), ("data",))
            rng = np.random.default_rng(0)
            G = rng.standard_normal((4, 5, 6))
            Us = [np.linalg.qr(rng.standard_normal((d, r)))[0]
                  for d, r in zip((24, 40, 16), (4, 5, 6))]
            X = T.reconstruct(jnp.asarray(G, jnp.float32),
                              [jnp.asarray(u, jnp.float32) for u in Us])
            ref = plan(X.shape, X.dtype,
                       TuckerConfig(ranks=(4, 5, 6), methods="eig")).execute(X)
            p = plan(X.shape, X.dtype,
                     TuckerConfig(ranks=(4, 5, 6), methods="eig",
                                  mode_order="opt", impl="sharded",
                                  mesh=mesh,
                                  memory_cap_bytes=64 * 1024 * 1024))
            assert p.backend == "sharded"
            res = p.execute(X)
            err = float(res.tucker.rel_error(X))
            ref_err = float(ref.tucker.rel_error(X))
            assert abs(err - ref_err) < 1e-3, (err, ref_err)
            # sharded sweeps must never donate (shard_map aliasing guard)
            assert p.donates is False
        """)

    def test_distributed_wrapper_takes_mode_order_and_cap(self):
        run_in_subprocess("""
            from repro.core.distributed import sthosvd_distributed
            from repro.core.schedule_opt import MemoryCapError
            mesh = jax.make_mesh((8,), ("data",))
            X = jnp.asarray(np.random.default_rng(0)
                            .standard_normal((24, 40, 16)), jnp.float32)
            res = sthosvd_distributed(X, (4, 5, 6), mesh, methods="eig",
                                      mode_order="opt")
            assert float(res.tucker.rel_error(X)) < 1.0
            try:
                sthosvd_distributed(X, (4, 5, 6), mesh, methods="eig",
                                    memory_cap_bytes=1000)
            except MemoryCapError as e:
                assert "bytes" in str(e)
            else:
                raise AssertionError("cap should have been infeasible")
        """)


# ---------------------------------------------------------------------------
# Plan derivation (for_shape) on sharded plans — the serve-layer reuse hook
# ---------------------------------------------------------------------------

def test_for_shape_rederives_sharded_plans():
    run_in_subprocess("""
        from repro.core import TuckerConfig, mesh_spec, plan
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(4)
        base = plan((24, 40, 16), jnp.float32,
                    TuckerConfig(ranks=(4, 5, 6), methods="eig",
                                 impl="sharded", mesh=mesh))
        assert [s.shard_mode for s in base.schedule] == [1, 2, None]

        # derived plan keeps the mesh and matches a direct plan exactly
        d = base.for_shape((24, 40, 15))
        assert mesh_spec(d.config.mesh) == mesh_spec(mesh)
        direct = plan((24, 40, 15), jnp.float32, base.config)
        assert d.schedule == direct.schedule
        assert d.backend == "sharded"

        # shard participation RE-resolves for the new dims: with mode 0
        # already shrunk to rank 4, neither 4 nor 15 divides over the 8
        # devices, so the mode-1 solve replicates instead of sharding mode 2
        assert [s.shard_mode for s in d.schedule] == [1, None, None]

        X = jnp.asarray(rng.standard_normal((24, 40, 15)), jnp.float32)
        r1, r2 = d.execute(X), direct.execute(X)
        np.testing.assert_allclose(np.asarray(r1.tucker.core),
                                   np.asarray(r2.tucker.core),
                                   rtol=1e-5, atol=1e-5)

        # keep_methods pins the bucket plan's solvers and sweep order
        auto = plan((24, 40, 16), jnp.float32,
                    TuckerConfig(ranks=(4, 5, 6), methods=("als", "eig",
                                                           "als"),
                                 impl="sharded", mesh=mesh))
        pinned = auto.for_shape((24, 40, 15), keep_methods=True)
        assert pinned.methods == auto.methods
        assert [s.mode for s in pinned.schedule] == \\
            [s.mode for s in auto.schedule]

        # same-shape derivation is the identity (no replanning)
        assert base.for_shape((24, 40, 16)) is base
        print("OK")
    """)
