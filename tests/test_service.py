"""Streaming serve subsystem: buckets, padding parity, backpressure, async.

The load-bearing assertion lives in ``TestPaddingBitwise``: a request padded
up to a shape bucket must come back **bitwise-equal** to its unpadded
singleton execution (exact pad mode routes the sliced valid block through
the plan the TRUE shape resolves to — the identical cached compiled sweep a
direct ``decompose`` runs).  Mask mode's contract is weaker (exactly-zero
slack rows, same reconstruction quality) and is tested separately.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.core.api import (
    CACHE_STATS,
    TuckerConfig,
    decompose,
    plan as make_plan,
)
from repro.core.schedule_opt import MemoryCapError
from repro.serve import (
    BucketPolicy,
    RejectedError,
    ServiceClosed,
    TuckerBatchEngine,
    TuckerRequest,
    TuckerService,
    pad_block,
    pad_waste,
    slice_valid,
    trim_result,
)


def tensor(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def parts(res):
    return [res.tucker.core, *res.tucker.factors]


def bitwise_equal(a, b):
    return all(x.dtype == y.dtype and bool(jnp.array_equal(x, y))
               for x, y in zip(parts(a), parts(b)))


CFG = TuckerConfig(ranks=(3, 3, 3), methods="eig")


# ---------------------------------------------------------------------------
# bucket policy
# ---------------------------------------------------------------------------

class TestBucketPolicy:
    def test_rounds_each_dim_up_to_grid(self):
        pol = BucketPolicy(grid=8, max_pad_ratio=10.0)
        assert pol.bucket_shape((13, 10, 9)) == (16, 16, 16)
        assert pol.bucket_shape((16, 8, 24)) == (16, 8, 24)

    def test_per_mode_grid(self):
        pol = BucketPolicy(grid=(4, 8, 16), max_pad_ratio=10.0)
        assert pol.bucket_shape((5, 5, 5)) == (8, 8, 16)
        with pytest.raises(ValueError):
            pol.bucket_shape((5, 5, 5, 5))   # no grid entry for mode 3

    def test_max_pad_ratio_falls_back_to_exact_bucket(self):
        # (9, 9, 9) -> (16, 16, 16) would be 5.6x the elements: sliver keeps
        # its own exact bucket instead of burning memory on slack
        pol = BucketPolicy(grid=8, max_pad_ratio=2.0)
        assert pol.bucket_shape((9, 9, 9)) == (9, 9, 9)
        assert pol.bucket_shape((15, 14, 13)) == (16, 16, 16)  # 1.5x: ok

    def test_exact_policy_is_identity(self):
        pol = BucketPolicy.exact()
        assert pol.bucket_shape((13, 10, 9)) == (13, 10, 9)
        assert pol.wave_slots is None
        assert pol.lanes_for(5) == 5

    def test_lane_pow2_rounds_up_and_caps_at_wave_slots(self):
        pol = BucketPolicy(wave_slots=8)
        assert [pol.lanes_for(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]

    def test_validation(self):
        with pytest.raises(ValueError):
            BucketPolicy(grid=0)
        with pytest.raises(ValueError):
            BucketPolicy(pad_mode="clip")
        with pytest.raises(ValueError):
            BucketPolicy(max_pad_ratio=0.5)
        with pytest.raises(ValueError):
            BucketPolicy(wave_slots=0)

    def test_pad_slice_roundtrip_is_bitwise_lossless(self):
        x = tensor((7, 6, 5), seed=3)
        padded = pad_block(x, (8, 8, 8))
        assert padded.shape == (8, 8, 8)
        assert bool(jnp.array_equal(slice_valid(padded, x.shape), x))
        assert pad_waste(x.shape, (8, 8, 8)) == pytest.approx(1 - 210 / 512)
        with pytest.raises(ValueError):
            pad_block(x, (6, 8, 8))   # does not fit


# ---------------------------------------------------------------------------
# padding parity (the acceptance-criteria assertion)
# ---------------------------------------------------------------------------

class TestPaddingBitwise:
    @pytest.mark.parametrize("method,dtype", [
        ("eig", "float32"), ("als", "float32"),
        ("eig", "bfloat16"), ("als", "bfloat16"),
    ])
    @given(dims=st.tuples(st.integers(9, 15), st.integers(9, 15),
                          st.integers(9, 15)))
    def test_padded_request_bitwise_equals_unpadded_execution(
            self, method, dtype, dims):
        cfg = TuckerConfig(ranks=(3, 3, 3), methods=(method,) * 3)
        x = tensor(dims, seed=sum(dims), dtype=jnp.dtype(dtype))
        svc = TuckerService(policy=BucketPolicy(grid=8, max_pad_ratio=8.0))
        t = svc.submit(x, cfg)
        assert t.bucket == (16, 16, 16) and t.padded == (dims != (16,) * 3)
        svc.drain()
        res = svc.poll(t)
        ref = decompose(x, cfg)   # unpadded singleton execution
        assert bitwise_equal(res, ref)

    def test_padded_and_exact_members_mix_in_one_bucket(self):
        svc = TuckerService(policy=BucketPolicy(grid=8, max_pad_ratio=8.0))
        xs = [tensor((16, 16, 16), seed=1), tensor((12, 11, 10), seed=2),
              tensor((16, 16, 16), seed=3), tensor((9, 16, 13), seed=4)]
        ts = [svc.submit(x, CFG) for x in xs]
        svc.drain()
        for x, t in zip(xs, ts):
            assert bitwise_equal(svc.poll(t), decompose(x, CFG))
        st_ = svc.stats()
        assert st_["requests"] == 4 and st_["n_buckets"] == 1
        (bucket,) = st_["buckets"].values()
        assert bucket["padded"] == 2
        assert 0.0 < bucket["pad_waste"] < 1.0


# ---------------------------------------------------------------------------
# mask mode (throughput path: one vmapped wave, trimmed factors)
# ---------------------------------------------------------------------------

class TestMaskMode:
    @pytest.mark.parametrize("method", ["eig", "als"])
    def test_slack_rows_come_back_exactly_zero(self, method):
        cfg = TuckerConfig(ranks=(3, 3, 3), methods=(method,) * 3)
        x = tensor((13, 12, 11), seed=5)
        p = make_plan((16, 16, 16), x.dtype, cfg)
        res = p.execute(pad_block(x, (16, 16, 16)))
        for u, s in zip(res.tucker.factors, x.shape):
            assert bool(jnp.all(u[s:] == 0.0))   # zero slack propagates

    def test_mixed_wave_fuses_and_matches_unpadded_quality(self):
        svc = TuckerService(policy=BucketPolicy(grid=8, max_pad_ratio=8.0,
                                                pad_mode="mask"))
        xs = [tensor((13, 12, 11), seed=6), tensor((16, 16, 16), seed=7),
              tensor((10, 15, 9), seed=8)]
        ts = [svc.submit(x, CFG) for x in xs]
        svc.drain()
        st_ = svc.stats()
        assert st_["batches"] == 1          # the whole mixed wave fused
        for x, t in zip(xs, ts):
            res = svc.poll(t)
            for u, s in zip(res.tucker.factors, x.shape):
                assert u.shape[0] == s      # trimmed to the true shape
                # trimmed factors keep orthonormal columns
                g = u.T @ u
                assert float(jnp.max(jnp.abs(g - jnp.eye(g.shape[0])))) < 1e-4
            ref = decompose(x, CFG)
            assert float(res.tucker.rel_error(x)) < \
                float(ref.tucker.rel_error(x)) + 1e-4

    def test_trim_result_preserves_trace(self):
        x = tensor((13, 12, 11), seed=9)
        p = make_plan((16, 16, 16), x.dtype, CFG)
        res = p.execute(pad_block(x, (16, 16, 16)))
        trimmed = trim_result(res, x.shape)
        assert trimmed.tucker.core.shape == res.tucker.core.shape
        assert trimmed.trace is res.trace


# ---------------------------------------------------------------------------
# plan reuse hook
# ---------------------------------------------------------------------------

class TestForShape:
    def test_default_matches_direct_plan(self):
        base = make_plan((16, 16, 16), jnp.float32, CFG)
        derived = base.for_shape((13, 12, 11))
        direct = make_plan((13, 12, 11), jnp.float32, CFG)
        assert derived.shape == (13, 12, 11)
        assert derived.schedule == direct.schedule
        assert derived._cache_key(False) == direct._cache_key(False)

    def test_same_shape_returns_self(self):
        base = make_plan((16, 16, 16), jnp.float32, CFG)
        assert base.for_shape((16, 16, 16)) is base

    def test_keep_methods_pins_bucket_solvers_and_order(self):
        cfg = TuckerConfig(ranks=(3, 3, 3), methods=("als", "eig", "als"),
                           mode_order=(2, 0, 1))
        base = make_plan((16, 16, 16), jnp.float32, cfg)
        derived = base.for_shape((12, 11, 10), keep_methods=True)
        assert derived.methods == base.methods
        assert tuple(s.mode for s in derived.schedule) == \
            tuple(s.mode for s in base.schedule)

    def test_order_mismatch_raises(self):
        base = make_plan((16, 16, 16), jnp.float32, CFG)
        with pytest.raises(ValueError):
            base.for_shape((16, 16))


# ---------------------------------------------------------------------------
# admission: backpressure, validation, lifecycle
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_reject_policy_raises_and_counts(self):
        svc = TuckerService(max_queue=2)
        x = tensor((8, 8, 8))
        svc.submit(x, CFG)
        svc.submit(x, CFG)
        with pytest.raises(RejectedError):
            svc.submit(x, CFG)
        assert svc.stats()["rejected"] == 1
        svc.drain()
        svc.submit(x, CFG)   # space again after the wave completed
        svc.drain()
        assert svc.stats()["requests"] == 3

    def test_block_policy_pumps_inline_without_worker(self):
        svc = TuckerService(max_queue=1, backpressure="block")
        x = tensor((8, 8, 8))
        ts = [svc.submit(x, CFG) for _ in range(3)]   # each submit frees space
        svc.drain()
        assert all(svc.poll(t) is not None for t in ts)

    def test_bad_ranks_fail_at_submit(self):
        svc = TuckerService()
        with pytest.raises(ValueError):
            svc.submit(tensor((8, 8, 8)), TuckerConfig(ranks=(9, 2, 2)))
        assert svc.stats()["submitted"] == 0

    def test_closed_service_refuses_submissions(self):
        svc = TuckerService()
        t = svc.submit(tensor((8, 8, 8)), CFG)
        svc.close()
        assert svc.poll(t) is not None   # close() drained
        with pytest.raises(ServiceClosed):
            svc.submit(tensor((8, 8, 8)), CFG)

    def test_plan_failure_surfaces_through_poll(self):
        svc = TuckerService(memory_cap_bytes=64)   # nothing fits 64 bytes
        t = svc.submit(tensor((8, 8, 8)), CFG)
        svc.drain()
        with pytest.raises(MemoryCapError):
            svc.poll(t)
        assert svc.stats()["failed"] == 1

    def test_wave_slots_bound_batch_size(self):
        svc = TuckerService(policy=BucketPolicy(grid=1, wave_slots=2,
                                                lane_pow2=False))
        ts = [svc.submit(tensor((8, 8, 8), seed=i), CFG) for i in range(5)]
        svc.drain()
        assert svc.stats()["batches"] == 3   # ceil(5 / 2)
        assert all(svc.poll(t) is not None for t in ts)


# ---------------------------------------------------------------------------
# async worker
# ---------------------------------------------------------------------------

class TestAsync:
    def test_submit_poll_wait_through_worker(self, tmp_path):
        trace = tmp_path / "trace.jsonl"
        with TuckerService(policy=BucketPolicy(grid=8, max_pad_ratio=8.0),
                           max_queue=64, trace_path=trace) as svc:
            svc.start()
            xs = [tensor((13, 12, 11), seed=i) for i in range(5)]
            ts = [svc.submit(x, CFG) for x in xs]
            res = [svc.wait(t, timeout=120) for t in ts]
            assert all(r is not None for r in res)
            for x, r in zip(xs, res):
                assert bitwise_equal(r, decompose(x, CFG))
            st_ = svc.stats()
            assert st_["requests"] == 5 and st_["pending"] == 0
            assert st_["latency"]["p95_ms"] > 0.0
        kinds = [json.loads(l)["kind"] for l in trace.read_text().splitlines()]
        assert kinds.count("submit") == 5 and kinds.count("done") == 5
        assert "wave" in kinds

    def test_block_backpressure_against_worker(self):
        with TuckerService(max_queue=2, backpressure="block") as svc:
            svc.start()
            ts = [svc.submit(tensor((8, 8, 8), seed=i), CFG)
                  for i in range(6)]   # submits block until the worker frees space
            assert all(svc.wait(t, timeout=120) is not None for t in ts)

    def test_stop_drains_by_default(self):
        svc = TuckerService()
        svc.start()
        t = svc.submit(tensor((8, 8, 8)), CFG)
        svc.stop()
        assert svc.poll(t) is not None


# ---------------------------------------------------------------------------
# engine compatibility wrapper
# ---------------------------------------------------------------------------

class TestEngineParity:
    def test_results_and_stats_match_pre_service_engine(self):
        """The rewired engine must reproduce the old run() exactly: same
        grouping, same plan reuse, same vmapped-batch results, same stats
        counters (the old semantics, reimplemented inline as the oracle)."""
        cfg_a = TuckerConfig(ranks=(2, 3, 2), methods="eig")
        cfg_b = TuckerConfig(ranks=(2, 2, 2), methods="eig")
        reqs = [TuckerRequest(x=tensor((10, 9, 8), seed=s), config=cfg_a,
                              rid=s) for s in range(4)]
        reqs += [TuckerRequest(x=tensor((6, 7, 5), seed=9), config=cfg_b,
                               rid=99)]
        eng = TuckerBatchEngine()
        eng.run(reqs)
        # oracle: the pre-service grouping semantics
        p_a = make_plan((10, 9, 8), jnp.float32, cfg_a)
        p_b = make_plan((6, 7, 5), jnp.float32, cfg_b)
        ref_batch = p_a.execute_batch(jnp.stack([r.x for r in reqs[:4]]))
        ref_single = p_b.execute(reqs[4].x)
        for r, ref in zip(reqs[:4], ref_batch):
            assert bitwise_equal(r.result, ref)
        assert bitwise_equal(reqs[4].result, ref_single)
        stats = eng.stats
        assert stats["plans_built"] == 2
        assert stats["requests"] == 5
        assert stats["batches"] == 2
        assert stats["backends"] == {p_a.backend: 5}
        # second wave, same shapes: no new plans (warm-path parity)
        eng.run([TuckerRequest(x=tensor((10, 9, 8), seed=7), config=cfg_a)])
        assert eng.stats["plans_built"] == 2
        assert eng.stats["batches"] == 3

    def test_engine_never_pads(self):
        eng = TuckerBatchEngine()
        r = TuckerRequest(x=tensor((13, 11, 9), seed=1), config=CFG)
        eng.run([r])
        (bucket,) = eng.stats["buckets"].values()
        assert bucket["padded"] == 0 and bucket["pad_waste"] == 0.0

    def test_engine_propagates_plan_errors(self):
        eng = TuckerBatchEngine(memory_cap_bytes=64)
        with pytest.raises(MemoryCapError):
            eng.run([TuckerRequest(x=tensor((8, 8, 8)), config=CFG)])


# ---------------------------------------------------------------------------
# autotune flywheel integration
# ---------------------------------------------------------------------------

class TestRecordFlywheel:
    def test_service_record_feeds_store_roundtrip(self, tmp_path):
        from repro.tune import RecordStore
        from repro.tune.records import HARVEST

        store = RecordStore(tmp_path / "records.jsonl")
        svc = TuckerService(policy=BucketPolicy(grid=8, max_pad_ratio=8.0),
                            record=True, record_store=store)
        x = tensor((13, 12, 11), seed=4)
        t = svc.submit(x, CFG)
        t2 = svc.submit(tensor((16, 16, 16), seed=5), CFG)
        svc.drain()
        assert svc.poll(t) is not None and svc.poll(t2) is not None
        ms = store.load()
        assert len(ms) == 6                      # 2 requests x 3 modes
        assert all(m.source == HARVEST for m in ms)
        assert all(m.seconds > 0 for m in ms)
        # padded request recorded at its TRUE per-mode sizes (exact mode
        # runs the true-shape plan), so the flywheel learns real problems
        assert {m.i_n for m in ms} == {13, 12, 11, 16}

    def test_ambient_recording_context_reaches_waves(self, tmp_path):
        from repro.tune import RecordStore, recording

        store = RecordStore(tmp_path / "records.jsonl")
        svc = TuckerService()
        t = svc.submit(tensor((8, 8, 8)), CFG)
        with recording(store):
            svc.drain()
        assert svc.poll(t) is not None
        assert len(store.load()) == 3            # one per mode

    def test_engine_record_passthrough(self, tmp_path):
        from repro.tune import RecordStore

        store = RecordStore(tmp_path / "records.jsonl")
        eng = TuckerBatchEngine(record=True, record_store=store)
        eng.run([TuckerRequest(x=tensor((8, 8, 8), seed=i), config=CFG)
                 for i in range(2)])
        assert len(store.load()) == 6


# ---------------------------------------------------------------------------
# compiled-program bounding
# ---------------------------------------------------------------------------

class TestLaneBounding:
    def test_pow2_lane_fill_bounds_batched_program_count(self):
        """Waves of 3, 5, 6, 7 requests all round to {4, 8} lanes: two
        batched programs ever, instead of one per observed batch size."""
        cfg = TuckerConfig(ranks=(2, 2, 2), methods="eig")
        svc = TuckerService(policy=BucketPolicy(grid=8, wave_slots=8))
        before = CACHE_STATS["traces"]
        for n in (3, 5, 6, 7):
            ts = [svc.submit(tensor((8, 8, 8), seed=100 + n + i), cfg)
                  for i in range(n)]
            svc.drain()
            assert all(svc.poll(t) is not None for t in ts)
        # one cached jitted sweep, TWO traced programs (4- and 8-lane
        # batches); without lane fill every n would trace its own
        assert CACHE_STATS["traces"] - before == 2


# ---------------------------------------------------------------------------
# cross-wave pipelining
# ---------------------------------------------------------------------------
class TestPipelining:
    def test_inflight_depth_validated(self):
        for bad in (0, -1):
            with pytest.raises(ValueError):
                TuckerService(max_inflight_waves=bad)

    def test_stats_expose_depth_and_occupancy(self):
        svc = TuckerService(max_inflight_waves=3)
        t = svc.submit(tensor((8, 8, 8)), CFG)
        svc.drain()
        s = svc.stats()
        assert s["max_inflight_waves"] == 3
        (snap,) = s["buckets"].values()
        assert {"pipelined_waves", "pipeline_occupancy",
                "avg_inflight"} <= snap.keys()
        # a single wave has nothing to overlap with
        assert snap["pipelined_waves"] == 0
        assert snap["pipeline_occupancy"] == 0.0
        assert svc.poll(t) is not None

    def _run(self, depth, n=6):
        # wave_slots=2 forces ceil(n/2) waves out of one bucket
        svc = TuckerService(policy=BucketPolicy(grid=1, wave_slots=2,
                                                lane_pow2=False),
                            max_inflight_waves=depth)
        ts = [svc.submit(tensor((8, 8, 8), seed=s), CFG) for s in range(n)]
        svc.drain()
        res = [svc.poll(t) for t in ts]
        assert all(r is not None for r in res)
        return svc, res

    def test_serial_and_pipelined_results_bitwise_equal(self):
        _, serial = self._run(depth=1)
        _, piped = self._run(depth=3)
        for a, b in zip(serial, piped):
            assert bitwise_equal(a, b)

    def test_pipelined_waves_counted(self):
        svc1, _ = self._run(depth=1)
        (snap1,) = svc1.stats()["buckets"].values()
        assert snap1["waves"] == 3
        assert snap1["pipelined_waves"] == 0      # depth 1 = serial dispatch
        assert snap1["avg_inflight"] == 0.0

        svc3, _ = self._run(depth=3)
        (snap3,) = svc3.stats()["buckets"].values()
        assert snap3["waves"] == 3
        assert snap3["pipelined_waves"] >= 1      # later waves overlapped
        assert 0.0 < snap3["pipeline_occupancy"] <= 1.0
        assert snap3["avg_inflight"] > 0.0
