"""Plan-time schedule search: DP-optimal mode order + solver choice under a
memory cap (ROADMAP "Plan-aware memory"; the paper's GPU OOM regime).

st-HOSVD cost is dominated by the order modes are processed in — shrinking a
high-compression mode first collapses J_n for every later step — and the key
structural fact is that the (I_n, R_n, J_n) triple a mode sees depends only
on the *set* of modes already processed, not on their sequence.  That makes
the search space a lattice of 2^N subsets instead of N! sequences, so an
exact Held–Karp-style DP is cheap for any realistic tensor order:

  state    = subset S of already-shrunk modes
  value(S) = min total predicted cost of reaching S
  edge     = processing mode m ∉ S with solver q, priced by the (possibly
             calibrated) :class:`~repro.core.cost_model.CostModel` —
             predicted seconds when calibrated, Eq. 4/5 FLOPs otherwise —
             and gated by ``memory_cap_bytes`` against the same per-device
             ``_step_peak_bytes`` model the plan layer stamps on every step.

The DP jointly picks the mode ORDER and the per-step SOLVER: a cap below
EIG's I_n² Gram scratch can force the slower-but-smaller ALS iterate (or
vice versa — ALS's fp32 input cast can be the binding buffer for sub-fp32
inputs), exactly the trade the paper's OOM regime demands.  For sharded
plans the per-state shard participation follows
:func:`~repro.core.distributed.pick_shard_mode` on the state's shrunken
shape, so different orders genuinely see different per-device peaks — the
DP searches over shard participation implicitly through the order.

Entry points:

  * :func:`optimize_schedule` — the DP; returns the optimal order + per-step
    methods + predicted total.  Raises :class:`MemoryCapError` naming the
    binding step when no complete schedule fits the cap.
  * :func:`validate_schedule_cap` — post-hoc cap check for schedules whose
    order was fixed by the caller (explicit ``mode_order``, t-HOSVD, HOOI
    refinement sweeps); same error contract.

Used by :func:`repro.core.plan.resolve_schedule` when
``mode_order="opt"`` / ``memory_cap_bytes`` flow in from ``TuckerConfig``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from .cost_model import DEFAULT_COST_MODEL, CostModel
from .solvers import DEFAULT_ALS_ITERS

#: solvers the optimizer may choose between when methods are not pinned.
#: SVD is deliberately excluded — it is never the predicted-best solver and
#: always matricizes (plan it explicitly if you want the baseline).
SEARCH_METHODS = ("eig", "als")


class MemoryCapError(ValueError):
    """No schedule satisfies ``memory_cap_bytes``; the message names the
    binding step (mode, solver, problem size, modeled bytes)."""


@dataclass(frozen=True)
class ScheduleSearch:
    """Result of the subset DP: the optimal order, the solver chosen for
    each position of that order, the predicted total cost (seconds for a
    calibrated cost model, FLOPs otherwise), and how many lattice states
    were expanded (diagnostics / tune harvesting)."""
    order: tuple[int, ...]
    methods: tuple[str, ...]        # per position of ``order``
    total_cost: float
    calibrated: bool                # total_cost is seconds, not FLOPs
    n_states: int

    def to_dict(self) -> dict:
        return {"order": list(self.order), "methods": list(self.methods),
                "total_cost": self.total_cost, "calibrated": self.calibrated,
                "n_states": self.n_states}


def _candidates(methods, mode: int) -> tuple[str, ...]:
    """Solver candidates for ``mode``: the pinned one, or the search set."""
    if methods is None:
        return SEARCH_METHODS
    return (methods[mode],)


def _priced_candidates(shape, ranks, methods, itemsize, n_shards, cur, m):
    """Every (method, peak_bytes, i_n, r_n, j_n) candidate for solving mode
    ``m`` at the DP state whose current (partially shrunk) dims are ``cur``
    — the ONE place the shard-participation and per-device peak rules live,
    shared by the DP transition loop and the infeasibility message."""
    from .plan import _step_peak_bytes   # shared model; plan.py imports us
    i_n, r_n = shape[m], ranks[m]        # lazily, so no cycle
    j_n = math.prod(cur) // i_n
    if n_shards > 1:
        from .distributed import pick_shard_mode
        shard = pick_shard_mode(tuple(cur), m, n_shards)
    else:
        shard = None
    for meth in _candidates(methods, m):
        eff = n_shards if (shard is not None and meth != "svd") else 1
        yield meth, _step_peak_bytes(meth, i_n, r_n, j_n, itemsize, eff), \
            i_n, r_n, j_n


def step_cost(cost_model: CostModel, method: str, i_n: int, r_n: int,
              j_n: int, als_iters: int) -> float:
    """The DP's edge weight: MARGINAL predicted seconds — the calibrated
    per-FLOP scales times Eq. 4/5, WITHOUT the fitted per-solve dispatch
    overheads.  Every complete schedule runs exactly N solves, so the
    overhead term is a constant offset that cannot change the argmin over
    orders — but it was fitted on eager per-solve dispatch, which the fused
    compiled sweep the optimizer is scheduling never pays, and keeping it
    would bias the solver choice toward the low-overhead solver (EIG) far
    beyond its in-sweep advantage.  With textbook scales (1.0) this
    degrades to a plain FLOP count, pricing the uncalibrated regime."""
    if method == "eig":
        return cost_model.eig_scale * cost_model.eig_flops(i_n, r_n, j_n)
    if method == "als":
        return cost_model.als_scale * \
            cost_model.als_flops(i_n, r_n, j_n, als_iters)
    # svd has no fitted scale; eig's per-FLOP seconds are the closest GEMM
    # proxy (same convention as CostModel.predict_seconds) — svd only enters
    # the search when explicitly pinned, so the bias cannot flip a solver
    # choice, only shade the order of a schedule that already chose svd
    return cost_model.eig_scale * cost_model.svd_flops(i_n, r_n, j_n)


def optimize_schedule(
    shape: Sequence[int],
    ranks: Sequence[int],
    *,
    methods: Sequence[str] | None = None,
    als_iters: int = DEFAULT_ALS_ITERS,
    itemsize: int = 4,
    n_shards: int = 1,
    cost_model: CostModel | None = None,
    memory_cap_bytes: int | None = None,
) -> ScheduleSearch:
    """Exact subset DP over st-HOSVD schedules.

    ``methods`` pins the solver per MODE (the DP then only searches order);
    ``None`` lets each step choose from :data:`SEARCH_METHODS`.  With
    ``n_shards > 1`` every candidate step's peak is the per-device figure
    for the shard mode :func:`pick_shard_mode` assigns at that state.

    Raises :class:`MemoryCapError` when no complete order fits the cap; the
    message names the cheapest-memory step that still exceeds it at the
    deepest reachable state (the *binding* step).
    """
    shape = tuple(int(s) for s in shape)
    ranks = tuple(int(r) for r in ranks)
    n = len(shape)
    cm = cost_model if cost_model is not None else DEFAULT_COST_MODEL
    full = (1 << n) - 1

    # best[mask] = (cost, prev_mask, mode, method); transitions only ever
    # set bits, so ascending-mask iteration is a valid topological order.
    best: dict[int, tuple[float, int, int, str]] = {0: (0.0, -1, -1, "")}
    for mask in range(full):
        state = best.get(mask)
        if state is None:
            continue
        cur = [ranks[i] if mask >> i & 1 else shape[i] for i in range(n)]
        for m in range(n):
            if mask >> m & 1:
                continue
            for meth, peak, i_n, r_n, j_n in _priced_candidates(
                    shape, ranks, methods, itemsize, n_shards, cur, m):
                if memory_cap_bytes is not None and peak > memory_cap_bytes:
                    continue
                cost = state[0] + step_cost(cm, meth, i_n, r_n, j_n, als_iters)
                nxt = mask | (1 << m)
                if nxt not in best or cost < best[nxt][0]:
                    best[nxt] = (cost, mask, m, meth)

    if full not in best:
        raise MemoryCapError(_infeasible_message(
            shape, ranks, methods, als_iters, itemsize, n_shards,
            memory_cap_bytes, best))

    order: list[int] = []
    meths: list[str] = []
    mask = full
    while mask:
        _, prev, m, meth = best[mask]
        order.append(m)
        meths.append(meth)
        mask = prev
    order.reverse()
    meths.reverse()
    return ScheduleSearch(order=tuple(order), methods=tuple(meths),
                          total_cost=best[full][0],
                          calibrated=cm.calibrated, n_states=len(best))


def _infeasible_message(shape, ranks, methods, als_iters, itemsize, n_shards,
                        cap, best) -> str:
    """Name the binding step: at the deepest reachable state, the remaining
    mode whose cheapest-memory solver still exceeds the cap by the least —
    the step any schedule must eventually pay."""
    n = len(shape)
    deepest = max(best, key=lambda mask: bin(mask).count("1"))
    cur = [ranks[i] if deepest >> i & 1 else shape[i] for i in range(n)]
    done = [i for i in range(n) if deepest >> i & 1]
    binding = None   # (peak, mode, method, i, r, j)
    for m in range(n):
        if deepest >> m & 1:
            continue
        for meth, peak, i_n, r_n, j_n in _priced_candidates(
                shape, ranks, methods, itemsize, n_shards, cur, m):
            if binding is None or peak < binding[0]:
                binding = (peak, m, meth, i_n, r_n, j_n)
    peak, m, meth, i_n, r_n, j_n = binding
    dev = " per device" if n_shards > 1 else ""
    after = f"after shrinking modes {done}, " if done else ""
    return (f"memory_cap_bytes={cap:,} is infeasible for shape {shape} → "
            f"ranks {ranks}: {after}the binding step — mode {m} "
            f"({meth}, I={i_n} R={r_n} J={j_n}) — still needs "
            f"≥{peak:,} modeled bytes{dev}; raise the cap above that, "
            "shrink the ranks, or shard over more devices")


def validate_schedule_cap(steps, memory_cap_bytes: int) -> None:
    """Post-hoc cap check for fixed-order schedules (explicit ``mode_order``,
    t-HOSVD, HOOI refinements): every step's modeled per-device peak must fit.
    Raises :class:`MemoryCapError` naming the first binding step."""
    for k, s in enumerate(steps):
        if s.peak_bytes > memory_cap_bytes:
            dev = " per device" if s.n_shards > 1 else ""
            raise MemoryCapError(
                f"schedule exceeds memory_cap_bytes={memory_cap_bytes:,}: "
                f"step {k} (mode {s.mode}, {s.method}, I={s.i_n} R={s.r_n} "
                f"J={s.j_n}) models {s.peak_bytes:,} peak bytes{dev}; "
                "mode_order='opt' searches order AND solver under the cap")
