"""Plan-time schedule search: DP-optimal mode order + solver choice under a
memory cap (ROADMAP "Plan-aware memory"; the paper's GPU OOM regime).

st-HOSVD cost is dominated by the order modes are processed in — shrinking a
high-compression mode first collapses J_n for every later step — and the key
structural fact is that the (I_n, R_n, J_n) triple a mode sees depends only
on the *set* of modes already processed (and the ranks they shrank to), not
on their sequence.  That makes the search space a lattice of 2^N subsets
instead of N! sequences, so an exact Held–Karp-style DP is cheap for any
realistic tensor order:

  state    = subset S of already-shrunk modes, encoded as the bit-mask
             ``mask`` (bit m set ⇔ mode m already shrunk); transitions only
             ever SET bits, so iterating masks in ascending integer order
             is a valid topological order of the lattice
  value(S) = min total predicted cost of reaching S, held in
             ``best[mask] = (cost, flops, prev_mask, group, assign, rks,
             cur)`` — cost is the latency objective, flops the
             lexicographic tie-break, ``prev_mask`` the back-pointer the
             winning schedule is reconstructed from, ``group``/``assign``/
             ``rks`` the modes/solvers/ranks of the transition that reached
             this state, and ``cur`` the current (partially shrunk) dims
  edge     = processing mode m ∉ S with solver q at rank r, priced by the
             (possibly calibrated) :class:`~repro.core.cost_model.CostModel`
             — predicted seconds when calibrated, Eq. 4/5 FLOPs otherwise —
             and gated by ``memory_cap_bytes`` against the same per-device
             ``_step_peak_bytes`` model the plan layer stamps on every step
             (a transition whose modeled peak exceeds the cap is simply
             never relaxed, so infeasible schedules are pruned *during*
             the sweep rather than checked after)

The DP jointly picks the mode ORDER, the per-step SOLVER, and — when a
``rank_grid`` supplies per-mode candidates — the per-step RANK: a cap below
EIG's I_n² Gram scratch can force the slower-but-smaller ALS iterate (or
vice versa — ALS's fp32 input cast can be the binding buffer for sub-fp32
inputs), exactly the trade the paper's OOM regime demands.  For sharded
plans the per-state shard participation follows
:func:`~repro.core.distributed.pick_shard_mode` on the state's shrunken
shape, so different orders genuinely see different per-device peaks — the
DP searches over shard participation implicitly through the order.

The RANK axis (error-targeted planning, ROADMAP "randomized sketching"):
``rank_grid[m]`` lists ascending candidate ranks for mode m, and each
sequential transition tries every (solver, rank) pair, the chosen rank
propagating into every later step's J_n through ``cur``.  With the shipped
cost models — monotone in rank for every solver — the per-mode argmin is
always the smallest candidate admissible under the cap, so the axis's value
is exact J_n propagation and cap gating at the *chosen* ranks (a tight cap
can rule out a larger rank the executor might want; the DP detects that at
plan time instead of shipping cap-priced steps that cannot run).  The
chosen ranks come back in :attr:`ScheduleSearch.ranks`.  Rank-adaptive
plans (``TuckerConfig(error_target=...)``) use this to order their sketch
pass; the rank the *executor* finally settles on is read off the sketch's
singular-value tail at run time (:func:`repro.core.solvers.rand_sketch`).

With ``max_group > 1`` the DP also searches MODE-PARALLEL GROUPS: a
transition may shrink a whole set of modes at once, modeling the sharded
runner's concurrent-Gram path (all members' Grams from the same un-shrunk
tensor, one fused multi-TTM truncation).  A group edge is priced as the
``max`` of its members' step costs — latency, not work — while a FLOPs sum
is kept as the lexicographic tie-break so sequential execution wins exact
ties (it never does more work).  A group's modeled peak charges the shared
full-size input once plus every member's solver scratch CONCURRENTLY
(:func:`repro.core.plan._group_peak_bytes`), so a ``memory_cap_bytes`` that
admits each mode alone can still force a group to split.

Entry points:

  * :func:`optimize_schedule` — the DP; returns the optimal order + per-step
    methods (+ grouping when ``max_group > 1``) + predicted total.  Raises
    :class:`MemoryCapError` naming the binding step/group when no complete
    schedule fits the cap.
  * :func:`optimize_grouping` — grouping-only segmentation DP along a FIXED
    mode order (explicit ``mode_order`` with ``mode_parallel="auto"``).
  * :func:`validate_schedule_cap` — post-hoc cap check for schedules whose
    order was fixed by the caller (explicit ``mode_order``, t-HOSVD, HOOI
    refinement sweeps); same error contract.

Used by :func:`repro.core.plan.resolve_schedule` when
``mode_order="opt"`` / ``memory_cap_bytes`` / ``mode_parallel`` flow in
from ``TuckerConfig``.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from itertools import combinations, product
from typing import Sequence

from ..obs import trace as _obs
from .cost_model import DEFAULT_COST_MODEL, CostModel
from .errors import ResourceError
from .solvers import DEFAULT_ALS_ITERS

#: solvers the optimizer may choose between when methods are not pinned.
#: SVD is deliberately excluded — it is never the predicted-best solver and
#: always matricizes (plan it explicitly if you want the baseline).  RAND is
#: excluded from the *default* set too (its accuracy depends on the spectrum,
#: which the DP cannot see); pass ``search_methods=("eig", "als", "rand")``
#: to let sketch FLOPs compete, or pin it per mode via ``methods``.
SEARCH_METHODS = ("eig", "als")


class MemoryCapError(ResourceError, ValueError):
    """No schedule satisfies ``memory_cap_bytes``; the message names the
    binding step (mode, solver, problem size, modeled bytes).  Part of the
    classified-failure taxonomy (a :class:`~repro.core.errors.ResourceError`)
    while still a ``ValueError`` for pre-taxonomy call sites."""


@dataclass(frozen=True)
class ScheduleSearch:
    """Result of the subset DP: the optimal order, the solver chosen for
    each position of that order, the predicted total cost (seconds for a
    calibrated cost model, FLOPs otherwise), and how many lattice states
    were expanded (diagnostics / tune harvesting).  ``groups`` partitions
    ``order`` into consecutive mode-parallel groups (all singletons for a
    purely sequential schedule; empty for legacy callers that never asked
    the DP to consider grouping).  ``ranks`` is the rank chosen for each
    position of ``order`` — equal to the caller's fixed ranks unless a
    ``rank_grid`` opened the rank axis, in which case it is the DP's
    per-mode pick from the grid."""
    order: tuple[int, ...]
    methods: tuple[str, ...]        # per position of ``order``
    total_cost: float
    calibrated: bool                # total_cost is seconds, not FLOPs
    n_states: int
    groups: tuple[tuple[int, ...], ...] = ()
    ranks: tuple[int, ...] = ()     # per position of ``order``

    def to_dict(self) -> dict:
        return {"order": list(self.order), "methods": list(self.methods),
                "total_cost": self.total_cost, "calibrated": self.calibrated,
                "n_states": self.n_states,
                "groups": [list(g) for g in self.groups],
                "ranks": list(self.ranks)}


def _candidates(methods, mode: int,
                search=SEARCH_METHODS) -> tuple[str, ...]:
    """Solver candidates for ``mode``: the pinned one, or the search set."""
    if methods is None:
        return search
    return (methods[mode],)


def _priced_candidates(shape, ranks, methods, itemsize, n_shards, cur, m,
                       search=SEARCH_METHODS, rank_grid=None):
    """Every (method, peak_bytes, i_n, r_n, j_n) candidate for solving mode
    ``m`` at the DP state whose current (partially shrunk) dims are ``cur``
    — the ONE place the shard-participation and per-device peak rules live,
    shared by the DP transition loop and the infeasibility message.  With a
    ``rank_grid`` the rank axis opens: one candidate per (solver, grid rank)
    pair instead of the single fixed ``ranks[m]``."""
    from .plan import _step_peak_bytes   # shared model; plan.py imports us
    i_n = shape[m]                       # lazily, so no cycle
    j_n = math.prod(cur) // i_n
    rank_cands = (ranks[m],) if rank_grid is None else tuple(rank_grid[m])
    if n_shards > 1:
        from .distributed import pick_shard_mode
        shard = pick_shard_mode(tuple(cur), m, n_shards)
    else:
        shard = None
    for meth in _candidates(methods, m, search):
        eff = n_shards if (shard is not None and meth not in ("svd", "rand")) \
            else 1
        for r_n in rank_cands:
            yield meth, _step_peak_bytes(meth, i_n, r_n, j_n, itemsize, eff), \
                i_n, r_n, j_n


def step_cost(cost_model: CostModel, method: str, i_n: int, r_n: int,
              j_n: int, als_iters: int) -> float:
    """The DP's edge weight: MARGINAL predicted seconds — the calibrated
    per-FLOP scales times Eq. 4/5, WITHOUT the fitted per-solve dispatch
    overheads.  Every complete schedule runs exactly N solves, so the
    overhead term is a constant offset that cannot change the argmin over
    orders — but it was fitted on eager per-solve dispatch, which the fused
    compiled sweep the optimizer is scheduling never pays, and keeping it
    would bias the solver choice toward the low-overhead solver (EIG) far
    beyond its in-sweep advantage.  With textbook scales (1.0) this
    degrades to a plain FLOP count, pricing the uncalibrated regime."""
    if method == "eig":
        return cost_model.eig_scale * cost_model.eig_flops(i_n, r_n, j_n)
    if method == "als":
        return cost_model.als_scale * \
            cost_model.als_flops(i_n, r_n, j_n, als_iters)
    if method == "rand":
        # sketch FLOPs (range sample + power iterations + Rayleigh–Ritz)
        # with the fitted rand scale — how rank-adaptive sketch passes and
        # explicit rand pins are priced into the order/solver/rank search
        return cost_model.rand_scale_eff * cost_model.rand_flops(i_n, r_n, j_n)
    # svd has no fitted scale; eig's per-FLOP seconds are the closest GEMM
    # proxy (same convention as CostModel.predict_seconds) — svd only enters
    # the search when explicitly pinned, so the bias cannot flip a solver
    # choice, only shade the order of a schedule that already chose svd
    return cost_model.eig_scale * cost_model.svd_flops(i_n, r_n, j_n)


def _price_group(shape, ranks, methods, als_iters, itemsize, n_shards, cur,
                 g, cost_model):
    """Every priced solver assignment for running the modes of ``g`` as ONE
    mode-parallel group at the state whose current dims are ``cur``: yields
    ``(assign, latency, flops, peak_bytes)``.  Each member is sized at the
    group-entry shape (J_n keeps the other members un-shrunk), latency is
    the max over members (they run concurrently), flops the sum (the work
    tie-break), and the peak is the group model — shared input slab plus
    every member's scratch at once.  SVD matricizes and RAND runs replicated
    — neither joins a group; a group containing a mode pinned to either
    yields nothing (infeasible).  Groups are also rank-FIXED: the rank axis
    applies to sequential transitions only (a group's fused multi-TTM is
    sized at plan time and cannot absorb a run-time rank decision)."""
    from .plan import _group_peak_bytes   # shared model; lazy, no cycle
    in_elems = math.prod(cur)
    out_elems = in_elems
    for m in g:
        out_elems = out_elems // cur[m] * ranks[m]
    if n_shards > 1:
        from .distributed import pick_shard_mode_group
        shard = pick_shard_mode_group(tuple(cur), g, n_shards)
    else:
        shard = None
    eff = n_shards if shard is not None else 1
    cand_sets = []
    for m in g:
        cands = tuple(c for c in _candidates(methods, m)
                      if c not in ("svd", "rand"))
        if not cands:
            return
        cand_sets.append(cands)
    for assign in product(*cand_sets):
        entries = []
        lat = fl = 0.0
        for m, meth in zip(g, assign):
            i_n, r_n = cur[m], ranks[m]
            j_n = in_elems // i_n
            c = step_cost(cost_model, meth, i_n, r_n, j_n, als_iters)
            lat = max(lat, c)
            fl += c
            entries.append((meth, i_n, r_n, j_n))
        peak = _group_peak_bytes(entries, in_elems, out_elems, itemsize, eff)
        yield assign, lat, fl, peak


def _relax(best, nxt: int, cost: float, flops: float, prev: int,
           group, assign, rks, cur) -> None:
    """Lexicographic (latency, flops) relaxation: strictly-better latency
    wins; at equal latency the lower-work schedule wins, so a parallel
    group never displaces a sequential plan it merely ties.  ``rks`` records
    the rank chosen for each mode of ``group`` (the rank axis) and ``cur``
    the resulting current dims, which later transitions read their J_n
    from — the channel through which a rank choice propagates downstream."""
    cand = best.get(nxt)
    if cand is None or (cost, flops) < (cand[0], cand[1]):
        best[nxt] = (cost, flops, prev, tuple(group), tuple(assign),
                     tuple(rks), tuple(cur))


def optimize_schedule(
    shape: Sequence[int],
    ranks: Sequence[int],
    *,
    methods: Sequence[str] | None = None,
    als_iters: int = DEFAULT_ALS_ITERS,
    itemsize: int = 4,
    n_shards: int = 1,
    cost_model: CostModel | None = None,
    memory_cap_bytes: int | None = None,
    max_group: int = 1,
    search_methods: Sequence[str] = SEARCH_METHODS,
    rank_grid: Sequence[Sequence[int]] | None = None,
) -> ScheduleSearch:
    """Exact subset DP over st-HOSVD schedules.

    ``methods`` pins the solver per MODE (the DP then only searches order);
    ``None`` lets each step choose from ``search_methods`` (default
    :data:`SEARCH_METHODS`; widen to ``("eig", "als", "rand")`` to let the
    sketch-FLOPs pricing compete).  With ``n_shards > 1`` every candidate
    step's peak is the per-device figure for the shard mode
    :func:`pick_shard_mode` assigns at that state.  ``max_group > 1``
    additionally searches mode-parallel groupings: a transition may shrink
    up to ``max_group`` modes at once, priced by the latency/FLOPs rules of
    :func:`_price_group`; ``max_group=1`` reduces exactly to the sequential
    DP.

    ``rank_grid`` opens the RANK axis: per-mode ascending candidate ranks
    (``rank_grid[m]``; ``ranks`` then only seeds the search's sizing
    fallback) — sequential transitions try every (solver, rank) pair and
    the chosen rank shrinks ``cur`` for all later steps, so order × solver
    × rank is searched jointly.  Incompatible with ``max_group > 1``
    (groups are rank-fixed; see :func:`_price_group`).

    Raises :class:`MemoryCapError` when no complete order fits the cap; the
    message names the cheapest-memory step (or group) that still exceeds it
    at the deepest reachable state (the *binding* step).
    """
    wall0, t0 = time.time(), time.perf_counter()
    shape = tuple(int(s) for s in shape)
    ranks = tuple(int(r) for r in ranks)
    n = len(shape)
    cm = cost_model if cost_model is not None else DEFAULT_COST_MODEL
    full = (1 << n) - 1
    max_group = max(1, min(int(max_group), n))
    search = tuple(search_methods)
    if rank_grid is not None:
        rank_grid = tuple(tuple(int(r) for r in g) for g in rank_grid)
        if len(rank_grid) != n or any(not g for g in rank_grid):
            raise ValueError(f"rank_grid needs a non-empty candidate tuple "
                             f"per mode ({n} modes), got {rank_grid}")
        if max_group > 1:
            raise ValueError("the rank axis (rank_grid) applies to "
                             "sequential schedules only; groups are "
                             "rank-fixed — use max_group=1")

    # best[mask] = (cost, flops, prev_mask, group, assign, rks, cur); see
    # the module docstring for the full state encoding.  Transitions only
    # ever set bits, so ascending-mask iteration is a valid topological
    # order.  cost is the latency objective, flops the lexicographic
    # tie-break (see _relax); cur carries the chosen-rank dims forward.
    best: dict[int, tuple[float, float, int, tuple, tuple, tuple, tuple]] = {
        0: (0.0, 0.0, -1, (), (), (), shape)}
    for mask in range(full):
        state = best.get(mask)
        if state is None:
            continue
        cur = list(state[6])
        rem = [m for m in range(n) if not mask >> m & 1]
        for m in rem:   # sequential edges, exactly the max_group=1 DP
            for meth, peak, i_n, r_n, j_n in _priced_candidates(
                    shape, ranks, methods, itemsize, n_shards, cur, m,
                    search, rank_grid):
                if memory_cap_bytes is not None and peak > memory_cap_bytes:
                    continue
                c = step_cost(cm, meth, i_n, r_n, j_n, als_iters)
                nxt_cur = list(cur)
                nxt_cur[m] = r_n
                _relax(best, mask | (1 << m), state[0] + c, state[1] + c,
                       mask, (m,), (meth,), (r_n,), nxt_cur)
        for size in range(2, min(max_group, len(rem)) + 1):
            for g in combinations(rem, size):
                nxt = mask
                for m in g:
                    nxt |= 1 << m
                for assign, lat, fl, peak in _price_group(
                        shape, ranks, methods, als_iters, itemsize,
                        n_shards, cur, g, cm):
                    if memory_cap_bytes is not None \
                            and peak > memory_cap_bytes:
                        continue
                    nxt_cur = list(cur)
                    for m in g:
                        nxt_cur[m] = ranks[m]
                    _relax(best, nxt, state[0] + lat, state[1] + fl,
                           mask, g, assign, tuple(ranks[m] for m in g),
                           nxt_cur)

    if full not in best:
        raise MemoryCapError(_infeasible_message(
            shape, ranks, methods, als_iters, itemsize, n_shards,
            memory_cap_bytes, best, max_group=max_group, cost_model=cm,
            search=search, rank_grid=rank_grid))

    groups: list[tuple[int, ...]] = []
    meths: list[tuple[str, ...]] = []
    rkss: list[tuple[int, ...]] = []
    mask = full
    while mask:
        _, _, prev, g, assign, rks, _cur = best[mask]
        groups.append(g)
        meths.append(assign)
        rkss.append(rks)
        mask = prev
    groups.reverse()
    meths.reverse()
    rkss.reverse()
    result = ScheduleSearch(
        order=tuple(m for g in groups for m in g),
        methods=tuple(q for a in meths for q in a),
        total_cost=best[full][0], calibrated=cm.calibrated,
        n_states=len(best), groups=tuple(groups),
        ranks=tuple(r for rks in rkss for r in rks))
    _obs.event("span", t=wall0, name="plan.dp_search",
               dur_s=time.perf_counter() - t0, shape=list(shape),
               n_states=result.n_states, order=list(result.order),
               methods=list(result.methods), max_group=max_group,
               calibrated=result.calibrated, total_cost=result.total_cost)
    return result


def optimize_grouping(
    shape: Sequence[int],
    ranks: Sequence[int],
    order: Sequence[int],
    *,
    methods: Sequence[str] | None = None,
    als_iters: int = DEFAULT_ALS_ITERS,
    itemsize: int = 4,
    n_shards: int = 1,
    cost_model: CostModel | None = None,
    memory_cap_bytes: int | None = None,
    max_group: int | None = None,
) -> ScheduleSearch:
    """Mode-parallel grouping search along a FIXED mode order (the
    ``mode_parallel="auto"`` path when the user pinned ``mode_order``):
    a segmentation DP over prefixes of ``order`` — ``dp[k]`` is the
    cheapest latency to have shrunk ``order[:k]``, and a transition runs
    the contiguous slice ``order[k:k+L]`` as one group (``L=1`` is a plain
    sequential step).  Solver choice per member follows the same rules as
    :func:`optimize_schedule`.  ``max_group=None`` allows groups up to the
    full tensor order."""
    wall0, t0 = time.time(), time.perf_counter()
    shape = tuple(int(s) for s in shape)
    ranks = tuple(int(r) for r in ranks)
    order = tuple(int(m) for m in order)
    n = len(order)
    cm = cost_model if cost_model is not None else DEFAULT_COST_MODEL
    max_group = n if max_group is None else max(1, min(int(max_group), n))

    dp: dict[int, tuple[float, float, int, tuple, tuple, tuple, tuple]] = {
        0: (0.0, 0.0, -1, (), (), (), shape)}
    for k in range(n):
        state = dp.get(k)
        if state is None:
            continue
        done = set(order[:k])
        cur = [ranks[i] if i in done else shape[i]
               for i in range(len(shape))]
        m = order[k]
        for meth, peak, i_n, r_n, j_n in _priced_candidates(
                shape, ranks, methods, itemsize, n_shards, cur, m):
            if memory_cap_bytes is not None and peak > memory_cap_bytes:
                continue
            c = step_cost(cm, meth, i_n, r_n, j_n, als_iters)
            nxt_cur = list(cur)
            nxt_cur[m] = r_n
            _relax(dp, k + 1, state[0] + c, state[1] + c, k, (m,), (meth,),
                   (r_n,), nxt_cur)
        for size in range(2, min(max_group, n - k) + 1):
            g = order[k:k + size]
            for assign, lat, fl, peak in _price_group(
                    shape, ranks, methods, als_iters, itemsize, n_shards,
                    cur, g, cm):
                if memory_cap_bytes is not None and peak > memory_cap_bytes:
                    continue
                nxt_cur = list(cur)
                for gm in g:
                    nxt_cur[gm] = ranks[gm]
                _relax(dp, k + size, state[0] + lat, state[1] + fl,
                       k, g, assign, tuple(ranks[gm] for gm in g), nxt_cur)

    if n not in dp:
        deepest = max(dp)
        done = set(order[:deepest])
        cur = [ranks[i] if i in done else shape[i]
               for i in range(len(shape))]
        cands = [(order[deepest],)] + [
            order[deepest:deepest + size]
            for size in range(2, min(max_group, n - deepest) + 1)]
        binding = _min_peak_binding(shape, ranks, methods, als_iters,
                                    itemsize, n_shards, cur, cands, cm)
        raise MemoryCapError(_format_binding(
            shape, ranks, memory_cap_bytes, sorted(done), binding, n_shards))

    groups: list[tuple[int, ...]] = []
    meths: list[tuple[str, ...]] = []
    rkss: list[tuple[int, ...]] = []
    k = n
    while k:
        _, _, prev, g, assign, rks, _cur = dp[k]
        groups.append(g)
        meths.append(assign)
        rkss.append(rks)
        k = prev
    groups.reverse()
    meths.reverse()
    rkss.reverse()
    result = ScheduleSearch(
        order=order, methods=tuple(q for a in meths for q in a),
        total_cost=dp[n][0], calibrated=cm.calibrated,
        n_states=len(dp), groups=tuple(groups),
        ranks=tuple(r for rks in rkss for r in rks))
    _obs.event("span", t=wall0, name="plan.dp_grouping",
               dur_s=time.perf_counter() - t0, shape=list(shape),
               order=list(order), groups=[list(g) for g in result.groups],
               calibrated=result.calibrated, total_cost=result.total_cost)
    return result


def _min_peak_binding(shape, ranks, methods, als_iters, itemsize, n_shards,
                      cur, candidate_groups, cost_model,
                      search=SEARCH_METHODS, rank_grid=None):
    """The cheapest-memory candidate over ``candidate_groups`` (each a tuple
    of modes; singletons are plain sequential steps) at the state whose
    current dims are ``cur`` — the step/group any schedule must eventually
    pay.  Returns ``(peak, modes, assign, detail)`` where ``detail`` is the
    singleton's (i_n, r_n, j_n) or ``None`` for a multi-mode group."""
    binding = None
    for g in candidate_groups:
        if len(g) == 1:
            for meth, peak, i_n, r_n, j_n in _priced_candidates(
                    shape, ranks, methods, itemsize, n_shards, cur, g[0],
                    search, rank_grid):
                if binding is None or peak < binding[0]:
                    binding = (peak, g, (meth,), (i_n, r_n, j_n))
        else:
            for assign, _lat, _fl, peak in _price_group(
                    shape, ranks, methods, als_iters, itemsize, n_shards,
                    cur, g, cost_model):
                if binding is None or peak < binding[0]:
                    binding = (peak, g, assign, None)
    return binding


def _format_binding(shape, ranks, cap, done, binding, n_shards) -> str:
    peak, g, assign, detail = binding
    dev = " per device" if n_shards > 1 else ""
    after = f"after shrinking modes {list(done)}, " if done else ""
    if len(g) == 1:
        m, meth = g[0], assign[0]
        i_n, r_n, j_n = detail
        what = (f"the binding step — mode {m} "
                f"({meth}, I={i_n} R={r_n} J={j_n})")
        remedy = ("raise the cap above that, shrink the ranks, "
                  "or shard over more devices")
    else:
        what = (f"the binding group — modes {list(g)} "
                f"({'+'.join(assign)}, concurrent Grams from the un-shrunk "
                "input)")
        remedy = ("raise the cap above that, shrink the ranks, split the "
                  "group (mode_parallel='off'), or shard over more devices")
    return (f"memory_cap_bytes={cap:,} is infeasible for shape {shape} → "
            f"ranks {ranks}: {after}{what} — still needs "
            f"≥{peak:,} modeled bytes{dev}; {remedy}")


def _infeasible_message(shape, ranks, methods, als_iters, itemsize, n_shards,
                        cap, best, max_group=1, cost_model=None,
                        search=SEARCH_METHODS, rank_grid=None) -> str:
    """Name the binding step (or group): at the deepest reachable state, the
    remaining candidate whose cheapest-memory pricing still exceeds the cap
    by the least — the transition any schedule must eventually pay."""
    n = len(shape)
    cm = cost_model if cost_model is not None else DEFAULT_COST_MODEL
    deepest = max(best, key=lambda mask: bin(mask).count("1"))
    cur = list(best[deepest][6])   # state dims, rank-axis aware
    done = [i for i in range(n) if deepest >> i & 1]
    rem = [m for m in range(n) if not deepest >> m & 1]
    cands = [(m,) for m in rem]
    for size in range(2, min(max_group, len(rem)) + 1):
        cands.extend(combinations(rem, size))
    binding = _min_peak_binding(shape, ranks, methods, als_iters, itemsize,
                                n_shards, cur, cands, cm, search, rank_grid)
    return _format_binding(shape, ranks, cap, done, binding, n_shards)


def validate_schedule_cap(steps, memory_cap_bytes: int) -> None:
    """Post-hoc cap check for fixed-order schedules (explicit ``mode_order``,
    t-HOSVD, HOOI refinements): every step's modeled per-device peak must fit.
    Raises :class:`MemoryCapError` naming the first binding step."""
    for k, s in enumerate(steps):
        if s.peak_bytes > memory_cap_bytes:
            dev = " per device" if s.n_shards > 1 else ""
            grp = f" in mode-parallel group {s.group}" \
                if s.group is not None else ""
            raise MemoryCapError(
                f"schedule exceeds memory_cap_bytes={memory_cap_bytes:,}: "
                f"step {k} (mode {s.mode}, {s.method}, I={s.i_n} R={s.r_n} "
                f"J={s.j_n}){grp} models {s.peak_bytes:,} peak bytes{dev}; "
                "mode_order='opt' searches order AND solver under the cap")
