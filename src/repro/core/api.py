"""Plan/execute front door for a-Tucker: ``TuckerConfig`` → ``TuckerPlan``.

The legacy entry points (`sthosvd` & friends) re-run the adaptive selector
and re-dispatch solvers inside every call.  Following the plan/execute split
of randomized-Tucker systems that precompute their sketch/solve schedules,
this module moves ALL input-adaptive decisions to a one-time ``plan`` step:

    cfg  = TuckerConfig(ranks=(10, 10, 5), methods="auto")
    p    = plan(x.shape, x.dtype, cfg)     # selector runs here, never again
    res  = p.execute(x)                    # ONE cached jitted program
    ress = p.execute_batch(xs)             # same program, vmapped over axis 0

Because the per-mode solver schedule and mode order are frozen in the plan,
the entire sweep traces as a single XLA program, cached process-wide by
``(shape, dtype, schedule+backend, variant, als_iters, compute_dtype)`` — so
repeated executes on same-shaped inputs cost zero recompiles and zero
selector invocations.  Plans are JSON-serializable (``save``/``load``,
mirroring ``Selector.save``) so a schedule tuned on one box can ship to
another.

Rank-ADAPTIVE plans trade fixed ranks for an error target:

    cfg = TuckerConfig(error_target=0.05)        # ||X - X̂|| ≤ 0.05·||X||
    p   = plan(x.shape, x.dtype, cfg)            # freezes a rank POLICY
    res = p.execute(x)                           # sketches ranks, refines
    res.tucker.ranks, res.error_bound            # what the policy chose

The plan carries per-step candidate grids and equi-partitioned HOSVD
budgets instead of ranks; execution reads each mode's rank off a
randomized sketch (matricization-free, the same TTM/TTT/Gram kernels) and
either ships the sketch factors directly (``methods="rand"``) or refines
at the chosen ranks through the ordinary fixed-rank compiled path.
"""

from __future__ import annotations

import json
import math
import os
import sys
from dataclasses import dataclass, replace
from functools import lru_cache
from pathlib import Path
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from .. import chaos as _chaos
from ..obs import drift as _drift
from ..obs import metrics as _metrics
from ..obs import trace as _obs
from .backend import backend_names, get_backend, resolve_backend
from .errors import (CancelledError, DeadlineError, InputError,
                     NumericalError, ResourceError, TuckerError,
                     check_finite, check_result_finite, classify_exception)
from .plan import (
    ModeStep,
    TimedSelector,
    VARIANTS,
    resolve_schedule,
    sweep_hooi,
    sweep_sthosvd,
    sweep_thosvd,
)
from .solvers import DEFAULT_ALS_ITERS, DEFAULT_OVERSAMPLE, DEFAULT_POWER_ITERS
from .sthosvd import ModeTrace, SthosvdResult, TuckerTensor

PLAN_FORMAT_VERSION = 1


def mesh_spec(mesh: Mesh | None) -> dict | None:
    """JSON-serializable description of a mesh: axis names + per-axis sizes.
    Device identities are deliberately NOT serialized — a plan tuned on one
    box re-materializes its mesh from the local devices on another."""
    if mesh is None:
        return None
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names]}


def mesh_from_spec(spec: dict | None) -> Mesh | None:
    """Rebuild a mesh from :func:`mesh_spec` output against the LOCAL
    devices.  Returns None when the spec is None or the local process has
    too few devices — the plan then loads fine for inspection but
    ``execute`` raises until a real mesh is available."""
    if spec is None:
        return None
    shape = tuple(int(s) for s in spec["shape"])
    if math.prod(shape) > len(jax.devices()):
        return None
    return jax.make_mesh(shape, tuple(spec["axis_names"]))


@dataclass(frozen=True)
class TuckerConfig:
    """Frozen description of a Tucker decomposition job (the *what*).

    ``plan()`` turns it plus a concrete (shape, dtype) into a ``TuckerPlan``
    (the *how*): per-mode solvers resolved, costs estimated, sweep compiled.

    compute_dtype is the precision policy: inputs are cast to it before the
    sweep (e.g. "float32" to decompose bf16 weights at full precision); the
    default ``None`` keeps the input dtype.

    ``impl`` names an ops backend from :mod:`repro.core.backend` (``matfree``
    | ``explicit`` | ``pallas`` | ``sharded`` | any custom-registered name)
    or ``"auto"`` to let ``plan()`` pick the best backend for the current
    platform and compute dtype; the resolved choice is frozen into the
    plan's schedule.

    ``mesh`` attaches a ``jax.sharding.Mesh`` for multi-device execution:
    ``impl="sharded"`` requires one, and ``impl="auto"`` resolves to the
    sharded backend whenever one is present.  ``shard_axis`` names the mesh
    axis the tensor is sharded over (default: the mesh's first axis).  The
    mesh serializes as its SPEC (axis names + sizes, see :func:`mesh_spec`)
    — device handles never enter plan JSON.

    ``mode_order`` orders the st-HOSVD sweep: ``None`` (the paper's 1..N),
    an explicit permutation, ``"shrink"`` (greedy compression-ratio
    heuristic), or ``"opt"`` — the exact subset-DP schedule search
    (:mod:`repro.core.schedule_opt`) that jointly picks order AND per-step
    solver against the cost model's predicted total, under
    ``memory_cap_bytes`` when set.

    ``memory_cap_bytes`` is a hard per-device ceiling on every step's
    modeled peak working set: plans that cannot fit raise
    :class:`~repro.core.schedule_opt.MemoryCapError` at plan time naming
    the binding step (the paper's GPU OOM regime, decided before any
    allocation).

    ``donate_input`` controls whether the compiled sweep donates its input
    buffer to XLA (``jax.jit(donate_argnums=0)``) so a sweep stops holding
    a dead copy of X.  ``None`` (auto, the default) donates only the device
    copy ``execute`` itself materialized from a host array — a caller's
    jax array is never invalidated silently; ``True`` always donates (the
    input is CONSUMED — ``x`` is unusable after ``execute(x)``); ``False``
    disables donation by default (an explicit per-call
    ``execute(x, donate=True)`` still wins — the caller owns the buffer).
    Donation is automatically disabled where unsupported
    (sharded shard_map sweeps, interpret-mode backends, platforms without
    buffer aliasing) and globally via the ``ATUCKER_NO_DONATE`` env var.

    ``mode_parallel`` opts sharded st-HOSVD sweeps into MODE-PARALLEL
    groups: group members compute their Grams concurrently from the same
    un-shrunk tensor (one mesh barrier for the whole group) and truncate in
    one fused multi-TTM — lower latency, more FLOPs.  ``"off"`` (default)
    keeps the sequential shrinking sweep; an int ``G ≥ 2`` forces the first
    G modes of the resolved order into one group; ``"auto"`` lets the
    schedule DP price sequential vs every grouping per input (latency =
    max over group members, memory = shared input + concurrent scratches,
    under ``memory_cap_bytes``) and silently stays sequential on
    single-device plans.

    ``error_target`` switches the plan RANK-ADAPTIVE (st-HOSVD only): pass a
    target relative reconstruction error ε ∈ (0, 1) and ``ranks`` becomes
    optional — the plan carries a rank POLICY instead of fixed ranks, and
    execution reads each mode's rank off a randomized sketch
    (:func:`repro.core.solvers.rand_sketch`): the smallest candidate whose
    measured discarded energy fits the mode's equi-partitioned share
    ``τ_n² = ε²·||X||²/N`` of the HOSVD bound ``||X − X̂||² ≤ Σ_n τ_n²``.
    ``ranks``, when also given, caps the per-mode rank; ``rank_grid``
    restricts the candidates — a flat int tuple is one shared ascending
    grid for every mode, a tuple of tuples is per-mode (default: every rank
    up to the cap).  ``methods`` then names the solver that REFINES the
    decomposition at the chosen ranks through the ordinary fixed-rank
    compiled path (``"auto"``/``"eig"``/``"als"`` …); ``methods="rand"``
    skips refinement and ships the sketch's own factors — the fastest path,
    still within ε.  ``oversample``/``power_iters`` tune the sketch
    (ℓ = r + oversample columns, subspace-iteration count).

    ``SthosvdResult.error_bound`` then reports the certified bound
    ``sqrt(Σ_n tail_n)/||X||`` measured from the executed sketch.
    """
    ranks: tuple[int, ...] | None = None
    variant: str = "sthosvd"
    methods: str | tuple[str, ...] = "auto"
    mode_order: tuple[int, ...] | str | None = None
    impl: str = "matfree"
    als_iters: int = DEFAULT_ALS_ITERS
    hooi_iters: int = 3
    compute_dtype: str | None = None
    mesh: Mesh | None = None
    shard_axis: str | None = None
    memory_cap_bytes: int | None = None
    donate_input: bool | None = None
    mode_parallel: str | int = "off"
    error_target: float | None = None
    rank_grid: tuple | None = None
    oversample: int = DEFAULT_OVERSAMPLE
    power_iters: int = DEFAULT_POWER_ITERS

    def __post_init__(self):
        if self.ranks is not None:
            object.__setattr__(self, "ranks",
                               tuple(int(r) for r in self.ranks))
        elif self.error_target is None:
            raise ValueError("TuckerConfig needs ranks=... (fixed-rank) or "
                             "error_target=... (rank-adaptive)")
        if self.error_target is not None:
            object.__setattr__(self, "error_target", float(self.error_target))
            if not 0.0 < self.error_target < 1.0:
                raise ValueError(f"error_target={self.error_target} must be "
                                 "a relative error in (0, 1)")
            if self.variant != "sthosvd":
                raise ValueError("error_target (rank-adaptive planning) "
                                 "needs the sequential-shrink error "
                                 "accounting of variant='sthosvd', got "
                                 f"{self.variant!r}")
            if self.mode_parallel != "off":
                raise ValueError("rank-adaptive plans are sequential (the "
                                 "per-mode budget check threads the shrink); "
                                 "mode_parallel must stay 'off'")
            if self.mesh is not None or self.impl == "sharded":
                raise ValueError("rank-adaptive plans run replicated (the "
                                 "sketch has no collective path); drop the "
                                 "mesh / sharded impl, or resolve ranks "
                                 "first and plan the fixed-rank sharded "
                                 "sweep at the result")
        if self.rank_grid is not None:
            if self.error_target is None:
                raise ValueError("rank_grid is part of the rank-adaptive "
                                 "policy; set error_target=... too (for "
                                 "fixed ranks pass ranks=...)")
            rg = tuple(self.rank_grid)
            if all(isinstance(g, int) for g in rg):
                object.__setattr__(self, "rank_grid",
                                   tuple(int(g) for g in rg))
            else:
                object.__setattr__(
                    self, "rank_grid",
                    tuple(tuple(int(r) for r in g) for g in rg))
            if not rg:
                raise ValueError("rank_grid must not be empty")
        if self.oversample < 0 or self.power_iters < 0:
            raise ValueError("oversample and power_iters must be >= 0")
        if not isinstance(self.methods, str):
            object.__setattr__(self, "methods", tuple(self.methods))
        if isinstance(self.mode_order, (list, tuple)):
            object.__setattr__(self, "mode_order",
                               tuple(int(m) for m in self.mode_order))
        if isinstance(self.mode_order, str) and \
                self.mode_order not in ("shrink", "opt"):
            raise ValueError(f"mode_order {self.mode_order!r} must be a "
                             "permutation, 'shrink', 'opt', or None")
        if self.memory_cap_bytes is not None:
            object.__setattr__(self, "memory_cap_bytes",
                               int(self.memory_cap_bytes))
            if self.memory_cap_bytes <= 0:
                raise ValueError("memory_cap_bytes must be a positive byte "
                                 "count (None = uncapped)")
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}; "
                             f"expected one of {VARIANTS}")
        if self.impl != "auto":
            b = get_backend(self.impl)   # ValueError on unregistered names
            # a mesh on a single-device backend would be silently ignored —
            # the OOM-regime user who attached it deserves a loud error
            if self.mesh is not None and not b.requires_mesh:
                raise ValueError(
                    f"config carries a mesh but impl={self.impl!r} executes "
                    "on a single device; pass impl='sharded' (or 'auto', "
                    "which resolves to it when a mesh is present) or drop "
                    "the mesh")
        if self.als_iters < 1 or self.hooi_iters < 0:
            raise ValueError("als_iters must be ≥1 and hooi_iters ≥0")
        mp = self.mode_parallel
        if isinstance(mp, bool) or \
                not (mp in ("off", "auto") or isinstance(mp, int)):
            raise ValueError(f"mode_parallel {mp!r} must be 'off', 'auto', "
                             "or an int max group size")
        if isinstance(mp, int) and mp < 1:
            raise ValueError(f"mode_parallel={mp} must be >= 1")
        if self.shard_axis is not None and self.mesh is not None and \
                self.shard_axis not in self.mesh.axis_names:
            raise ValueError(f"shard_axis {self.shard_axis!r} not in mesh "
                             f"axes {self.mesh.axis_names}")

    @property
    def resolved_shard_axis(self) -> str | None:
        """The mesh axis sharded executions split over (explicit
        ``shard_axis`` or the mesh's first axis); None without a mesh."""
        if self.mesh is None:
            return self.shard_axis
        return self.shard_axis or self.mesh.axis_names[0]

    @property
    def n_shards(self) -> int:
        """Device count along the shard axis (1 without a mesh)."""
        return int(self.mesh.shape[self.resolved_shard_axis]) \
            if self.mesh is not None else 1

    def to_dict(self) -> dict:
        d = {"ranks": None if self.ranks is None else list(self.ranks),
             "variant": self.variant,
             "methods": (self.methods if isinstance(self.methods, str)
                         else list(self.methods)),
             "mode_order": (list(self.mode_order)
                            if isinstance(self.mode_order, tuple)
                            else self.mode_order),
             "impl": self.impl, "als_iters": self.als_iters,
             "hooi_iters": self.hooi_iters,
             "compute_dtype": self.compute_dtype,
             "mesh": mesh_spec(self.mesh),
             "shard_axis": self.shard_axis,
             "memory_cap_bytes": self.memory_cap_bytes,
             "donate_input": self.donate_input,
             "mode_parallel": self.mode_parallel}
        # rank-policy keys ride only on adaptive configs, so fixed-rank
        # config JSON is byte-identical to what pre-rank-policy versions
        # wrote (and they can still load it)
        if self.error_target is not None:
            d["error_target"] = self.error_target
            d["rank_grid"] = (None if self.rank_grid is None else
                              [list(g) if isinstance(g, tuple) else g
                               for g in self.rank_grid])
            d["oversample"] = self.oversample
            d["power_iters"] = self.power_iters
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TuckerConfig":
        rg = d.get("rank_grid")
        if rg is not None:
            rg = tuple(tuple(g) if isinstance(g, list) else int(g)
                       for g in rg)
        ranks = d["ranks"]
        return cls(ranks=None if ranks is None else tuple(ranks),
                   variant=d.get("variant", "sthosvd"),
                   methods=(d["methods"] if isinstance(d["methods"], str)
                            else tuple(d["methods"])),
                   mode_order=(tuple(d["mode_order"])
                               if isinstance(d.get("mode_order"), list)
                               else d.get("mode_order")),
                   impl=d.get("impl", "matfree"),
                   als_iters=d.get("als_iters", DEFAULT_ALS_ITERS),
                   hooi_iters=d.get("hooi_iters", 3),
                   compute_dtype=d.get("compute_dtype"),
                   mesh=mesh_from_spec(d.get("mesh")),
                   shard_axis=d.get("shard_axis"),
                   memory_cap_bytes=d.get("memory_cap_bytes"),
                   donate_input=d.get("donate_input"),
                   mode_parallel=d.get("mode_parallel", "off"),
                   error_target=d.get("error_target"),
                   rank_grid=rg,
                   oversample=d.get("oversample", DEFAULT_OVERSAMPLE),
                   power_iters=d.get("power_iters", DEFAULT_POWER_ITERS))


# ---------------------------------------------------------------------------
# Input-buffer donation
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def donation_supported(platform: str) -> bool:
    """Whether XLA honours input-output buffer aliasing on ``platform``.

    Probed once per process per platform by compiling a tiny donated
    program ON that platform's first device and checking the input buffer
    was actually invalidated — runtimes without aliasing (older CPU
    backends) silently ignore ``donate_argnums`` with a warning, and a
    sweep "donated" there would keep the dead copy of X alive anyway.
    """
    import warnings
    try:
        dev = jax.devices(platform)[0]
        # fresh, unshared buffer committed to the probed platform
        x = jax.device_put(jnp.zeros((2,), jnp.float32) + 1.0, dev)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            jax.block_until_ready(
                jax.jit(lambda a: a * 2.0, donate_argnums=0)(x))
        return bool(x.is_deleted())
    except Exception:  # pragma: no cover - defensive: treat as unsupported
        return False


# ---------------------------------------------------------------------------
# Process-wide compiled-sweep cache
# ---------------------------------------------------------------------------

_SWEEP_CACHE: dict[tuple, Callable] = {}

#: builds = new jitted programs constructed; hits = cache reuses;
#: traces = times a sweep body actually traced (== XLA compilations).
CACHE_STATS = {"builds": 0, "hits": 0, "traces": 0}


def clear_sweep_cache() -> None:
    _SWEEP_CACHE.clear()
    CACHE_STATS.update(builds=0, hits=0, traces=0)


def _make_sweep(p: "TuckerPlan", batched: bool, donate: bool = False) -> Callable:
    steps = p.schedule   # each step carries its resolved ops backend
    cfg = p.config
    n_init = len(p.shape)  # HOOI: first full sweep is the st-HOSVD init
    cdtype = jnp.dtype(cfg.compute_dtype) if cfg.compute_dtype else None

    if p.backend == "sharded":
        # donation is guarded off for shard_map sweeps upstream
        # (_resolve_donate); never build an aliasing program here
        from .distributed import sweep_mode_parallel, sweep_sharded
        if cfg.mesh is None:
            raise RuntimeError(
                "plan requires a mesh to execute its sharded schedule (the "
                "loading process has too few devices to rebuild the plan's "
                "mesh spec, or the config lost its mesh); re-plan with "
                "TuckerConfig(mesh=...) on a large enough host")
        if batched:
            raise RuntimeError("sharded sweeps do not vmap; execute_batch "
                               "runs sharded plans item by item")
        mesh, axis = cfg.mesh, cfg.resolved_shard_axis
        run = sweep_mode_parallel \
            if any(s.group is not None for s in steps) else sweep_sharded

        def sweep(x):
            CACHE_STATS["traces"] += 1
            if cdtype is not None:
                x = x.astype(cdtype)
            return run(x, steps, mesh=mesh, axis=axis,
                       als_iters=cfg.als_iters)

        return jax.jit(sweep)

    def sweep(x):
        CACHE_STATS["traces"] += 1
        if cdtype is not None:
            x = x.astype(cdtype)
        if cfg.variant == "sthosvd":
            return sweep_sthosvd(x, steps, als_iters=cfg.als_iters)
        if cfg.variant == "thosvd":
            return sweep_thosvd(x, steps, als_iters=cfg.als_iters)
        return sweep_hooi(x, steps, als_iters=cfg.als_iters, n_init=n_init)

    jitted = jax.jit(jax.vmap(sweep) if batched else sweep,
                     donate_argnums=(0,) if donate else ())
    if not donate:
        return jitted

    def donating(x):
        # donate_argnums lets XLA alias X into any shape-matching output;
        # a Tucker sweep's outputs (core + factors) rarely match, in which
        # case XLA ignores the donation (with a warning) and the dead copy
        # of X would survive the whole sweep — so release it explicitly
        # right after dispatch (the runtime holds its own reference while
        # the async execution still needs it).
        import warnings
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            out = jitted(x)
        if not x.is_deleted():
            x.delete()
        return out

    return donating


def _compile_probe(fn: Callable, p: "TuckerPlan", batched: bool) -> Callable:
    """Wrap a freshly built sweep so its FIRST invocation — the one that
    traces and XLA-compiles — is spanned as ``compile`` on the bus (the
    duration includes the first execution; jit offers no clean split
    without AOT lowering).  Later calls pass straight through."""
    state = {"first": True}

    def probed(x):
        if not state["first"]:
            return fn(x)
        state["first"] = False
        with _obs.span("compile", shape=list(p.shape), dtype=p.dtype,
                       backend=p.backend, variant=p.config.variant,
                       batched=batched, includes_first_run=True):
            return fn(x)

    return probed


# ---------------------------------------------------------------------------
# TuckerPlan
# ---------------------------------------------------------------------------

@dataclass
class TuckerPlan:
    """A frozen, executable solver schedule for one (shape, dtype, config).

    ``schedule`` lists every mode solve in execution order with the solver
    the selector (or explicit methods) chose and the modeled FLOPs / peak
    working-set bytes of that step.  ``execute`` runs the whole sweep as one
    cached jitted program; ``execute_batch`` vmaps it over a leading axis.
    """
    shape: tuple[int, ...]
    dtype: str
    config: TuckerConfig
    schedule: tuple[ModeStep, ...]
    select_seconds: float = 0.0     # one-time planning cost (selector calls)

    # -- introspection -------------------------------------------------------
    @property
    def is_adaptive(self) -> bool:
        """True when this plan carries a rank POLICY (``error_target``)
        instead of fixed ranks: steps are sized at their rank caps (the
        conservative figure for memory modeling) and ``execute`` reads the
        actual per-mode ranks off a randomized sketch of each input."""
        return self.config.error_target is not None

    @property
    def backend(self) -> str:
        """The resolved ops backend this plan's steps run on (``config.impl``
        may be ``"auto"``; this is what it resolved to at plan time)."""
        names = {s.backend for s in self.schedule}
        return self.schedule[0].backend if len(names) == 1 else "mixed"

    @property
    def methods(self) -> tuple[str, ...]:
        """Resolved solver per mode (first visit order, sorted by mode)."""
        first: dict[int, str] = {}
        for s in self.schedule:
            first.setdefault(s.mode, s.method)
        return tuple(first[m] for m in sorted(first))

    @property
    def total_flops(self) -> float:
        return sum(s.flops for s in self.schedule)

    @property
    def total_predicted_s(self) -> float:
        """Predicted sweep wall-clock: the sum of the per-step calibrated
        cost-model predictions (0.0 when no calibration was available at
        plan time — compare against summed ``ModeTrace.seconds``)."""
        return sum(s.predicted_s for s in self.schedule)

    @property
    def input_bytes(self) -> int:
        """Per-device bytes of the caller's input buffer — the plan's
        STORAGE dtype, not the compute dtype (the cast happens inside the
        jit; the buffer an undonated sweep keeps alive is x as passed) —
        divided by the first step's shard count for sharded plans."""
        return math.prod(self.shape) * jnp.dtype(self.dtype).itemsize \
            // self.schedule[0].n_shards

    @property
    def donates(self) -> bool:
        """Whether this plan's compiled sweep donates its input under the
        resolved static policy (config / env / backend guards; the ``None``
        auto policy counts as donating — the recommended host-input path
        materializes its own device copy, which IS donated)."""
        return self._resolve_donate(created=True, override=None)

    @property
    def peak_bytes(self) -> int:
        """Modeled per-device peak across the sweep, donation-aware: an
        undonated st-HOSVD sweep keeps the caller's (dead after step 0)
        input copy alive through every later step, so those steps charge
        ``input_bytes`` on top of their own working set; a donated sweep
        returns that buffer to XLA and pays only the per-step peaks.

        A leading mode-parallel group counts as "step 0" here: every member
        reads the full-size input, which its group peak already charges, so
        the dead-copy surcharge starts after the whole group."""
        base = max(s.peak_bytes for s in self.schedule)
        if self.config.variant != "sthosvd" or self.donates or \
                len(self.schedule) == 1:
            # t-HOSVD/HOOI read X in (almost) every step — it is already
            # counted in their per-step io, donated or not
            return base
        from .plan import iter_groups
        k0 = len(next(iter_groups(self.schedule)))
        if k0 >= len(self.schedule):
            return base
        extra = self.input_bytes
        return max(max(s.peak_bytes for s in self.schedule[:k0]),
                   max(s.peak_bytes + extra for s in self.schedule[k0:]))

    def _resolve_donate(self, created: bool, override: bool | None) -> bool:
        """Donation decision for one execute call.  ``created`` = the device
        buffer was materialized by execute itself (host input), so donating
        it can never invalidate a caller-held array.  ``override`` is the
        per-call argument; an explicit ``True``/``False`` at the call site
        beats ``config.donate_input`` (the caller owns the buffer), while
        the env escape hatch and the backend/platform guards beat both."""
        if override is False:
            return False
        if os.environ.get("ATUCKER_NO_DONATE"):
            return False
        if self.backend == "sharded":
            return False   # shard_map sweep: donation aliases live shards
        try:
            b = get_backend(self.backend)
        except ValueError:   # hand-built plan mixing backends per step
            return False
        if not b.native_on(jax.default_backend()):
            return False   # interpret-mode fallback: never alias a buffer
                           # the interpreter may still read
        if not donation_supported(jax.default_backend()):
            return False
        if override:       # per-call donate=True: consume x as documented
            return True
        cfg = self.config
        if cfg.donate_input is not None:
            return bool(cfg.donate_input)
        return created     # auto: only the copy execute itself materialized

    def _cache_key(self, batched: bool, donate: bool = False) -> tuple:
        # keyed on the RESOLVED per-step backend, not config.impl: two plans
        # whose "auto" resolved identically share one compiled sweep; sharded
        # plans additionally key on the mesh + frozen shard modes (a program
        # compiled for one device set never serves another); donated and
        # undonated variants are distinct programs (aliasing is compiled in)
        return (self.shape, self.dtype,
                tuple((s.mode, s.method, s.r_n, s.backend, s.shard_mode,
                       s.group)
                      for s in self.schedule),
                self.config.variant, self.config.als_iters,
                self.config.compute_dtype, batched, donate,
                self.config.mesh, self.config.resolved_shard_axis)

    def _sweep(self, batched: bool, donate: bool = False) -> Callable:
        key = self._cache_key(batched, donate)
        fn = _SWEEP_CACHE.get(key)
        if fn is None:
            fn = _SWEEP_CACHE[key] = _compile_probe(
                _make_sweep(self, batched, donate), self, batched)
            CACHE_STATS["builds"] += 1
            _obs.event("cache", status="miss", shape=list(self.shape),
                       dtype=self.dtype, backend=self.backend,
                       variant=self.config.variant, batched=batched,
                       donate=donate)
        else:
            # hits are counted but not published: a per-execute "hit" event
            # costs real µs on the warm path and says nothing the execute
            # span + CACHE_STATS don't (misses are the informative events)
            CACHE_STATS["hits"] += 1
        return fn

    def _place_input(self, x: jax.Array) -> jax.Array:
        """Sharded plans: land the input on the mesh pre-sharded the way the
        first step expects, so the compiled sweep starts from the frozen
        layout instead of paying a replicate-then-reshard."""
        if self.backend != "sharded" or self.config.mesh is None:
            return x
        from jax.sharding import NamedSharding

        from .distributed import _spec_for
        spec = _spec_for(len(self.shape), self.schedule[0].shard_mode,
                         self.config.resolved_shard_axis)
        return jax.device_put(x, NamedSharding(self.config.mesh, spec))

    # -- execution -----------------------------------------------------------
    def execute(self, x: jax.Array, *, record: bool = False,
                donate: bool | None = None,
                validate: str | None = None) -> SthosvdResult:
        """Run the frozen schedule on ``x`` as one compiled program.

        ``record=True`` (or an active :func:`repro.tune.recording` context)
        switches to the eager per-step runner so every mode solve gets real
        wall-clock in its trace — the traces then feed the autotune
        measurement store (predicted-vs-actual per step, and free training
        records from production traffic).  Sharded plans have no eager
        per-step path and reject ``record=True``.

        ``donate`` overrides ``config.donate_input`` for this call: ``True``
        donates ``x``'s buffer into the sweep (``x`` is CONSUMED — deleted
        after the call), ``False`` never donates, ``None`` follows the
        config policy (auto: donate only the device copy this call itself
        materialized from a host array).

        ``validate="finite"`` rejects NaN/Inf inputs up front with
        :class:`~repro.core.errors.InputError` naming the offending mode,
        and checks the sweep's outputs (raising
        :class:`~repro.core.errors.NumericalError`, which the fallback
        ladder then gets a chance to recover).  The output check forces a
        device sync, so it is opt-in — the serve layer validates at
        ``submit()`` and quarantines poisoned lanes itself.

        On a classified failure (see :mod:`repro.core.errors`) execution
        degrades along a bounded deterministic ladder — als→eig on
        numerical breakdown, pallas→matfree on a kernel failure,
        donated→undonated then replanned-under-a-tighter-cap on runtime
        OOM — each hop emitted as an obs ``fallback`` event and counted in
        the metrics registry before the failing class is re-raised only
        once the ladder is exhausted.
        """
        if not _obs.enabled():
            return self._execute(x, record=record, donate=donate,
                                 validate=validate)
        attrs = self.__dict__.get("_obs_attrs")
        if attrs is None:
            # static per-plan span attributes, built once: the properties
            # walk the schedule and would otherwise run on every execute
            attrs = self._obs_attrs = dict(
                shape=list(self.shape), dtype=self.dtype,
                backend=self.backend, variant=self.config.variant,
                adaptive=self.is_adaptive,
                predicted_s=self.total_predicted_s,
                peak_bytes=self.peak_bytes)
        with _obs.span("execute", record=record, **attrs):
            return self._execute(x, record=record, donate=donate,
                                 validate=validate)

    def _execute(self, x: jax.Array, *, record: bool = False,
                 donate: bool | None = None,
                 validate: str | None = None) -> SthosvdResult:
        xin = x
        x = jnp.asarray(x)
        if tuple(x.shape) != self.shape:
            raise InputError(f"plan is for shape {self.shape}, got {x.shape}")
        if str(x.dtype) != self.dtype:
            raise InputError(f"plan is for dtype {self.dtype}, got {x.dtype}")
        if validate not in (None, "none", "finite"):
            raise ValueError(
                f"validate must be None, 'none' or 'finite', got {validate!r}")
        if validate == "finite":
            check_finite(x, name="input")
        if self.is_adaptive:
            try:
                return self._execute_adaptive(x, record=record)
            except Exception as e:
                terr = classify_exception(e)
                if terr is not None and terr is not e:
                    raise terr from e
                raise
        created = x is not xin

        def can_retry() -> bool:
            # a failed donated sweep consumed the device copy; retry is
            # possible only while the caller's original buffer survives to
            # re-materialize from (always true for host inputs)
            nonlocal x, created
            d = getattr(x, "is_deleted", None)
            if d is None or not d():
                return True
            x2 = jnp.asarray(xin)
            d2 = getattr(x2, "is_deleted", None)
            if d2 is not None and d2():
                return False
            x, created = x2, x2 is not xin
            return True

        def run(p: "TuckerPlan", donate_override: bool | None) -> SthosvdResult:
            # sys.modules probe: plans that never meet repro.tune pay nothing
            tune = sys.modules.get("repro.tune")
            sink = tune.active_sink() if tune is not None else None
            if (record or sink is not None) and p.backend != "sharded":
                return p._execute_recorded(x, sink)
            if record:   # sharded + explicit record: fail loud, not silent
                raise ValueError(
                    "record=True needs the eager per-step runner, which "
                    "sharded plans do not have (the shard_map sweep is one "
                    "program); collect sharded measurements via "
                    "sthosvd_distributed")
            donate_now = p._resolve_donate(created=created,
                                           override=donate_override)
            _chaos.fire("sweep", backend=p.backend)
            core, factors = p._sweep(batched=False, donate=donate_now)(
                p._place_input(x))
            if _chaos.active() and _chaos.poison("sweep_out",
                                                 backend=p.backend):
                core = core * float("nan")
            if validate == "finite":
                check_result_finite(core, factors,
                                    context=f"{p.config.variant} sweep")
            return SthosvdResult(
                tucker=TuckerTensor(core=core, factors=list(factors)),
                trace=[ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, 0.0,
                                 backend=s.backend,
                                 predicted_s=s.predicted_s)
                       for s in p.schedule],
                select_overhead_s=0.0)

        return _run_with_fallback(self, can_retry, run, donate)

    def _execute_recorded(self, x: jax.Array, sink=None) -> SthosvdResult:
        """Eager mirror of the fused sweeps with per-step wall-clock; feeds
        the active tune sink (if any) so executed plans become training
        records."""
        from . import tensor_ops as T
        from .plan import run_schedule, solve_step
        cfg = self.config
        if cfg.compute_dtype:
            x = x.astype(jnp.dtype(cfg.compute_dtype))
        steps = self.schedule
        n = len(self.shape)
        if cfg.variant == "sthosvd":
            core, fdict, seconds = run_schedule(
                x, steps, sequential=True, als_iters=cfg.als_iters,
                block_until_ready=True)
            factors = [fdict[m] for m in range(n)]
        elif cfg.variant == "thosvd":
            _, fdict, seconds = run_schedule(
                x, steps, sequential=False, als_iters=cfg.als_iters,
                block_until_ready=True)
            factors = [fdict[m] for m in range(n)]
            core = x
            for mode, u in enumerate(factors):
                core = T.ttm(core, u.T, mode)
        else:  # hooi: timed init sweep, then timed projected refinements
            import time as _time
            core, fdict, seconds = run_schedule(
                x, steps[:n], sequential=True, als_iters=cfg.als_iters,
                block_until_ready=True)
            factors = [fdict[m] for m in range(n)]
            seconds = list(seconds)
            platform = jax.default_backend()
            for step in steps[n:]:
                y = x
                for m, u in enumerate(factors):
                    if m != step.mode:
                        y = T.ttm(y, u.T, m)
                wall0 = _time.time()
                t0 = _time.perf_counter()
                res = solve_step(y, step, als_iters=cfg.als_iters)
                jax.block_until_ready(res.u)
                dt = _time.perf_counter() - t0
                seconds.append(dt)
                _obs.event("span", t=wall0, name="solve", dur_s=dt,
                           mode=step.mode, solver=step.method,
                           backend=step.backend, platform=platform,
                           rank=step.r_n, i_n=step.i_n, j_n=step.j_n,
                           predicted_s=step.predicted_s)
                _drift.MONITOR.observe(platform=platform,
                                       backend=step.backend,
                                       solver=step.method,
                                       predicted_s=step.predicted_s,
                                       actual_s=dt, source="execute")
                factors[step.mode] = res.u
            core = x
            for mode, u in enumerate(factors):
                core = T.ttm(core, u.T, mode)
        trace = [ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, dt,
                           backend=s.backend, predicted_s=s.predicted_s)
                 for s, dt in zip(steps, seconds)]
        if sink is not None:
            sink.add_traces(trace, platform=jax.default_backend(),
                            dtype=cfg.compute_dtype or self.dtype,
                            order=n, als_iters=cfg.als_iters)
        return SthosvdResult(
            tucker=TuckerTensor(core=core, factors=factors),
            trace=trace, select_overhead_s=0.0)

    def resolve_ranks(self, x: jax.Array) -> tuple[tuple[int, ...], float]:
        """Run ONLY the sketch pass on ``x``: the per-mode ranks the policy
        chooses for this input plus the certified relative-error bound —
        without building the decomposition.  Adaptive plans only."""
        if not self.is_adaptive:
            raise ValueError("resolve_ranks needs a rank-adaptive plan "
                             "(TuckerConfig(error_target=...)); this plan's "
                             f"ranks are fixed at {self.config.ranks}")
        ranks, tails, *_ = self._sketch_pass(jnp.asarray(x))
        return ranks, math.sqrt(sum(tails.values()))

    def _sketch_pass(self, x: jax.Array):
        """The rank-adaptive sweep core: sequential randomized sketches
        (:func:`repro.core.solvers.rand_sketch`) in schedule order, reading
        each mode's rank off its sketched eigenvalue tail.

        Per step, the captured energy of a rank-r truncation of the current
        tensor equals the sum of the top-r eigenvalues of the sketched Gram
        — EXACT for the factor actually used, not an estimate — so the
        smallest grid candidate whose discarded energy fits the step's
        budget ``tau·||X||²`` is chosen (the grid cap when none fits).
        ``||X||²`` is the energy measured at step 0, before anything was
        truncated, which makes ``sqrt(Σ_n tail_n)`` of the recorded
        fractional tails a guaranteed relative-error bound via the
        sequential HOSVD inequality ``||X − X̂||² ≤ Σ_n τ_n²``.

        The sketch width is INPUT-ADAPTIVE: each mode starts narrow and
        doubles only while no candidate ≤ the current width meets the
        budget (up to ``rank cap + oversample``).  A narrower sketch can
        only under-capture — the measured tail of the factor it yields is
        still exact — so widening never weakens the guarantee, and
        well-compressible inputs never pay for the rank cap (without a
        ``ranks``/``rank_grid`` hint the cap is the full mode dimension;
        a full-width sketch there would erase the sketch's whole
        linear-in-I_n advantage).  Doubling keeps total sketch work within
        2× of the final width's.

        Returns ``(ranks, tails, factors, core, seconds, js, missed)``:
        per-mode chosen ranks and fractional tails, the sketch's own
        orthonormal factors, the shrunk core, per-step wall-clock, the
        actual (shrunk) J_n each step saw, and the modes whose budget NO
        grid candidate met even at the cap width — the error-target miss
        that triggers the rand→eig ladder hop in :meth:`_execute_adaptive`.
        """
        import time as _time

        import numpy as np

        from .backend import backend_ops
        from .solvers import rand_sketch
        cfg = self.config
        if cfg.compute_dtype:
            x = x.astype(jnp.dtype(cfg.compute_dtype))
        wdtype = x.dtype
        y = x
        total = None
        chosen: dict[int, int] = {}
        tails: dict[int, float] = {}
        factors: dict[int, jax.Array] = {}
        seconds: list[float] = []
        js: list[int] = []
        missed: list[int] = []
        platform = jax.default_backend()
        for s in self.schedule:
            wall0 = _time.time()
            t0 = _time.perf_counter()
            _chaos.fire("sketch", mode=s.mode)
            js.append(int(y.size // y.shape[s.mode]))
            width_cap = min(s.i_n, s.rank_grid[-1] + cfg.oversample)
            width = min(width_cap, max(16, 2 * cfg.oversample,
                                       s.rank_grid[0] + cfg.oversample))
            while True:
                q, b, evals, vecs, energy = rand_sketch(
                    y, s.mode, width, power_iters=cfg.power_iters,
                    impl=s.backend)
                ev = np.maximum(np.asarray(evals, dtype=np.float64), 0.0)
                energy = float(energy)
                if total is None:
                    total = energy or 1.0  # step 0: ||X||², the budget basis
                csum = np.cumsum(ev[::-1])  # csum[r-1] = top-r captured
                budget = s.tau * total
                r = tail = None
                for cand in s.rank_grid:    # ascending: smallest fit wins
                    if cand > width:
                        break
                    t = max(energy - float(csum[cand - 1]), 0.0)
                    if t <= budget:
                        r, tail = cand, t
                        break
                if r is not None or width >= width_cap:
                    break
                width = min(2 * width, width_cap)
            if r is None:   # no candidate fits even at the cap width: take
                            # the largest grid rank the sketch can express
                r = max(g for g in s.rank_grid if g <= width)
                tail = max(energy - float(csum[r - 1]), 0.0)
                missed.append(s.mode)
            chosen[s.mode], tails[s.mode] = int(r), tail / total
            # top-r Ritz rotation of the range basis; shrink via the
            # already-projected b — no second pass over the input
            v = vecs[:, -r:][:, ::-1].astype(q.dtype)
            u = jnp.dot(q, v, precision=jax.lax.Precision.HIGHEST)
            factors[s.mode] = u.astype(wdtype)
            ttm = backend_ops(s.backend)[0]
            y = ttm(b, v.T, s.mode).astype(wdtype)
            jax.block_until_ready(y)
            dt = _time.perf_counter() - t0
            seconds.append(dt)
            # retroactive span (no enter/exit to leak on solver errors):
            # same shape a live Span emits, parented under the execute span
            _obs.event("span", t=wall0, name="sketch", dur_s=dt,
                       mode=s.mode, solver="rand", backend=s.backend,
                       platform=platform, i_n=s.i_n, rank=int(r),
                       tail_err=tail / total, width=int(width), j_n=js[-1],
                       predicted_s=s.predicted_s)
            _drift.MONITOR.observe(platform=platform, backend=s.backend,
                                   solver="rand",
                                   predicted_s=s.predicted_s, actual_s=dt,
                                   source="execute")
        ranks = tuple(chosen[m] for m in range(len(self.shape)))
        return ranks, tails, factors, y, seconds, js, missed

    def _execute_adaptive(self, x: jax.Array, *,
                          record: bool = False) -> SthosvdResult:
        """Two-phase rank-adaptive execution (never donates — the original
        input is read again by the refinement sweep).

        Phase 1 resolves ranks per mode (:meth:`_sketch_pass`).  Phase 2:
        with ``methods="rand"`` the sketch's own factors and shrunk core
        ARE the result — the fastest path, certified by the measured bound;
        any other ``methods`` re-plans at the chosen FIXED ranks and runs
        the ordinary compiled eig/als sweep as refinement, with the sketch
        cost reported as ``select_overhead_s`` and the measured per-mode
        tails riding the refined trace as ``tail_err`` labels for the tune
        store."""
        cfg = self.config
        xa = jnp.asarray(x)
        ranks, tails, factors, core, seconds, js, missed = \
            self._sketch_pass(xa)
        bound = math.sqrt(sum(tails.values()))
        m = cfg.methods
        sketch_only = m == "rand" or \
            (not isinstance(m, str) and all(q == "rand" for q in m))
        hop_methods = None
        if sketch_only and missed:
            # rand→eig ladder hop: the sketch missed its per-mode budget at
            # the cap width on these modes, so instead of shipping the
            # under-converged sketch factors, refine deterministically at
            # the chosen (cap) ranks.  The reported bound stays the
            # measured sketch bound — honest about the miss (> target)
            # rather than silently optimistic.
            hop_methods = "eig"
            sketch_only = False
            _obs.event("fallback", hop="rand_to_eig",
                       modes=[int(mm) for mm in missed],
                       shape=list(self.shape), backend=self.backend)
            _metrics.REGISTRY.counter(
                "atucker_fallback_hops_total",
                "execute-time fallback ladder hops, by rung").inc(
                    hop="rand_to_eig", backend=self.backend)
        if not sketch_only:
            rcfg = replace(cfg, ranks=ranks, error_target=None,
                           rank_grid=None,
                           mode_order=tuple(s.mode for s in self.schedule))
            if hop_methods is not None:
                rcfg = replace(rcfg, methods=hop_methods)
            res = plan(self.shape, self.dtype, rcfg).execute(
                xa, record=record, donate=False)
            for t in res.trace:
                t.tail_err = tails[t.mode]
            return SthosvdResult(
                tucker=res.tucker, trace=res.trace,
                select_overhead_s=res.select_overhead_s + sum(seconds),
                error_bound=bound)
        n = len(self.shape)
        trace = [ModeTrace(s.mode, "rand", s.i_n, ranks[s.mode], j, dt,
                           backend=s.backend, predicted_s=s.predicted_s,
                           tail_err=tails[s.mode])
                 for s, j, dt in zip(self.schedule, js, seconds)]
        tune = sys.modules.get("repro.tune")
        sink = tune.active_sink() if tune is not None else None
        if sink is not None:
            sink.add_traces(trace, platform=jax.default_backend(),
                            dtype=cfg.compute_dtype or self.dtype,
                            order=n, als_iters=cfg.als_iters)
        return SthosvdResult(
            tucker=TuckerTensor(core=core,
                                factors=[factors[mm] for mm in range(n)]),
            trace=trace, select_overhead_s=0.0, error_bound=bound)

    def execute_batch(self, xs: jax.Array, *,
                      donate: bool | None = None) -> list[SthosvdResult]:
        """Decompose a fleet of same-shaped tensors (leading batch axis) with
        one vmapped program; returns one result per batch element.

        Sharded plans run the fleet item by item instead (shard_map
        schedules don't vmap) — each item still reuses the one cached
        compiled sweep, so the fleet pays a single compilation.

        ``donate`` behaves as in :meth:`execute`, applied to the whole
        stacked batch buffer (donating a fleet an engine stacked itself is
        free memory back)."""
        xin = xs
        xs = jnp.asarray(xs)
        if tuple(xs.shape[1:]) != self.shape:
            raise ValueError(
                f"plan is for batches of shape {self.shape}, got {xs.shape}")
        if str(xs.dtype) != self.dtype:
            raise ValueError(f"plan is for dtype {self.dtype}, got {xs.dtype}")
        if self.backend == "sharded" or self.is_adaptive:
            # adaptive: item by item — the policy may choose different
            # ranks per tensor, so there is no one vmappable program
            return [self.execute(xs[b]) for b in range(xs.shape[0])]
        donate_now = self._resolve_donate(created=xs is not xin,
                                          override=donate)
        cores, factors = self._sweep(batched=True, donate=donate_now)(xs)
        out = []
        for b in range(xs.shape[0]):
            out.append(SthosvdResult(
                tucker=TuckerTensor(core=cores[b],
                                    factors=[u[b] for u in factors]),
                trace=[ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, 0.0,
                                 backend=s.backend, predicted_s=s.predicted_s)
                       for s in self.schedule],
                select_overhead_s=0.0))
        return out

    __call__ = execute

    # -- derivation ----------------------------------------------------------
    def for_shape(self, shape: Sequence[int], *,
                  selector: Callable[..., str] | None = None,
                  keep_methods: bool = False) -> "TuckerPlan":
        """This plan's config/dtype re-planned at a different ``shape`` — the
        plan-reuse hook for the serve layer's shape buckets, where a bucket's
        warm plan spawns plans for the member shapes padded into it.

        By default the selector and mode order re-resolve against the new
        per-mode problem sizes, so the derived plan is indistinguishable from
        ``plan(shape, self.dtype, self.config)`` — same schedule, same cached
        compiled sweep, bitwise-identical execution to a direct plan (what
        the exact pad mode relies on).  ``keep_methods=True`` instead pins
        this plan's resolved per-mode solvers and frozen sweep order onto
        the new shape: zero selector calls, at the price of solver choices
        tuned for the bucket shape, not the member's.
        """
        shape = tuple(int(s) for s in shape)
        if len(shape) != len(self.shape):
            raise ValueError(
                f"plan is for an order-{len(self.shape)} tensor; cannot "
                f"derive an order-{len(shape)} plan (shape {shape})")
        if shape == self.shape:
            return self
        cfg = self.config
        if keep_methods:
            order = tuple(s.mode for s in self.schedule[:len(self.shape)])
            if self.is_adaptive:
                # the policy IS the method; pin only the sweep order
                # (config.methods stays the refinement solver choice)
                cfg = replace(cfg, mode_order=order)
            else:
                cfg = replace(cfg, methods=self.methods, mode_order=order)
        return plan(shape, self.dtype, cfg, selector=selector)

    # -- reporting -----------------------------------------------------------
    def describe(self) -> str:
        """Human-readable plan report: the frozen schedule in execution
        order with modeled cost and per-device peak per step, plus the
        totals, donation policy, and memory cap the plan was built under."""
        cfg = self.config
        cap = cfg.memory_cap_bytes
        head = (f"error_target={cfg.error_target:g} (rank-adaptive)"
                if self.is_adaptive else f"ranks {cfg.ranks}")
        lines = [
            f"TuckerPlan {self.shape} {self.dtype} -> {head} "
            f"[{cfg.variant}, backend={self.backend}]",
            f"  mode_order={cfg.mode_order!r}  "
            + (f"mode_parallel={cfg.mode_parallel!r}  "
               if cfg.mode_parallel != "off" else "")
            + f"memory_cap_bytes={cap if cap is not None else 'uncapped'}  "
            f"donate_input={'auto' if cfg.donate_input is None else cfg.donate_input}"
            + (" (resolves: donated for host inputs; a caller-held jax "
               "array is kept)" if self.donates and cfg.donate_input is None
               else f" (resolves: {'donated' if self.donates else 'undonated'})"),
        ]
        if self.is_adaptive:
            lines.append(
                f"  rank policy: tau²={self.schedule[0].tau:.3g}·||X||² "
                f"per mode  oversample={cfg.oversample}  "
                f"power_iters={cfg.power_iters}  "
                "(steps sized at grid caps; ranks resolve per input)")
        per_dev = any(s.n_shards > 1 for s in self.schedule)
        for k, s in enumerate(self.schedule):
            pred = f"  pred={s.predicted_s * 1e3:.3f}ms" if s.predicted_s \
                else ""
            shard = f"  shard_mode={s.shard_mode}/{s.n_shards}" \
                if per_dev else ""
            grp = f"  ∥group={s.group}" if s.group is not None else ""
            pol = (f"  grid={s.rank_grid[0]}..{s.rank_grid[-1]}"
                   f"({len(s.rank_grid)})"
                   if s.rank_grid is not None else "")
            lines.append(
                f"  step {k}: mode {s.mode} {s.method:>3s}  "
                f"I={s.i_n} R={s.r_n} J={s.j_n}  "
                f"flops={s.flops:.3g}  peak={s.peak_bytes:,}B"
                f"{shard}{grp}{pol}{pred}")
        total_pred = self.total_predicted_s
        lines.append(
            f"  total: flops={self.total_flops:.3g}  "
            f"peak={self.peak_bytes:,}B"
            + (" (per device)" if per_dev else "")
            + (f"  predicted={total_pred * 1e3:.3f}ms" if total_pred else "")
            + (f"  cap_headroom={cap - self.peak_bytes:,}B"
               if cap is not None else ""))
        return "\n".join(lines)

    # -- persistence (mirrors Selector.save) ---------------------------------
    def to_dict(self) -> dict:
        return {"version": PLAN_FORMAT_VERSION, "shape": list(self.shape),
                "dtype": self.dtype, "config": self.config.to_dict(),
                "schedule": [s.to_dict() for s in self.schedule],
                "select_seconds": self.select_seconds}

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "TuckerPlan":
        if d.get("version", 1) > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan format {d['version']} newer than supported "
                             f"{PLAN_FORMAT_VERSION}")
        return cls(shape=tuple(d["shape"]), dtype=d["dtype"],
                   config=TuckerConfig.from_dict(d["config"]),
                   schedule=tuple(ModeStep.from_dict(s) for s in d["schedule"]),
                   select_seconds=d.get("select_seconds", 0.0))

    @classmethod
    def from_json(cls, s: str) -> "TuckerPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())

    @classmethod
    def load(cls, path: str | Path) -> "TuckerPlan":
        return cls.from_json(Path(path).read_text())


# ---------------------------------------------------------------------------
# plan / decompose
# ---------------------------------------------------------------------------

def _resolve_rank_policy(shape: tuple[int, ...],
                         config: TuckerConfig) -> tuple[tuple, tuple]:
    """Per-mode candidate grids + sizing caps for a rank-adaptive config.

    The cap (each step's ``r_n`` — what scratch/peak modeling and the
    schedule DP see) is the largest candidate: ``ranks`` when given, else
    the grid maximum, else the full mode dimension.  A flat int
    ``rank_grid`` is one shared grid applied to every mode; a tuple of
    tuples is per-mode.  Candidates are deduplicated, clamped to
    ``[1, cap]``, and sorted ascending — the execute-time budget check
    walks them smallest-first."""
    n = len(shape)
    rg = config.rank_grid
    if rg is not None and all(isinstance(g, int) for g in rg):
        rg = tuple(rg for _ in range(n))
    if rg is not None and len(rg) != n:
        raise ValueError(f"rank_grid has {len(rg)} mode entries for an "
                         f"order-{n} tensor of shape {shape}")
    if config.ranks is not None and len(config.ranks) != n:
        raise ValueError(f"ranks {config.ranks} do not match order-{n} "
                         f"shape {shape}")
    grids = []
    for m in range(n):
        hi = shape[m] if config.ranks is None \
            else max(1, min(int(config.ranks[m]), shape[m]))
        if rg is None:
            g = tuple(range(1, hi + 1))
        else:
            g = tuple(sorted({max(1, min(int(r), hi)) for r in rg[m]}))
        grids.append(g)
    return tuple(grids), tuple(g[-1] for g in grids)


def _plan_adaptive(shape: tuple[int, ...], dtype,
                   config: TuckerConfig) -> TuckerPlan:
    """Rank-adaptive planning: freeze a rank POLICY, not ranks.

    The schedule is sized at each mode's rank CAP (see
    :func:`_resolve_rank_policy`) — the conservative figure for scratch
    modeling and ``memory_cap_bytes`` — with every step pinned to the
    ``rand`` sketch solver.  ``mode_order="opt"`` runs the schedule DP with
    the rank grid as its third decision axis
    (:func:`repro.core.schedule_opt.optimize_schedule`), so the sweep order
    is chosen for the policy, not just the caps.  Each step then carries
    its ``rank_grid`` and the equi-partitioned HOSVD budget share
    ``tau = error_target²/N``; the actual ranks resolve per input at
    execute time (:meth:`TuckerPlan._sketch_pass`)."""
    import time as _time
    n = len(shape)
    compute_dtype = jnp.dtype(config.compute_dtype) if config.compute_dtype \
        else dtype
    backend = resolve_backend(config.impl, dtype=compute_dtype)
    if not backend.supports_solver("rand"):
        raise ValueError(f"backend {backend.name!r} cannot run the 'rand' "
                         "sketch solver rank-adaptive plans are built on "
                         f"(capabilities: {backend.solvers})")
    grids, caps = _resolve_rank_policy(shape, config)
    from .selector import default_selector
    cost_model = default_selector(backend=backend.name).cost_model
    t0 = _time.perf_counter()
    mode_order = config.mode_order
    if mode_order == "opt":
        from .schedule_opt import optimize_schedule
        mode_order = optimize_schedule(
            shape, caps, methods=["rand"] * n, als_iters=config.als_iters,
            itemsize=compute_dtype.itemsize, cost_model=cost_model,
            memory_cap_bytes=config.memory_cap_bytes,
            rank_grid=grids).order
    schedule = resolve_schedule(
        shape, caps, variant="sthosvd", methods="rand",
        mode_order=mode_order, als_iters=config.als_iters,
        itemsize=compute_dtype.itemsize, backend=backend.name,
        n_shards=1, cost_model=cost_model,
        memory_cap_bytes=config.memory_cap_bytes)
    tau = float(config.error_target) ** 2 / n
    schedule = tuple(replace(s, rank_grid=grids[s.mode], tau=tau)
                     for s in schedule)
    return TuckerPlan(shape=shape, dtype=str(dtype), config=config,
                      schedule=schedule,
                      select_seconds=_time.perf_counter() - t0)


def plan(shape: Sequence[int], dtype, config: TuckerConfig, *,
         selector: Callable[..., str] | None = None) -> TuckerPlan:
    """Resolve ``config`` against a concrete (shape, dtype) → ``TuckerPlan``.

    All selector/cost-model queries happen here, against the statically known
    per-mode problem sizes, and ``config.impl`` (possibly ``"auto"``) is
    resolved through the backend registry against the current platform,
    compute dtype, and mesh; ``TuckerPlan.execute`` never selects or
    resolves again.  With a mesh (``impl="sharded"``, or ``"auto"`` when
    one is attached) the shard-mode schedule is frozen here too: per-step
    shard choice, reshard points, and per-device ``peak_bytes``.

    A config with ``error_target=`` routes to rank-ADAPTIVE planning
    (:func:`_plan_adaptive`): the plan freezes a rank policy and sweep
    order; per-mode ranks resolve per input at execute time.
    """
    if not _obs.enabled():
        return _plan(shape, dtype, config, selector=selector)
    with _obs.span("plan", shape=[int(s) for s in shape],
                   dtype=str(jnp.dtype(dtype)), impl=config.impl,
                   variant=config.variant,
                   mode_order=str(config.mode_order),
                   adaptive=config.error_target is not None) as sp:
        p = _plan(shape, dtype, config, selector=selector)
        sp.set(backend=p.backend, n_steps=len(p.schedule),
               methods=list(p.methods), select_s=p.select_seconds,
               predicted_s=p.total_predicted_s, peak_bytes=p.peak_bytes)
        return p


def _plan(shape: Sequence[int], dtype, config: TuckerConfig, *,
          selector: Callable[..., str] | None = None) -> TuckerPlan:
    shape = tuple(int(s) for s in shape)
    dtype = jnp.dtype(dtype)
    if config.error_target is not None:
        return _plan_adaptive(shape, dtype, config)
    compute_dtype = jnp.dtype(config.compute_dtype) if config.compute_dtype \
        else dtype
    backend = resolve_backend(config.impl, dtype=compute_dtype,
                              mesh=config.mesh)
    if backend.requires_mesh and config.variant != "sthosvd":
        raise ValueError(f"backend {backend.name!r} supports variant "
                         f"'sthosvd' only, got {config.variant!r}")
    # selector resolution sees the RESOLVED backend: a per-backend trained
    # model (repro.tune) outranks the platform-pooled one, and its embedded
    # (possibly calibrated) cost model prices the schedule either way
    from .selector import default_selector
    timed = None
    if config.methods == "auto":
        if selector is None:
            selector = default_selector(backend=backend.name)
        selector = timed = TimedSelector(selector)
    cost_model = getattr(selector, "cost_model", None) or \
        default_selector(backend=backend.name).cost_model
    mp: str | int = config.mode_parallel
    if not backend.requires_mesh and mp != "off":
        if mp == "auto":
            mp = "off"   # single device: sequential shrinking always wins
        else:
            raise ValueError(
                f"mode_parallel={mp} needs a sharded backend (attach a "
                f"mesh); impl resolved to {backend.name!r}")
    schedule = resolve_schedule(
        shape, config.ranks, variant=config.variant, methods=config.methods,
        mode_order=config.mode_order, selector=selector,
        als_iters=config.als_iters, hooi_iters=config.hooi_iters,
        itemsize=compute_dtype.itemsize, backend=backend.name,
        n_shards=config.n_shards if backend.requires_mesh else 1,
        cost_model=cost_model, memory_cap_bytes=config.memory_cap_bytes,
        mode_parallel=mp)
    p = TuckerPlan(shape=shape, dtype=str(dtype), config=config,
                   schedule=schedule,
                   select_seconds=timed.seconds if timed else 0.0)
    if config.memory_cap_bytes is not None and \
            p.peak_bytes > config.memory_cap_bytes:
        # every step fits, but the plan-level (donation-aware) peak does
        # not: an undonated sweep keeps the dead input copy live through
        # steps 1..N-1 on top of each step's working set
        from .schedule_opt import MemoryCapError
        raise MemoryCapError(
            f"schedule fits memory_cap_bytes={config.memory_cap_bytes:,} "
            f"per step, but the undonated sweep's modeled peak is "
            f"{p.peak_bytes:,} bytes — the caller-held input copy "
            f"({p.input_bytes:,} bytes) rides on every step after the "
            "first; enable donation (donate_input=True or the default "
            "auto policy with host inputs) or raise the cap")
    return p


# ---------------------------------------------------------------------------
# Execute-time fallback ladder
# ---------------------------------------------------------------------------

def _replan_safe(p: "TuckerPlan", cfg: TuckerConfig) -> "TuckerPlan | None":
    """Plan a ladder hop's degraded config, or None when the hop itself
    cannot be planned (e.g. the tighter cap admits no schedule) — the
    ladder then moves on / gives up rather than masking the original
    failure with a planning error."""
    try:
        return plan(p.shape, p.dtype, cfg)
    except Exception:
        return None


def _next_hop(p: "TuckerPlan", err: BaseException,
              applied: list[str]) -> "tuple[str, TuckerPlan] | None":
    """Pick the next ladder rung for a classified failure, or None when the
    ladder is exhausted (each rung applies at most once, in a fixed order,
    so the ladder is bounded and deterministic)."""
    cfg = p.config
    has_pallas = any(s.backend == "pallas" for s in p.schedule)

    def to_matfree():
        if has_pallas and "pallas_to_matfree" not in applied:
            p2 = _replan_safe(p, replace(cfg, impl="matfree"))
            if p2 is not None:
                return "pallas_to_matfree", p2
        return None

    if isinstance(err, NumericalError):
        if "als_to_eig" not in applied and \
                any(s.method == "als" for s in p.schedule):
            methods = tuple("eig" if m == "als" else m for m in p.methods)
            p2 = _replan_safe(p, replace(cfg, methods=methods))
            if p2 is not None:
                return "als_to_eig", p2
        return to_matfree()
    if isinstance(err, ResourceError):
        # rung 1 retries the SAME schedule with donation forced off (an
        # aliased buffer is the usual marginal allocation); rung 2 replans
        # the whole sweep under a tighter per-device cap
        if "donate_off" not in applied:
            return "donate_off", p
        if "replan_cap" not in applied:
            current = cfg.memory_cap_bytes or p.peak_bytes
            cap = max(1, int(0.75 * current))
            p2 = _replan_safe(p, replace(cfg, memory_cap_bytes=cap,
                                         mode_order="opt"))
            if p2 is not None:
                return "replan_cap", p2
        return None
    # unclassified runtime failure: a kernel-backend swap is the only hop
    # that can plausibly help (and the only one that is safe to try)
    return to_matfree()


def _emit_hop(p: "TuckerPlan", name: str, err: BaseException) -> None:
    _obs.event("fallback", hop=name, error=type(err).__name__,
               shape=list(p.shape), backend=p.backend)
    _metrics.REGISTRY.counter(
        "atucker_fallback_hops_total",
        "execute-time fallback ladder hops, by rung").inc(
            hop=name, backend=p.backend)


def _run_with_fallback(p0: "TuckerPlan", can_retry, run,
                       donate_override: bool | None) -> SthosvdResult:
    """Drive ``run(plan, donate)`` through the fallback ladder: classify
    each failure, degrade one rung at a time, re-raise the classified error
    once no rung remains.  Input-side failures (bad input, deadline,
    cancellation) never hop — retrying cannot fix the caller's data."""
    p, donate_now = p0, donate_override
    applied: list[str] = []
    while True:
        try:
            return run(p, donate_now)
        except Exception as e:  # noqa: BLE001 - classification is the point
            if isinstance(e, (InputError, DeadlineError, CancelledError)):
                raise
            terr = classify_exception(e)
            if not can_retry():
                # the failed sweep consumed the donated input buffer and no
                # original survives to re-materialize from — surface the
                # classification instead of hopping onto a dead input
                if terr is not None and terr is not e:
                    raise terr from e
                raise
            hop = _next_hop(p, terr if terr is not None else e, applied)
            if hop is None:
                if terr is not None and terr is not e:
                    raise terr from e
                raise
            name, p2 = hop
            applied.append(name)
            if name == "donate_off":
                donate_now = False
            _emit_hop(p, name, terr if terr is not None else e)
            # the degraded plan records through the same tune/obs machinery
            # as any other execute, so the flywheel learns the hop happened
            p = p2


def decompose(x: jax.Array, config: TuckerConfig, *,
              selector: Callable[..., str] | None = None) -> SthosvdResult:
    """One-shot convenience: ``plan(x.shape, x.dtype, config).execute(x)``.
    The compiled sweep is still cached process-wide, so repeated calls on
    same-shaped inputs only pay the (cheap) schedule resolution."""
    x = jnp.asarray(x)
    return plan(x.shape, x.dtype, config, selector=selector).execute(x)
