"""Plan/execute front door for a-Tucker: ``TuckerConfig`` → ``TuckerPlan``.

The legacy entry points (`sthosvd` & friends) re-run the adaptive selector
and re-dispatch solvers inside every call.  Following the plan/execute split
of randomized-Tucker systems that precompute their sketch/solve schedules,
this module moves ALL input-adaptive decisions to a one-time ``plan`` step:

    cfg  = TuckerConfig(ranks=(10, 10, 5), methods="auto")
    p    = plan(x.shape, x.dtype, cfg)     # selector runs here, never again
    res  = p.execute(x)                    # ONE cached jitted program
    ress = p.execute_batch(xs)             # same program, vmapped over axis 0

Because the per-mode solver schedule and mode order are frozen in the plan,
the entire sweep traces as a single XLA program, cached process-wide by
``(shape, dtype, schedule+backend, variant, als_iters, compute_dtype)`` — so
repeated executes on same-shaped inputs cost zero recompiles and zero
selector invocations.  Plans are JSON-serializable (``save``/``load``,
mirroring ``Selector.save``) so a schedule tuned on one box can ship to
another.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .backend import backend_names, get_backend, resolve_backend
from .plan import (
    ModeStep,
    TimedSelector,
    VARIANTS,
    resolve_schedule,
    sweep_hooi,
    sweep_sthosvd,
    sweep_thosvd,
)
from .solvers import DEFAULT_ALS_ITERS
from .sthosvd import ModeTrace, SthosvdResult, TuckerTensor

PLAN_FORMAT_VERSION = 1


@dataclass(frozen=True)
class TuckerConfig:
    """Frozen description of a Tucker decomposition job (the *what*).

    ``plan()`` turns it plus a concrete (shape, dtype) into a ``TuckerPlan``
    (the *how*): per-mode solvers resolved, costs estimated, sweep compiled.

    compute_dtype is the precision policy: inputs are cast to it before the
    sweep (e.g. "float32" to decompose bf16 weights at full precision); the
    default ``None`` keeps the input dtype.

    ``impl`` names an ops backend from :mod:`repro.core.backend` (``matfree``
    | ``explicit`` | ``pallas`` | any custom-registered name) or ``"auto"``
    to let ``plan()`` pick the best backend for the current platform and
    compute dtype; the resolved choice is frozen into the plan's schedule.
    """
    ranks: tuple[int, ...]
    variant: str = "sthosvd"
    methods: str | tuple[str, ...] = "auto"
    mode_order: tuple[int, ...] | str | None = None
    impl: str = "matfree"
    als_iters: int = DEFAULT_ALS_ITERS
    hooi_iters: int = 3
    compute_dtype: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "ranks", tuple(int(r) for r in self.ranks))
        if not isinstance(self.methods, str):
            object.__setattr__(self, "methods", tuple(self.methods))
        if isinstance(self.mode_order, (list, tuple)):
            object.__setattr__(self, "mode_order",
                               tuple(int(m) for m in self.mode_order))
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}; "
                             f"expected one of {VARIANTS}")
        if self.impl != "auto":
            get_backend(self.impl)   # ValueError on unregistered names
        if self.als_iters < 1 or self.hooi_iters < 0:
            raise ValueError("als_iters must be ≥1 and hooi_iters ≥0")

    def to_dict(self) -> dict:
        return {"ranks": list(self.ranks), "variant": self.variant,
                "methods": (self.methods if isinstance(self.methods, str)
                            else list(self.methods)),
                "mode_order": (list(self.mode_order)
                               if isinstance(self.mode_order, tuple)
                               else self.mode_order),
                "impl": self.impl, "als_iters": self.als_iters,
                "hooi_iters": self.hooi_iters,
                "compute_dtype": self.compute_dtype}

    @classmethod
    def from_dict(cls, d: dict) -> "TuckerConfig":
        return cls(ranks=tuple(d["ranks"]), variant=d.get("variant", "sthosvd"),
                   methods=(d["methods"] if isinstance(d["methods"], str)
                            else tuple(d["methods"])),
                   mode_order=(tuple(d["mode_order"])
                               if isinstance(d.get("mode_order"), list)
                               else d.get("mode_order")),
                   impl=d.get("impl", "matfree"),
                   als_iters=d.get("als_iters", DEFAULT_ALS_ITERS),
                   hooi_iters=d.get("hooi_iters", 3),
                   compute_dtype=d.get("compute_dtype"))


# ---------------------------------------------------------------------------
# Process-wide compiled-sweep cache
# ---------------------------------------------------------------------------

_SWEEP_CACHE: dict[tuple, Callable] = {}

#: builds = new jitted programs constructed; hits = cache reuses;
#: traces = times a sweep body actually traced (== XLA compilations).
CACHE_STATS = {"builds": 0, "hits": 0, "traces": 0}


def clear_sweep_cache() -> None:
    _SWEEP_CACHE.clear()
    CACHE_STATS.update(builds=0, hits=0, traces=0)


def _make_sweep(p: "TuckerPlan", batched: bool) -> Callable:
    steps = p.schedule   # each step carries its resolved ops backend
    cfg = p.config
    n_init = len(p.shape)  # HOOI: first full sweep is the st-HOSVD init
    cdtype = jnp.dtype(cfg.compute_dtype) if cfg.compute_dtype else None

    def sweep(x):
        CACHE_STATS["traces"] += 1
        if cdtype is not None:
            x = x.astype(cdtype)
        if cfg.variant == "sthosvd":
            return sweep_sthosvd(x, steps, als_iters=cfg.als_iters)
        if cfg.variant == "thosvd":
            return sweep_thosvd(x, steps, als_iters=cfg.als_iters)
        return sweep_hooi(x, steps, als_iters=cfg.als_iters, n_init=n_init)

    return jax.jit(jax.vmap(sweep) if batched else sweep)


# ---------------------------------------------------------------------------
# TuckerPlan
# ---------------------------------------------------------------------------

@dataclass
class TuckerPlan:
    """A frozen, executable solver schedule for one (shape, dtype, config).

    ``schedule`` lists every mode solve in execution order with the solver
    the selector (or explicit methods) chose and the modeled FLOPs / peak
    working-set bytes of that step.  ``execute`` runs the whole sweep as one
    cached jitted program; ``execute_batch`` vmaps it over a leading axis.
    """
    shape: tuple[int, ...]
    dtype: str
    config: TuckerConfig
    schedule: tuple[ModeStep, ...]
    select_seconds: float = 0.0     # one-time planning cost (selector calls)

    # -- introspection -------------------------------------------------------
    @property
    def backend(self) -> str:
        """The resolved ops backend this plan's steps run on (``config.impl``
        may be ``"auto"``; this is what it resolved to at plan time)."""
        names = {s.backend for s in self.schedule}
        return self.schedule[0].backend if len(names) == 1 else "mixed"

    @property
    def methods(self) -> tuple[str, ...]:
        """Resolved solver per mode (first visit order, sorted by mode)."""
        first: dict[int, str] = {}
        for s in self.schedule:
            first.setdefault(s.mode, s.method)
        return tuple(first[m] for m in sorted(first))

    @property
    def total_flops(self) -> float:
        return sum(s.flops for s in self.schedule)

    @property
    def peak_bytes(self) -> int:
        return max(s.peak_bytes for s in self.schedule)

    def _cache_key(self, batched: bool) -> tuple:
        # keyed on the RESOLVED per-step backend, not config.impl: two plans
        # whose "auto" resolved identically share one compiled sweep
        return (self.shape, self.dtype,
                tuple((s.mode, s.method, s.r_n, s.backend)
                      for s in self.schedule),
                self.config.variant, self.config.als_iters,
                self.config.compute_dtype, batched)

    def _sweep(self, batched: bool) -> Callable:
        key = self._cache_key(batched)
        fn = _SWEEP_CACHE.get(key)
        if fn is None:
            fn = _SWEEP_CACHE[key] = _make_sweep(self, batched)
            CACHE_STATS["builds"] += 1
        else:
            CACHE_STATS["hits"] += 1
        return fn

    # -- execution -----------------------------------------------------------
    def execute(self, x: jax.Array) -> SthosvdResult:
        """Run the frozen schedule on ``x`` as one compiled program."""
        x = jnp.asarray(x)
        if tuple(x.shape) != self.shape:
            raise ValueError(f"plan is for shape {self.shape}, got {x.shape}")
        if str(x.dtype) != self.dtype:
            raise ValueError(f"plan is for dtype {self.dtype}, got {x.dtype}")
        core, factors = self._sweep(batched=False)(x)
        return SthosvdResult(
            tucker=TuckerTensor(core=core, factors=list(factors)),
            trace=[ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, 0.0,
                             backend=s.backend)
                   for s in self.schedule],
            select_overhead_s=0.0)

    def execute_batch(self, xs: jax.Array) -> list[SthosvdResult]:
        """Decompose a fleet of same-shaped tensors (leading batch axis) with
        one vmapped program; returns one result per batch element."""
        xs = jnp.asarray(xs)
        if tuple(xs.shape[1:]) != self.shape:
            raise ValueError(
                f"plan is for batches of shape {self.shape}, got {xs.shape}")
        if str(xs.dtype) != self.dtype:
            raise ValueError(f"plan is for dtype {self.dtype}, got {xs.dtype}")
        cores, factors = self._sweep(batched=True)(xs)
        out = []
        for b in range(xs.shape[0]):
            out.append(SthosvdResult(
                tucker=TuckerTensor(core=cores[b],
                                    factors=[u[b] for u in factors]),
                trace=[ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, 0.0,
                                 backend=s.backend)
                       for s in self.schedule],
                select_overhead_s=0.0))
        return out

    __call__ = execute

    # -- persistence (mirrors Selector.save) ---------------------------------
    def to_dict(self) -> dict:
        return {"version": PLAN_FORMAT_VERSION, "shape": list(self.shape),
                "dtype": self.dtype, "config": self.config.to_dict(),
                "schedule": [s.to_dict() for s in self.schedule],
                "select_seconds": self.select_seconds}

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "TuckerPlan":
        if d.get("version", 1) > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan format {d['version']} newer than supported "
                             f"{PLAN_FORMAT_VERSION}")
        return cls(shape=tuple(d["shape"]), dtype=d["dtype"],
                   config=TuckerConfig.from_dict(d["config"]),
                   schedule=tuple(ModeStep.from_dict(s) for s in d["schedule"]),
                   select_seconds=d.get("select_seconds", 0.0))

    @classmethod
    def from_json(cls, s: str) -> "TuckerPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json())

    @classmethod
    def load(cls, path: str | Path) -> "TuckerPlan":
        return cls.from_json(Path(path).read_text())


# ---------------------------------------------------------------------------
# plan / decompose
# ---------------------------------------------------------------------------

def plan(shape: Sequence[int], dtype, config: TuckerConfig, *,
         selector: Callable[..., str] | None = None) -> TuckerPlan:
    """Resolve ``config`` against a concrete (shape, dtype) → ``TuckerPlan``.

    All selector/cost-model queries happen here, against the statically known
    per-mode problem sizes, and ``config.impl`` (possibly ``"auto"``) is
    resolved through the backend registry against the current platform and
    compute dtype; ``TuckerPlan.execute`` never selects or resolves again.
    """
    shape = tuple(int(s) for s in shape)
    dtype = jnp.dtype(dtype)
    compute_dtype = jnp.dtype(config.compute_dtype) if config.compute_dtype \
        else dtype
    backend = resolve_backend(config.impl, dtype=compute_dtype)
    timed = None
    if config.methods == "auto":
        if selector is None:
            from .selector import default_selector
            selector = default_selector()
        selector = timed = TimedSelector(selector)
    schedule = resolve_schedule(
        shape, config.ranks, variant=config.variant, methods=config.methods,
        mode_order=config.mode_order, selector=selector,
        als_iters=config.als_iters, hooi_iters=config.hooi_iters,
        itemsize=compute_dtype.itemsize, backend=backend.name)
    return TuckerPlan(shape=shape, dtype=str(dtype), config=config,
                      schedule=schedule,
                      select_seconds=timed.seconds if timed else 0.0)


def decompose(x: jax.Array, config: TuckerConfig, *,
              selector: Callable[..., str] | None = None) -> SthosvdResult:
    """One-shot convenience: ``plan(x.shape, x.dtype, config).execute(x)``.
    The compiled sweep is still cached process-wide, so repeated calls on
    same-shaped inputs only pay the (cheap) schedule resolution."""
    x = jnp.asarray(x)
    return plan(x.shape, x.dtype, config, selector=selector).execute(x)
