"""Mode-wise flexible st-HOSVD (a-Tucker Alg. 2) and coarse-grained variants.

These are the legacy per-call entry points, kept as thin wrappers over the
plan/execute machinery (:mod:`repro.core.plan`): the per-mode solver schedule
is resolved up front (selector time is reported as ``select_overhead_s``) and
then run eagerly — per-mode jitted solves with real wall-clock in the trace.
For amortized/batched execution use :mod:`repro.core.api` instead.

``methods`` accepts:
  - "auto"              → adaptive selector (decision tree, cost-model fallback)
  - "eig"/"als"/"svd"   → coarse-grained single solver (paper baselines)
  - sequence per mode   → explicit mode-wise schedule, e.g. ("eig","als","als")
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax

from ..obs import trace as _obs
from . import tensor_ops as T
from .solvers import ALS, DEFAULT_ALS_ITERS, EIG, SVD


@dataclass
class TuckerTensor:
    """Result of a Tucker decomposition:  X ≈ G ×_1 U^(1) ··· ×_N U^(N)."""
    core: jax.Array
    factors: list[jax.Array]          # factors[n]: (I_n, R_n)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(u.shape[0] for u in self.factors)

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(self.core.shape)

    def reconstruct(self) -> jax.Array:
        return T.reconstruct(self.core, self.factors)

    def rel_error(self, x: jax.Array) -> jax.Array:
        return T.rel_error(x, self.core, self.factors)

    @property
    def n_elements(self) -> int:
        return int(self.core.size + sum(u.size for u in self.factors))

    @property
    def compression_ratio(self) -> float:
        return float(math.prod(self.shape)) / float(self.n_elements)


@dataclass
class ModeTrace:
    mode: int
    method: str
    i_n: int
    r_n: int
    j_n: int
    seconds: float             # measured wall-clock (0.0 inside fused sweeps)
    backend: str = "matfree"   # ops backend the solve ran on
    predicted_s: float = 0.0   # plan-time prediction from a calibrated cost
                               # model (0.0 = uncalibrated) — compare with
                               # ``seconds`` for predicted-vs-actual drift
    tail_err: float = 0.0      # discarded energy at this step as a fraction
                               # of ||X||² (rank-adaptive executions only;
                               # 0.0 = not measured).  Flows into the tune
                               # store as the achieved-error label.

    @property
    def delta_s(self) -> float:
        """Predicted-vs-actual drift: ``seconds - predicted_s`` (positive =
        slower than the calibrated model expected).  Only meaningful when
        both sides are real — a fused sweep has no per-step ``seconds`` and
        an uncalibrated plan no ``predicted_s``."""
        return self.seconds - self.predicted_s


@dataclass
class SthosvdResult:
    tucker: TuckerTensor
    trace: list[ModeTrace] = field(default_factory=list)
    select_overhead_s: float = 0.0
    error_bound: float | None = None  # rank-adaptive executions: guaranteed
                                      # relative-error upper bound
                                      # sqrt(Σ_n tail_err_n) from the HOSVD
                                      # inequality; None for fixed-rank runs

    @property
    def methods(self) -> tuple[str, ...]:
        return tuple(t.method for t in sorted(self.trace, key=lambda t: t.mode))

    def report(self) -> str:
        """Per-step execution report in schedule order: solver, problem
        size, measured seconds, and — when a calibrated cost model priced
        the plan — predicted seconds and the drift, so order-search wins
        (and calibration rot) are visible in traces, not just benches."""
        predicted = any(t.predicted_s for t in self.trace)
        head = "step  mode method backend    I     R     J    seconds"
        if predicted:
            head += "  predicted    delta"
        lines = [head]
        for k, t in enumerate(self.trace):
            row = (f"{k:>4}  {t.mode:>4} {t.method:>6} {t.backend:>8} "
                   f"{t.i_n:>5} {t.r_n:>5} {t.j_n:>5} {t.seconds:>9.4f}")
            if predicted:
                row += f" {t.predicted_s:>10.4f} {t.delta_s:>+8.4f}"
            lines.append(row)
        total_s = sum(t.seconds for t in self.trace)
        total = f"total{'':>38}{total_s:>9.4f}"
        if predicted:
            total_p = sum(t.predicted_s for t in self.trace)
            total += f" {total_p:>10.4f} {total_s - total_p:>+8.4f}"
        lines.append(total)
        return "\n".join(lines)


def sthosvd(
    x: jax.Array,
    ranks: Sequence[int],
    methods: str | Sequence[str] = "auto",
    *,
    selector: Callable[..., str] | None = None,
    mode_order: Sequence[int] | str | None = None,
    als_iters: int = DEFAULT_ALS_ITERS,
    impl: str = "matfree",
    memory_cap_bytes: int | None = None,
    block_until_ready: bool = False,
) -> SthosvdResult:
    """Flexible st-HOSVD (Alg. 2).  Returns factors, core, per-mode trace.

    ``mode_order`` defaults to the paper's 1..N sweep; adaptive shrink-ratio
    ordering (beyond-paper, DESIGN.md §9.3) is available via
    ``mode_order="shrink"``, and the exact DP schedule search (order AND
    per-step solver, optionally under ``memory_cap_bytes``) via
    ``mode_order="opt"`` (see :mod:`repro.core.schedule_opt`).

    ``memory_cap_bytes`` is the hard plan-time ceiling on each step's
    modeled peak working set; infeasible schedules raise ``MemoryCapError``
    naming the binding step before anything is allocated.

    ``impl`` names an ops backend (``matfree`` | ``explicit`` | ``pallas`` |
    custom-registered) or ``"auto"`` for the platform default.
    """
    from .backend import resolve_backend
    from .plan import TimedSelector, resolve_schedule, run_schedule

    backend = resolve_backend(impl, dtype=x.dtype)
    timed = None
    if methods == "auto":
        if selector is None:
            from .selector import default_selector
            selector = default_selector()
        selector = timed = TimedSelector(selector)
    schedule = resolve_schedule(
        x.shape, ranks, variant="sthosvd", methods=methods,
        mode_order=mode_order, selector=selector, als_iters=als_iters,
        itemsize=x.dtype.itemsize, backend=backend.name,
        memory_cap_bytes=memory_cap_bytes)

    with _obs.span("execute", shape=list(x.shape), dtype=str(x.dtype),
                   backend=backend.name, variant="sthosvd", legacy=True):
        core, factors, seconds = run_schedule(
            x, schedule, sequential=True, als_iters=als_iters,
            block_until_ready=block_until_ready)
    trace = [ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, dt,
                       backend=s.backend, predicted_s=s.predicted_s)
             for s, dt in zip(schedule, seconds)]
    tucker = TuckerTensor(core=core, factors=[factors[m] for m in range(x.ndim)])
    return SthosvdResult(tucker=tucker, trace=trace,
                         select_overhead_s=timed.seconds if timed else 0.0)


# Coarse-grained baselines (paper Sec. VI) -----------------------------------

def sthosvd_eig(x, ranks, **kw) -> SthosvdResult:
    return sthosvd(x, ranks, methods=EIG, **kw)


def sthosvd_als(x, ranks, **kw) -> SthosvdResult:
    return sthosvd(x, ranks, methods=ALS, **kw)


def sthosvd_svd(x, ranks, **kw) -> SthosvdResult:
    return sthosvd(x, ranks, methods=SVD, **kw)
