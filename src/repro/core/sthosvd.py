"""Mode-wise flexible st-HOSVD (a-Tucker Alg. 2) and coarse-grained variants.

The mode loop runs at trace/Python level (every mode has different shapes →
separate XLA programs anyway, exactly like the per-mode kernel launches in
the paper); each per-mode solve is a jitted, matricization-free program.

``methods`` accepts:
  - "auto"              → adaptive selector (decision tree, cost-model fallback)
  - "eig"/"als"/"svd"   → coarse-grained single solver (paper baselines)
  - sequence per mode   → explicit mode-wise schedule, e.g. ("eig","als","als")
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from . import tensor_ops as T
from .solvers import ALS, DEFAULT_ALS_ITERS, EIG, SOLVERS, SVD


@dataclass
class TuckerTensor:
    """Result of a Tucker decomposition:  X ≈ G ×_1 U^(1) ··· ×_N U^(N)."""
    core: jax.Array
    factors: list[jax.Array]          # factors[n]: (I_n, R_n)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(u.shape[0] for u in self.factors)

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(self.core.shape)

    def reconstruct(self) -> jax.Array:
        return T.reconstruct(self.core, self.factors)

    def rel_error(self, x: jax.Array) -> jax.Array:
        return T.rel_error(x, self.core, self.factors)

    @property
    def n_elements(self) -> int:
        return int(self.core.size + sum(u.size for u in self.factors))

    @property
    def compression_ratio(self) -> float:
        return float(math.prod(self.shape)) / float(self.n_elements)


@dataclass
class ModeTrace:
    mode: int
    method: str
    i_n: int
    r_n: int
    j_n: int
    seconds: float


@dataclass
class SthosvdResult:
    tucker: TuckerTensor
    trace: list[ModeTrace] = field(default_factory=list)
    select_overhead_s: float = 0.0

    @property
    def methods(self) -> tuple[str, ...]:
        return tuple(t.method for t in sorted(self.trace, key=lambda t: t.mode))


def _resolve_methods(methods, n_modes: int) -> list[str]:
    if isinstance(methods, str):
        return [methods] * n_modes
    methods = list(methods)
    if len(methods) != n_modes:
        raise ValueError(f"need {n_modes} per-mode methods, got {len(methods)}")
    return methods


def sthosvd(
    x: jax.Array,
    ranks: Sequence[int],
    methods: str | Sequence[str] = "auto",
    *,
    selector: Callable[..., str] | None = None,
    mode_order: Sequence[int] | None = None,
    als_iters: int = DEFAULT_ALS_ITERS,
    impl: str = "matfree",
    block_until_ready: bool = False,
) -> SthosvdResult:
    """Flexible st-HOSVD (Alg. 2).  Returns factors, core, per-mode trace.

    ``mode_order`` defaults to the paper's 1..N sweep; adaptive shrink-ratio
    ordering (beyond-paper, DESIGN.md §9.3) is available via
    ``mode_order="shrink"``.
    """
    n = x.ndim
    ranks = tuple(int(r) for r in ranks)
    if len(ranks) != n:
        raise ValueError(f"ranks {ranks} do not match tensor order {n}")
    for m, (i, r) in enumerate(zip(x.shape, ranks)):
        if not (1 <= r <= i):
            raise ValueError(f"rank {r} invalid for mode {m} (dim {i})")

    if mode_order is None:
        order = list(range(n))
    elif mode_order == "shrink":
        order = sorted(range(n), key=lambda m: ranks[m] / x.shape[m])
    else:
        order = list(mode_order)
        if sorted(order) != list(range(n)):
            raise ValueError(f"mode_order {order} must be a permutation of 0..{n-1}")

    fixed = None if methods == "auto" else _resolve_methods(methods, n)
    if methods == "auto" and selector is None:
        from .selector import default_selector
        selector = default_selector()

    y = x
    factors: list[jax.Array | None] = [None] * n
    trace: list[ModeTrace] = []
    select_overhead = 0.0

    for mode in order:
        i_n = y.shape[mode]
        r_n = ranks[mode]
        j_n = y.size // i_n
        if fixed is not None:
            method = fixed[mode]
        else:
            t0 = time.perf_counter()
            method = selector(i_n=i_n, r_n=r_n, j_n=j_n)
            select_overhead += time.perf_counter() - t0
        if method not in SOLVERS:
            raise ValueError(f"unknown solver {method!r}")

        t0 = time.perf_counter()
        if method == ALS:
            res = SOLVERS[ALS](y, mode, r_n, num_iters=als_iters, impl=impl)
        else:
            res = SOLVERS[method](y, mode, r_n, impl=impl)
        if block_until_ready:
            jax.block_until_ready(res.y_new)
        dt = time.perf_counter() - t0

        factors[mode] = res.u
        y = res.y_new
        trace.append(ModeTrace(mode, method, i_n, r_n, j_n, dt))

    tucker = TuckerTensor(core=y, factors=factors)  # type: ignore[arg-type]
    return SthosvdResult(tucker=tucker, trace=trace, select_overhead_s=select_overhead)


# Coarse-grained baselines (paper Sec. VI) -----------------------------------

def sthosvd_eig(x, ranks, **kw) -> SthosvdResult:
    return sthosvd(x, ranks, methods=EIG, **kw)


def sthosvd_als(x, ranks, **kw) -> SthosvdResult:
    return sthosvd(x, ranks, methods=ALS, **kw)


def sthosvd_svd(x, ranks, **kw) -> SthosvdResult:
    return sthosvd(x, ranks, methods=SVD, **kw)
