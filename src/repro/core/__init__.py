"""a-Tucker core: input-adaptive, matricization-free Tucker decomposition.

Public API:
  TuckerConfig / plan / TuckerPlan / decompose — plan/execute front door
      (static solver schedules, cached jitted sweeps, batched execution)
  sthosvd / sthosvd_eig / sthosvd_als / sthosvd_svd — flexible st-HOSVD
      (legacy per-call wrappers over the same schedule runner)
  TuckerTensor — decomposition result (reconstruct, rel_error, ratio)
  Selector / default_selector — adaptive solver selector, resolved per
      (platform, backend); trained/calibrated by the repro.tune flywheel
  CostModel — Eq. 4/5 constants (textbook default, hardware-calibratable)
  tensor_ops — matricization-free TTM/TTT/Gram (+ explicit baselines)
  OpsBackend / register_backend / get_backend / resolve_backend /
      backend_names — pluggable ops-backend registry (matfree | explicit |
      pallas | sharded | custom) behind TuckerConfig.impl
  distributed — mesh execution engine behind the ``sharded`` backend
      (sthosvd_distributed legacy entry, pick_shard_mode, shard_map sweeps)
"""

# NOTE: the attribute ``repro.core.plan`` is the api.plan FUNCTION (the
# front-door entry point), which shadows the ``plan`` submodule on the
# package.  ``from repro.core.plan import ...`` still resolves the module
# (sys.modules), and ``plan_lib`` aliases it for attribute-style access.
from . import backend, cost_model, plan as plan_lib, tensor_ops, variants
from .api import (
    TuckerConfig,
    TuckerPlan,
    decompose,
    mesh_from_spec,
    mesh_spec,
    plan,
)
from .backend import (
    OpsBackend,
    backend_names,
    get_backend,
    register_backend,
    resolve_backend,
)
from .cost_model import DEFAULT_COST_MODEL, CostModel
from .errors import (CancelledError, DeadlineError, InputError,
                     NumericalError, ResourceError, TuckerError,
                     check_finite, classify_exception, coerce_exception)
from .plan import ModeStep, resolve_schedule
from .schedule_opt import (MemoryCapError, ScheduleSearch,
                           optimize_grouping, optimize_schedule)
from .selector import Selector, default_selector, extract_features
from .solvers import (ALS, EIG, RAND, SVD, als_solve, eig_solve, rand_sketch,
                      rand_solve, svd_solve)
from .sthosvd import (
    SthosvdResult,
    TuckerTensor,
    sthosvd,
    sthosvd_als,
    sthosvd_eig,
    sthosvd_svd,
)

__all__ = [
    "ALS", "DEFAULT_COST_MODEL", "EIG", "RAND", "SVD",
    "CancelledError", "CostModel", "DeadlineError", "InputError",
    "MemoryCapError", "ModeStep", "NumericalError", "OpsBackend",
    "ResourceError", "ScheduleSearch", "Selector", "SthosvdResult",
    "TuckerConfig", "TuckerError", "TuckerPlan", "TuckerTensor",
    "als_solve", "backend", "backend_names", "check_finite",
    "classify_exception", "coerce_exception", "cost_model", "decompose",
    "default_selector", "eig_solve", "extract_features", "get_backend",
    "mesh_from_spec", "mesh_spec", "optimize_grouping",
    "optimize_schedule", "plan", "plan_lib",
    "rand_sketch", "rand_solve",
    "register_backend", "resolve_backend", "resolve_schedule", "sthosvd",
    "sthosvd_als", "sthosvd_eig", "sthosvd_svd", "svd_solve", "tensor_ops",
    "variants",
]
