"""a-Tucker core: input-adaptive, matricization-free Tucker decomposition.

Public API:
  sthosvd / sthosvd_eig / sthosvd_als / sthosvd_svd — flexible st-HOSVD
  TuckerTensor — decomposition result (reconstruct, rel_error, ratio)
  Selector / default_selector / train_and_save — adaptive solver selector
  tensor_ops — matricization-free TTM/TTT/Gram (+ explicit baselines)
"""

from . import cost_model, tensor_ops, variants
from .selector import Selector, default_selector, extract_features
from .solvers import ALS, EIG, SVD, als_solve, eig_solve, svd_solve
from .sthosvd import (
    SthosvdResult,
    TuckerTensor,
    sthosvd,
    sthosvd_als,
    sthosvd_eig,
    sthosvd_svd,
)

__all__ = [
    "ALS", "EIG", "SVD",
    "Selector", "SthosvdResult", "TuckerTensor",
    "als_solve", "cost_model", "default_selector", "eig_solve",
    "extract_features", "sthosvd", "sthosvd_als", "sthosvd_eig",
    "sthosvd_svd", "svd_solve", "tensor_ops", "variants",
]
