"""Static solver schedules for the plan/execute Tucker front door.

The paper's flexible algorithms pick a solver per mode at runtime; here the
same selection happens ONCE, ahead of time, against the (statically known)
shapes each mode solve will see.  The result is a tuple of :class:`ModeStep`
records — mode, solver, the (I_n, R_n, J_n) triple the selector saw, plus
modeled FLOPs (cost_model Eq. 4/5) and peak working-set bytes — which is

  * the single dispatch point for all three variants (st-HOSVD shrinks the
    tensor between steps, t-HOSVD solves every mode on the original tensor,
    HOOI refines from an st-HOSVD init), replacing the per-variant copies of
    the selector/dispatch logic, and
  * fully static, so an entire sweep can be compiled as ONE jitted program
    and vmapped over a batch axis (see :mod:`repro.core.api`).

``run_schedule`` is the eager per-step runner used by the legacy entry
points (per-mode wall-clock in the trace); the ``sweep_*`` builders express
the same schedules as pure functions for whole-program jit.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Sequence

import jax

from .. import chaos as _chaos
from ..obs import drift as _drift
from ..obs import trace as _obs
from . import tensor_ops as T
from .backend import get_backend
from .cost_model import als_flops, eig_flops, rand_flops, svd_flops
from .errors import NumericalError
from .solvers import (ALS, DEFAULT_ALS_ITERS, DEFAULT_OVERSAMPLE,
                      DEFAULT_POWER_ITERS, RAND, SOLVERS)

VARIANTS = ("sthosvd", "thosvd", "hooi")


@dataclass(frozen=True)
class ModeStep:
    """One frozen mode solve: which solver runs on which (sub)problem,
    through which ops backend.

    For sharded schedules (``backend="sharded"``) two extra fields freeze
    the distribution decision: ``shard_mode`` is the tensor mode the input
    is sharded on while this step runs (``None`` = fully replicated — the
    shrunk tensor no longer divides over the mesh), and ``n_shards`` is the
    device count the step's slab is split across (1 when replicated).
    ``peak_bytes`` is then a PER-DEVICE figure: the sharded I/O slabs divide
    by ``n_shards`` while replicated solver scratch does not.

    ``group`` marks mode-parallel execution: consecutive steps sharing a
    non-None group id compute their factors concurrently from the SAME
    un-shrunk tensor (their ``j_n`` reflects the group-entry shape, not the
    sequential shrink) and truncate together in one fused multi-TTM.
    ``None`` (the back-compat default) is a sequential singleton.  Group
    members all record the GROUP's modeled peak (the shared input slab plus
    every member's concurrent solver scratch) as their ``peak_bytes``.

    The RANK POLICY fields make a step rank-*adaptive* (error-targeted
    plans, see :class:`repro.core.api.TuckerConfig` ``error_target``):
    ``rank_grid`` is the ascending tuple of candidate ranks the executed
    sketch may settle on (``r_n`` is then the sizing CAP — the largest
    candidate — so FLOPs/peak stay conservative), and ``tau`` is this
    mode's squared error budget as a fraction of ``||X||²`` (the HOSVD
    bound ``||X-X̂||² ≤ Σ_n τ_n²`` equi-partitioned: ``tau = ε²/N``).
    Fixed-rank steps keep the defaults (``None``/``0.0``) and serialize
    byte-identically to pre-rank-policy plans.
    """
    mode: int
    method: str          # "eig" | "als" | "svd" | "rand"
    i_n: int             # mode dimension at solve time
    r_n: int             # truncation rank
    j_n: int             # product of the remaining dims at solve time
    flops: float         # modeled solver cost (cost_model Eq. 4/5)
    peak_bytes: int      # modeled peak working set (per device if sharded)
    backend: str = "matfree"   # resolved ops backend (never "auto")
    shard_mode: int | None = None  # mode sharded over the mesh (None = replicated)
    n_shards: int = 1    # devices this step's tensor is split across
    predicted_s: float = 0.0   # predicted wall-clock (0.0 = no calibrated
                               # cost model was available at plan time)
    group: int | None = None   # mode-parallel group id (None = sequential)
    rank_grid: tuple[int, ...] | None = None  # adaptive candidate ranks
    tau: float = 0.0     # squared error budget / ||X||² (adaptive steps only)

    def to_dict(self) -> dict:
        d = {"mode": self.mode, "method": self.method, "i_n": self.i_n,
             "r_n": self.r_n, "j_n": self.j_n, "flops": self.flops,
             "peak_bytes": self.peak_bytes, "backend": self.backend,
             "shard_mode": self.shard_mode, "n_shards": self.n_shards,
             "predicted_s": self.predicted_s, "group": self.group}
        # the rank policy serializes only when present, so fixed-rank plan
        # JSON stays byte-identical to pre-rank-policy writers
        if self.rank_grid is not None:
            d["rank_grid"] = list(self.rank_grid)
            d["tau"] = self.tau
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ModeStep":
        shard_mode = d.get("shard_mode")
        group = d.get("group")
        rank_grid = d.get("rank_grid")
        return cls(mode=int(d["mode"]), method=str(d["method"]),
                   i_n=int(d["i_n"]), r_n=int(d["r_n"]), j_n=int(d["j_n"]),
                   flops=float(d["flops"]), peak_bytes=int(d["peak_bytes"]),
                   backend=str(d.get("backend", "matfree")),
                   shard_mode=None if shard_mode is None else int(shard_mode),
                   n_shards=int(d.get("n_shards", 1)),
                   predicted_s=float(d.get("predicted_s", 0.0)),
                   group=None if group is None else int(group),
                   rank_grid=None if rank_grid is None
                   else tuple(int(r) for r in rank_grid),
                   tau=float(d.get("tau", 0.0)))


class TimedSelector:
    """Wraps a selector callable, accumulating wall-clock spent selecting."""

    def __init__(self, selector: Callable[..., str]):
        self._selector = selector
        self.seconds = 0.0
        self.calls = 0

    def __call__(self, *, i_n: int, r_n: int, j_n: int) -> str:
        t0 = time.perf_counter()
        method = self._selector(i_n=i_n, r_n=r_n, j_n=j_n)
        self.seconds += time.perf_counter() - t0
        self.calls += 1
        return method

    @property
    def cost_model(self):
        """The wrapped selector's (possibly calibrated) cost model, if any."""
        return getattr(self._selector, "cost_model", None)


# ---------------------------------------------------------------------------
# Schedule resolution (selection moved out of the hot loop)
# ---------------------------------------------------------------------------

def resolve_mode_order(shape: Sequence[int], ranks: Sequence[int],
                       mode_order) -> list[int]:
    n = len(shape)
    if mode_order is None:
        return list(range(n))
    if mode_order == "opt":
        raise ValueError("mode_order='opt' is resolved by resolve_schedule "
                         "(the DP search needs solver costs and the memory "
                         "cap), not by resolve_mode_order")
    if mode_order == "shrink":
        return sorted(range(n), key=lambda m: ranks[m] / shape[m])
    order = [int(m) for m in mode_order]
    if sorted(order) != list(range(n)):
        raise ValueError(f"mode_order {order} must be a permutation of 0..{n - 1}")
    return order


def validate_ranks(shape: Sequence[int], ranks: Sequence[int]) -> tuple[int, ...]:
    ranks = tuple(int(r) for r in ranks)
    if len(ranks) != len(shape):
        raise ValueError(f"ranks {ranks} do not match tensor order {len(shape)}")
    for m, (i, r) in enumerate(zip(shape, ranks)):
        if not (1 <= r <= i):
            raise ValueError(f"rank {r} invalid for mode {m} (dim {i})")
    return ranks


def _resolve_methods(methods, n_modes: int):
    """Normalize ``methods`` to either None (= use selector) or a per-mode list."""
    if methods == "auto":
        return None
    if isinstance(methods, str):
        methods = [methods] * n_modes
    else:
        methods = list(methods)
        if len(methods) != n_modes:
            raise ValueError(f"need {n_modes} per-mode methods, got {len(methods)}")
    for m in methods:
        if m not in SOLVERS:
            raise ValueError(f"unknown solver {m!r}")
    return methods


def _step_cost(method: str, i_n: int, r_n: int, j_n: int,
               als_iters: int) -> float:
    if method == "eig":
        return eig_flops(i_n, r_n, j_n)
    if method == "als":
        return als_flops(i_n, r_n, j_n, als_iters)
    if method == "rand":
        return rand_flops(i_n, r_n, j_n)
    return svd_flops(i_n, r_n, j_n)


def _solver_scratch_bytes(method: str, i_n: int, r_n: int, j_n: int,
                          itemsize: int, n_shards: int = 1) -> int:
    """Modeled solver scratch only (no I/O tensors): EIG's I_n×I_n Gram,
    ALS's L/R iterates (+ fp32 input cast for sub-fp32 dtypes), SVD's
    explicit unfolding plus its left singular block.  Scratch lives in the
    *accumulation* dtype; sharded parts (ALS's R-tensor and cast, which
    stay with the input) divide by ``n_shards`` while replicated scratch
    (EIG's psum'd Gram, ALS's L factor and R^T R) does not."""
    accum = max(itemsize, 4)   # bf16/fp16 accumulate in fp32; fp64 stays 8
    if method == "eig":
        return i_n * i_n * accum               # replicated psum'd Gram
    if method == "als":
        scratch = (2 * i_n * r_n + 2 * r_n * r_n) * accum \
            + 2 * r_n * j_n * accum // n_shards   # R-tensor stays sharded
        if accum != itemsize:
            scratch += i_n * j_n * accum // n_shards  # yc: fp32 input cast
        return scratch
    if method == "rand":
        # Gaussian test tensor Ω (ℓ·J) + range sample / Q (I·ℓ) + the ℓ-wide
        # projected tensor b (ℓ·J) + the ℓ×ℓ sketched Gram; plus the fp32
        # input cast for sub-fp32 dtypes (like ALS).  Replicated by design
        # (the sketch runs before any reshard; see _make_step).
        ell = min(i_n, r_n + DEFAULT_OVERSAMPLE)
        scratch = (2 * ell * j_n + i_n * ell + ell * ell) * accum
        if accum != itemsize:
            scratch += i_n * j_n * accum
        return scratch
    # svd materializes the unfolding and U, replicated by design
    return (i_n * j_n + i_n * min(i_n, j_n)) * accum


def _step_peak_bytes(method: str, i_n: int, r_n: int, j_n: int,
                     itemsize: int, n_shards: int = 1) -> int:
    """Modeled peak working set: input + output tensors plus solver scratch
    (see :func:`_solver_scratch_bytes`).

    I/O tensors live in the compute dtype (``itemsize``); with
    ``n_shards > 1`` the figure is PER DEVICE: the I/O slabs divide by the
    shard count, replicated scratch does not — the paper's GPU OOM regime
    is exactly where this distinction decides whether a mode fits.
    """
    io = (i_n * j_n + r_n * j_n) * itemsize // n_shards
    return int(io + _solver_scratch_bytes(method, i_n, r_n, j_n, itemsize,
                                          n_shards))


def _group_peak_bytes(entries, in_elems: int, out_elems: int,
                      itemsize: int, n_shards: int = 1) -> int:
    """Modeled per-device peak of one mode-parallel group: the SHARED
    un-shrunk input slab (every member's Gram reads the same tensor, so it
    is charged once), the fused multi-TTM's fully-truncated output slab,
    plus every member's solver scratch CONCURRENTLY (the latency win of
    running G Grams at once is paid for in G live scratches — the memory
    coupling that lets a cap force a group to split).

    ``entries`` is a sequence of ``(method, i_n, r_n, j_n)`` at the group's
    entry shape.  For a singleton group this reduces exactly to
    :func:`_step_peak_bytes` (in = I_n·J_n, out = R_n·J_n, one scratch).
    """
    io = (in_elems + out_elems) * itemsize // n_shards
    scratch = sum(_solver_scratch_bytes(meth, i_n, r_n, j_n, itemsize,
                                        n_shards)
                  for meth, i_n, r_n, j_n in entries)
    return int(io + scratch)


def iter_groups(steps):
    """Partition a schedule into execution groups: consecutive steps sharing
    a non-None ``group`` id run as ONE mode-parallel group (all factors from
    the shared un-shrunk input, one fused multi-TTM truncation); ``None``
    steps are sequential singletons.  Yields lists of :class:`ModeStep`."""
    batch: list = []
    for s in steps:
        if batch and s.group is not None and s.group == batch[0].group:
            batch.append(s)
            continue
        if batch:
            yield batch
        batch = [s]
    if batch:
        yield batch


def _make_step(mode: int, method, selector, i_n: int, r_n: int, j_n: int,
               als_iters: int, itemsize: int, backend: str,
               n_shards: int = 1, shard_mode: int | None = None,
               cost_model=None, group: int | None = None,
               peak_override: int | None = None) -> ModeStep:
    m = selector(i_n=i_n, r_n=r_n, j_n=j_n) if method is None else method
    if m not in SOLVERS:
        raise ValueError(f"unknown solver {m!r}")
    if not get_backend(backend).supports_solver(m):
        raise ValueError(
            f"backend {backend!r} does not support solver {m!r} "
            f"(capability metadata lists {get_backend(backend).solvers}); "
            "pin a supported method or pick another impl")
    if m in ("svd", "rand"):
        # SVD matricizes; RAND's sketch/QR pipeline has no collective form
        # yet (distributed.solve_step_sharded handles eig/als only) — both
        # run replicated in sharded schedules
        shard_mode = None
    eff_shards = n_shards if shard_mode is not None else 1
    scale = get_backend(backend).cost_scale
    # a calibrated cost model (repro.tune.calibrate) predicts wall-clock per
    # step; its scales already absorb the backend it was fitted on, so the
    # registry cost_scale hint is NOT applied on top
    predicted_s = cost_model.predict_seconds(m, i_n, r_n, j_n, als_iters) \
        if cost_model is not None and cost_model.calibrated else 0.0
    peak = _step_peak_bytes(m, i_n, r_n, j_n, itemsize, eff_shards) \
        if peak_override is None else peak_override
    return ModeStep(mode=mode, method=m, i_n=i_n, r_n=r_n, j_n=j_n,
                    flops=scale * _step_cost(m, i_n, r_n, j_n, als_iters),
                    peak_bytes=peak,
                    backend=backend, shard_mode=shard_mode,
                    n_shards=eff_shards, predicted_s=predicted_s,
                    group=group)


def _make_group_steps(g, gid: int, cur, ranks, methods_g, selector,
                      als_iters: int, itemsize: int, backend: str,
                      n_shards: int, cost_model) -> list[ModeStep]:
    """Emit the ModeSteps of one mode-parallel group: every member is sized
    at the GROUP-ENTRY shape (``j_n`` keeps the other members un-shrunk —
    the FLOPs premium of parallel execution), one shard mode serves the
    whole group (chosen OUTSIDE it, so every member's Gram keeps the shard
    axis inside its contraction dims; ``None`` = replicated when the group
    covers every shardable mode), and the GROUP's modeled peak — shared
    input slab + all members' concurrent scratch — is stamped on each
    member."""
    j_base = math.prod(cur)
    if n_shards > 1:
        from .distributed import pick_shard_mode_group
        shard = pick_shard_mode_group(tuple(cur), g, n_shards)
    else:
        shard = None
    eff = n_shards if shard is not None else 1
    resolved = []
    for m, meth in zip(g, methods_g):
        i_n, r_n = cur[m], ranks[m]
        j_n = j_base // i_n
        meth = selector(i_n=i_n, r_n=r_n, j_n=j_n) if meth is None else meth
        if meth in ("svd", "rand"):
            raise ValueError(
                f"mode {m} resolved to {meth!r}, which runs replicated and "
                "cannot join a mode-parallel group; pin eig/als for grouped "
                f"modes (mode_parallel='auto' never groups {meth})")
        resolved.append((meth, i_n, r_n, j_n))
    out_elems = j_base
    for m in g:
        out_elems = out_elems // cur[m] * ranks[m]
    gpeak = _group_peak_bytes(resolved, j_base, out_elems, itemsize, eff)
    return [
        _make_step(m, meth, None, i_n, r_n, j_n, als_iters, itemsize,
                   backend, n_shards, shard, cost_model=cost_model,
                   group=gid, peak_override=gpeak)
        for m, (meth, i_n, r_n, j_n) in zip(g, resolved)]


def resolve_schedule(
    shape: Sequence[int],
    ranks: Sequence[int],
    *,
    variant: str = "sthosvd",
    methods="auto",
    mode_order=None,
    selector: Callable[..., str] | None = None,
    als_iters: int = DEFAULT_ALS_ITERS,
    hooi_iters: int = 3,
    include_init: bool = True,
    itemsize: int = 4,
    backend: str = "matfree",
    n_shards: int = 1,
    cost_model=None,
    memory_cap_bytes: int | None = None,
    mode_parallel: str | int = "off",
) -> tuple[ModeStep, ...]:
    """Resolve the full per-mode solver schedule ahead of execution.

    Every (I_n, R_n, J_n) triple a runtime selector would have seen is
    derived from ``shape``/``ranks`` alone, so selection runs zero times at
    execute time.  For HOOI, ``include_init=False`` drops the st-HOSVD init
    sweep (caller supplies its own initial factors).

    ``itemsize`` is the byte width of the *compute* dtype (callers derive it
    from ``TuckerConfig.compute_dtype`` or the input dtype — never assume 4)
    and ``backend`` the resolved ops-backend name stamped on every step.

    ``n_shards > 1`` resolves the DISTRIBUTION schedule too (sharded/mesh
    backend, st-HOSVD only): each step freezes the shard mode the tensor
    lives on while that mode is solved — the largest remaining mode (other
    than the one being solved) that divides by the shard count, via
    :func:`repro.core.distributed.pick_shard_mode` — so reshard points are
    known ahead of execution and ``peak_bytes`` become per-device figures.

    ``cost_model`` (a :class:`repro.core.cost_model.CostModel`) annotates
    each step with its predicted wall-clock (``ModeStep.predicted_s``) when
    CALIBRATED (``repro.tune.calibrate``); the textbook model carries no
    seconds unit, so uncalibrated schedules record 0.0.  When a selector is
    auto-resolved here, its embedded cost model is used.

    ``mode_order="opt"`` (st-HOSVD and the HOOI init sweep) runs the exact
    subset DP of :mod:`repro.core.schedule_opt`, jointly choosing mode order
    AND per-step solver (respecting pinned ``methods``) to minimize the cost
    model's predicted total — seconds when calibrated, Eq. 4/5 FLOPs
    otherwise — subject to ``memory_cap_bytes``.

    ``memory_cap_bytes`` is a hard per-device ceiling on every step's
    modeled ``peak_bytes``: fixed-order schedules that exceed it (and
    ``"opt"`` searches that cannot fit under it) raise
    :class:`repro.core.schedule_opt.MemoryCapError` at plan time, naming
    the binding step — the paper's OOM regime fails before the first byte
    is allocated, and a tight cap can force the slower-but-smaller solver.

    ``mode_parallel`` (sharded st-HOSVD only) opens mode-PARALLEL groups:
    group members compute their Grams/iterates concurrently from the same
    un-shrunk tensor and truncate together in one fused multi-TTM — lower
    latency (fewer collective barriers, priced as the max over members) at
    more FLOPs (members see un-shrunk ``j_n``).  ``"off"`` (default) keeps
    the sequential shrink; an int G groups the leading G modes of the
    resolved order; ``"auto"`` lets the DP price sequential-vs-parallel per
    input — jointly with order/solver when ``mode_order="opt"``, as a
    grouping search along the fixed order otherwise.  Group peaks charge
    the shared input slab plus every member's concurrent scratch, so a
    tight ``memory_cap_bytes`` can force a group to split.  ``"auto"``
    degrades to sequential when ``n_shards <= 1`` (no concurrent mesh
    resources); an explicit int G > 1 there is an error.
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; expected one of {VARIANTS}")
    get_backend(backend)   # concrete, registered backend only (never "auto")
    if n_shards > 1 and variant != "sthosvd":
        raise ValueError(f"sharded schedules support variant 'sthosvd' only, "
                         f"got {variant!r} (t-HOSVD/HOOI re-solve from the "
                         "full tensor; reshard scheduling assumes the "
                         "sequential shrink)")
    mp: str | int = mode_parallel
    if isinstance(mp, bool) or \
            not (mp in ("off", "auto") or isinstance(mp, int)):
        raise ValueError(f"mode_parallel {mode_parallel!r} must be 'off', "
                         "'auto', or an int max group size")
    if isinstance(mp, int):
        if mp < 1:
            raise ValueError(f"mode_parallel={mp} must be >= 1")
        if mp == 1:
            mp = "off"   # a group of one IS the sequential step
    if mp != "off":
        if variant != "sthosvd":
            raise ValueError("mode_parallel applies to the sequential "
                             "st-HOSVD sweep only; leave it 'off' for "
                             f"variant {variant!r}")
        if n_shards <= 1:
            if mp == "auto":
                mp = "off"   # single device: no concurrent mode resources,
                             # sequential shrinking always wins the latency race
            else:
                raise ValueError(
                    f"mode_parallel={mp} needs a sharded schedule "
                    "(n_shards > 1): single-device execution has no "
                    "concurrent mesh resources to assign mode Grams to")
    shape = tuple(int(s) for s in shape)
    ranks = validate_ranks(shape, ranks)
    n = len(shape)
    fixed = _resolve_methods(methods, n)
    if fixed is None and selector is None:
        from .selector import default_selector
        selector = default_selector(backend=backend)
    if cost_model is None:
        # a trained selector carries the calibration fitted from the same
        # records; TimedSelector exposes the wrapped selector's cost_model
        cost_model = getattr(selector, "cost_model", None)

    def method_for(mode):
        return None if fixed is None else fixed[mode]

    def _capped(steps_t: tuple[ModeStep, ...]) -> tuple[ModeStep, ...]:
        # hard plan-time cap: "opt" schedules were searched under it, but the
        # check runs uniformly so fixed orders (and HOOI refinements, which
        # the DP does not reorder) fail loudly too
        if memory_cap_bytes is not None:
            from .schedule_opt import validate_schedule_cap
            validate_schedule_cap(steps_t, memory_cap_bytes)
        return steps_t

    steps: list[ModeStep] = []
    if variant == "thosvd":
        if mode_order is not None:
            raise ValueError("mode_order is meaningless for thosvd (factors "
                             "are computed independently from the original "
                             "tensor); leave it None")
        size = math.prod(shape)
        for mode in range(n):
            i_n, r_n = shape[mode], ranks[mode]
            steps.append(_make_step(mode, method_for(mode), selector,
                                    i_n, r_n, size // i_n, als_iters,
                                    itemsize, backend,
                                    cost_model=cost_model))
        return _capped(tuple(steps))

    # st-HOSVD sweep (also HOOI's init): the tensor shrinks between steps
    # (or between GROUPS when mode_parallel opens one)
    if variant == "sthosvd" or include_init:
        if n_shards > 1:
            from .distributed import pick_shard_mode
        flat_methods: list | None
        if mp == "auto":
            # the planner prices sequential-vs-parallel per input: joint
            # subset DP when the order is searched too, grouping search
            # along the fixed order otherwise
            from .schedule_opt import optimize_grouping, optimize_schedule
            if mode_order == "opt":
                search = optimize_schedule(
                    shape, ranks, methods=fixed, als_iters=als_iters,
                    itemsize=itemsize, n_shards=n_shards,
                    cost_model=cost_model,
                    memory_cap_bytes=memory_cap_bytes, max_group=n)
            else:
                search = optimize_grouping(
                    shape, ranks,
                    tuple(resolve_mode_order(shape, ranks, mode_order)),
                    methods=fixed, als_iters=als_iters, itemsize=itemsize,
                    n_shards=n_shards, cost_model=cost_model,
                    memory_cap_bytes=memory_cap_bytes)
            groups = list(search.groups)
            flat_methods = list(search.methods)
        else:
            if mode_order == "opt":
                from .schedule_opt import optimize_schedule
                search = optimize_schedule(
                    shape, ranks, methods=fixed, als_iters=als_iters,
                    itemsize=itemsize, n_shards=n_shards,
                    cost_model=cost_model,
                    memory_cap_bytes=memory_cap_bytes)
                order, flat_methods = list(search.order), list(search.methods)
            else:
                order = resolve_mode_order(shape, ranks, mode_order)
                flat_methods = None
            if mp == "off":
                groups = [(m,) for m in order]
            else:   # int G >= 2: fixed strategy — leading group, rest sequential
                g_lead = min(int(mp), n)
                groups = [tuple(order[:g_lead])] + [(m,) for m in order[g_lead:]]
        cur = list(shape)
        pos = 0
        gid = 0
        for g in groups:
            if len(g) == 1:
                mode = g[0]
                i_n, r_n = cur[mode], ranks[mode]
                j_n = math.prod(cur) // i_n
                shard = pick_shard_mode(tuple(cur), mode, n_shards) \
                    if n_shards > 1 else None
                method = flat_methods[pos] if flat_methods is not None \
                    else method_for(mode)
                steps.append(_make_step(mode, method, selector,
                                        i_n, r_n, j_n, als_iters, itemsize,
                                        backend, n_shards, shard,
                                        cost_model=cost_model))
                cur[mode] = r_n
            else:
                meths_g = [flat_methods[pos + i] if flat_methods is not None
                           else method_for(m) for i, m in enumerate(g)]
                steps.extend(_make_group_steps(
                    g, gid, cur, ranks, meths_g, selector, als_iters,
                    itemsize, backend, n_shards, cost_model))
                for m in g:
                    cur[m] = ranks[m]
                gid += 1
            pos += len(g)
    if variant == "sthosvd":
        return _capped(tuple(steps))

    # HOOI refinement sweeps: mode n sees x projected on all OTHER factors,
    # i.e. shape (R_0 .. I_n .. R_{N-1}) — static, so resolvable up front.
    rank_prod = math.prod(ranks)
    for _ in range(hooi_iters):
        for mode in range(n):
            i_n, r_n = shape[mode], ranks[mode]
            j_n = rank_prod // r_n
            steps.append(_make_step(mode, method_for(mode), selector,
                                    i_n, r_n, j_n, als_iters, itemsize,
                                    backend, cost_model=cost_model))
    return _capped(tuple(steps))


# ---------------------------------------------------------------------------
# Single solver dispatch + runners
# ---------------------------------------------------------------------------

def solve_step(y: jax.Array, step: ModeStep, *, als_iters: int = DEFAULT_ALS_ITERS,
               oversample: int = DEFAULT_OVERSAMPLE,
               power_iters: int = DEFAULT_POWER_ITERS,
               impl: str | None = None):
    """THE solver dispatch point: every variant's mode solve funnels here.

    ``impl`` overrides the step's recorded ops backend; by default each step
    runs on the backend frozen into it at schedule-resolution time.
    ``oversample``/``power_iters`` only affect ``"rand"`` steps (sketch
    width ℓ = R_n + oversample and subspace-iteration count).
    """
    impl = step.backend if impl is None else impl
    if step.method == ALS:
        return SOLVERS[ALS](y, step.mode, step.r_n, num_iters=als_iters, impl=impl)
    if step.method == RAND:
        return SOLVERS[RAND](y, step.mode, step.r_n, oversample=oversample,
                             power_iters=power_iters, impl=impl)
    return SOLVERS[step.method](y, step.mode, step.r_n, impl=impl)


def run_schedule(x: jax.Array, steps: Sequence[ModeStep], *,
                 sequential: bool, als_iters: int = DEFAULT_ALS_ITERS,
                 oversample: int = DEFAULT_OVERSAMPLE,
                 power_iters: int = DEFAULT_POWER_ITERS,
                 impl: str | None = None, block_until_ready: bool = False):
    """Eager runner: per-mode jitted solves with wall-clock per step.

    ``sequential=True`` threads the shrinking tensor through the steps
    (st-HOSVD); ``sequential=False`` solves every step against ``x`` itself
    (t-HOSVD factors, HOOI inner solves on pre-projected tensors).

    Returns ``(y_or_none, factors, seconds)`` where ``factors[mode]`` is the
    LAST factor computed for that mode and ``seconds[k]`` is step k's wall
    time.
    """
    y = x
    factors: dict[int, jax.Array] = {}
    seconds: list[float] = []
    platform = jax.default_backend()
    for step in steps:
        wall0 = time.time()
        t0 = time.perf_counter()
        _chaos.fire("solve", mode=step.mode, method=step.method)
        res = solve_step(y if sequential else x, step,
                         als_iters=als_iters, oversample=oversample,
                         power_iters=power_iters, impl=impl)
        if _chaos.active() and _chaos.poison("solve_out", mode=step.mode):
            res = res._replace(u=res.u * float("nan"))
        if block_until_ready:
            jax.block_until_ready(res.y_new)
            dt = time.perf_counter() - t0
            # a breakdown that slipped past the in-solver guards (e.g. a
            # non-finite Gram) shows up here as NaN factors — surface it
            # as a classified error naming the step, not as silent poison
            if not bool(jax.numpy.all(jax.numpy.isfinite(res.u))):
                raise NumericalError(
                    f"{step.method} solve on mode {step.mode} produced a "
                    "non-finite factor (numerical breakdown)")
            # the eager per-step path is the only place a mode solve has
            # real wall-clock: span it retroactively (no enter/exit to
            # leak on solver errors) and feed predicted-vs-actual drift
            _obs.event("span", t=wall0, name="solve", dur_s=dt,
                       mode=step.mode, solver=step.method,
                       backend=impl or step.backend, platform=platform,
                       rank=step.r_n, i_n=step.i_n, j_n=step.j_n,
                       predicted_s=step.predicted_s)
            _drift.MONITOR.observe(platform=platform,
                                   backend=impl or step.backend,
                                   solver=step.method,
                                   predicted_s=step.predicted_s,
                                   actual_s=dt, source="execute")
        else:
            dt = time.perf_counter() - t0
        seconds.append(dt)
        factors[step.mode] = res.u
        if sequential:
            y = res.y_new
    return (y if sequential else None), factors, seconds


# ---------------------------------------------------------------------------
# Whole-sweep pure functions (compiled as ONE program by api.TuckerPlan)
# ---------------------------------------------------------------------------

def sweep_sthosvd(x, steps: Sequence[ModeStep], *, als_iters: int,
                  oversample: int = DEFAULT_OVERSAMPLE,
                  power_iters: int = DEFAULT_POWER_ITERS,
                  impl: str | None = None):
    y = x
    factors: dict[int, jax.Array] = {}
    for step in steps:
        res = solve_step(y, step, als_iters=als_iters, oversample=oversample,
                         power_iters=power_iters, impl=impl)
        factors[step.mode] = res.u
        y = res.y_new
    return y, [factors[m] for m in range(x.ndim)]


def sweep_thosvd(x, steps: Sequence[ModeStep], *, als_iters: int,
                 oversample: int = DEFAULT_OVERSAMPLE,
                 power_iters: int = DEFAULT_POWER_ITERS,
                 impl: str | None = None):
    factors = [solve_step(x, step, als_iters=als_iters, oversample=oversample,
                          power_iters=power_iters, impl=impl).u
               for step in steps]
    core = x
    for mode, u in enumerate(factors):
        core = T.ttm(core, u.T, mode)
    return core, factors


def sweep_hooi(x, steps: Sequence[ModeStep], *, als_iters: int, n_init: int,
               oversample: int = DEFAULT_OVERSAMPLE,
               power_iters: int = DEFAULT_POWER_ITERS,
               impl: str | None = None):
    """HOOI with its st-HOSVD init inlined: ``steps[:n_init]`` is the init
    sweep (sequential shrink), the rest are refinement solves on x projected
    over every factor but the step's mode."""
    _, factors = sweep_sthosvd(x, steps[:n_init], als_iters=als_iters,
                               oversample=oversample, power_iters=power_iters,
                               impl=impl)
    for step in steps[n_init:]:
        y = x
        for m, u in enumerate(factors):
            if m != step.mode:
                y = T.ttm(y, u.T, m)
        factors[step.mode] = solve_step(y, step, als_iters=als_iters,
                                        oversample=oversample,
                                        power_iters=power_iters,
                                        impl=impl).u
    core = x
    for mode, u in enumerate(factors):
        core = T.ttm(core, u.T, mode)
    return core, factors
