"""Pluggable ops backends: who actually computes TTM / Gram / TTT.

The paper separates *what* to solve per mode (the adaptive EIG/ALS/SVD
schedule, Sec. III–IV) from *how* the three tensor primitives run on the
hardware (the matricization-free CPU/GPU kernels, Sec. V).  This module is
that seam for the JAX port: an :class:`OpsBackend` bundles the three
primitives with capability metadata, and a process-wide registry maps names
to backends so every layer — solvers, schedules, plans, the serving engine,
benchmarks — routes through one dispatch point instead of pattern-matching
an ``impl`` string.

Built-in backends:

  ``matfree``   jnp contractions on the (A, I_n, B) view — no unfold copy
                (tensor_ops; the paper's Fig. 4 structure via XLA).
  ``explicit``  unfold → GEMM → fold baseline (paper Fig. 3 / Fig. 8).
  ``pallas``    hand-written Pallas TPU kernels (kernels/ops.py): tiled
                matmul / batched-TTM / TTT with zero-padding shims for
                non-tile-multiple shapes; interpret-mode fallback off-TPU
                so the same code path runs (slowly) everywhere.
  ``sharded``   multi-device st-HOSVD over a jax mesh (core/distributed.py):
                TuckerMPI-style partial-Gram + psum and local TTM under
                shard_map, resharding to the largest remaining mode between
                steps.  Requires a mesh (``TuckerConfig(mesh=...)``); the
                local per-device primitives are ``matfree``'s, so this
                backend never matricizes either.

``resolve_backend("auto", ...)`` picks the best available backend for the
current platform at *plan* time (a mesh → ``sharded``, TPU → ``pallas``,
otherwise ``matfree``), honouring each backend's dtype/platform
capabilities.  Custom backends register via :func:`register_backend` and
are immediately usable as ``impl=`` values.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import tensor_ops as T

#: Ops signature: ttm(x, u, mode) with u (R, I_n); gram(x, mode) → (I_n, I_n);
#: ttt(x, y, mode) → (I_n, R_n).  All dispatch positionally so backends with
#: extra keyword knobs (precision, interpret, …) plug in unchanged.
OpsTriple = tuple[Callable, Callable, Callable]


@dataclass(frozen=True)
class OpsBackend:
    """One named implementation of the three mode-n primitives.

    ``loader`` defers the import of heavyweight kernel modules until the
    backend is first used; the resolved triple is cached on the instance.

    Capability metadata drives ``auto`` resolution and plan-time validation:

    dtypes
        dtype names the primitives accept (``"*"`` = anything jnp takes).
    platforms
        jax backend names this runs *natively* on (``"*"`` = any).  A
        backend with ``interpret_fallback=True`` additionally runs anywhere
        through the Pallas interpreter — correct but slow, for testing.
    matricizes
        True if the primitives materialize mode-n unfoldings (extra
        O(I_n·J_n) buffer; the paper's Fig. 8 memory axis).  Note the SVD
        *solver* unfolds regardless of backend — see
        :func:`repro.core.solvers.svd_solve`.
    tile_align
        Hardware tile multiple the backend pads to internally (informs the
        plan-aware-memory model; None = no padding).
    cost_scale
        Relative per-FLOP cost hint vs ``matfree`` on this backend's native
        platform; the selector/cost model may scale Eq. 4/5 estimates by it.
    requires_mesh
        True if the backend executes across a jax mesh: plans must carry one
        (``TuckerConfig(mesh=...)``), ``auto`` only selects it when a mesh is
        supplied, and per-step ``peak_bytes`` become per-device figures.
    solvers
        Solver families (``repro.core.solvers.SOLVERS`` names) whose kernel
        mix this backend supports.  All four built-ins support the full set
        — ``rand`` is built from the same TTM/TTT/Gram primitives — but a
        custom backend that e.g. lacks a TTT can exclude ``als``/``rand``
        here and plan-time validation (``plan._make_step``) rejects the
        combination before anything compiles.
    """
    name: str
    loader: Callable[[], OpsTriple]
    dtypes: tuple[str, ...] = ("*",)
    platforms: tuple[str, ...] = ("*",)
    matricizes: bool = False
    tile_align: int | None = None
    cost_scale: float = 1.0
    interpret_fallback: bool = False
    requires_mesh: bool = False
    solvers: tuple[str, ...] = ("eig", "als", "svd", "rand")
    _ops: list = field(default_factory=list, repr=False, compare=False)

    def ops(self) -> OpsTriple:
        """Resolve (ttm, gram, ttt), importing lazily on first use."""
        if not self._ops:
            self._ops.append(self.loader())
        return self._ops[0]

    def supports_dtype(self, dtype) -> bool:
        return "*" in self.dtypes or str(jnp.dtype(dtype)) in self.dtypes

    def supports_solver(self, method: str) -> bool:
        return "*" in self.solvers or method in self.solvers

    def native_on(self, platform: str) -> bool:
        return "*" in self.platforms or platform in self.platforms


_REGISTRY: dict[str, OpsBackend] = {}


def register_backend(backend: OpsBackend, *, overwrite: bool = False) -> OpsBackend:
    """Add ``backend`` to the registry (its name becomes a valid ``impl=``)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered "
                         "(pass overwrite=True to replace)")
    if backend.name == "auto":
        raise ValueError("'auto' is reserved for plan-time resolution")
    _REGISTRY[backend.name] = backend
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def backend_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_backend(name: str) -> OpsBackend:
    """Look up a backend by name; raises ValueError listing known names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{backend_names()} (or 'auto')") from None


#: ``auto`` preference order per platform: first registered name that is
#: native on the platform and supports the dtype wins.
AUTO_ORDER: dict[str, tuple[str, ...]] = {
    "tpu": ("pallas", "matfree"),
    "gpu": ("matfree",),
    "cpu": ("matfree",),
}


def resolve_backend(impl: str, *, platform: str | None = None,
                    dtype=None, mesh=None) -> OpsBackend:
    """Resolve an ``impl`` name (or ``"auto"``) to a concrete backend.

    Explicit names are honoured even off their native platform when the
    backend has an interpreter/emulation path (``pallas`` off-TPU runs in
    Pallas interpret mode) — asking for a backend by name means you want
    *that* code path.  ``"auto"`` only ever picks natively-supported
    backends, falling back to ``matfree``; when ``mesh`` (a
    ``jax.sharding.Mesh``) is supplied, ``auto`` routes to the ``sharded``
    mesh backend so plans built with a mesh execute distributed by default.
    """
    platform = platform or jax.default_backend()
    if impl != "auto":
        b = get_backend(impl)
        if dtype is not None and not b.supports_dtype(dtype):
            raise ValueError(f"backend {b.name!r} does not support dtype "
                             f"{jnp.dtype(dtype)} (supported: {b.dtypes})")
        if b.requires_mesh and mesh is None:
            raise ValueError(f"backend {b.name!r} requires a mesh; pass "
                             "TuckerConfig(mesh=...) or call "
                             "sthosvd_distributed directly")
        if not b.native_on(platform) and not b.interpret_fallback:
            raise ValueError(f"backend {b.name!r} runs on {b.platforms}, not "
                             f"{platform!r}, and has no interpreter fallback")
        return b
    if mesh is not None and "sharded" in _REGISTRY:
        b = _REGISTRY["sharded"]
        if dtype is None or b.supports_dtype(dtype):
            return b
    for name in AUTO_ORDER.get(platform, ("matfree",)):
        b = _REGISTRY.get(name)
        if b is not None and b.native_on(platform) and \
                (dtype is None or b.supports_dtype(dtype)):
            return b
    return get_backend("matfree")


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

def _load_matfree() -> OpsTriple:
    return T.ttm, T.gram, T.ttt


def _load_explicit() -> OpsTriple:
    return T.ttm_explicit, T.gram_explicit, T.ttt_explicit


def _load_pallas() -> OpsTriple:
    """kernels/ops.py with dtype adapters matching matfree's contract.

    The Pallas kernels accumulate and return fp32; matfree keeps the input
    dtype for TTM and promotes to (at least) fp32 for Gram/TTT.  The
    adapters restore that contract so sweeps thread dtypes identically
    across backends (a bf16 plan shrinks a bf16 tensor either way).
    """
    from ..kernels import ops as K

    def ttm(x, u, mode):
        return K.ttm(x, u, mode).astype(x.dtype)

    def gram(x, mode):
        return K.gram(x, mode).astype(jnp.promote_types(x.dtype, jnp.float32))

    def ttt(x, y, mode):
        return K.ttt(x, y, mode).astype(jnp.promote_types(x.dtype, jnp.float32))

    return ttm, gram, ttt


register_backend(OpsBackend(
    name="matfree", loader=_load_matfree,
    dtypes=("*",), platforms=("*",), matricizes=False, cost_scale=1.0))

register_backend(OpsBackend(
    name="explicit", loader=_load_explicit,
    dtypes=("*",), platforms=("*",), matricizes=True,
    # the unfold copy is pure overhead; Fig. 8's explicit rows pay it
    cost_scale=1.3))

register_backend(OpsBackend(
    name="pallas", loader=_load_pallas,
    # fp64 has no Mosaic tile mapping; fp32/bf16 are what the kernels tile
    dtypes=("float32", "bfloat16"), platforms=("tpu",),
    matricizes=False, tile_align=128,
    # hand-tiled MXU kernels: modestly better than XLA's generic batched GEMM
    cost_scale=0.9,
    # kernels/ops.py defaults interpret=True off-TPU, so explicit
    # `impl="pallas"` works — slowly — on any platform
    interpret_fallback=True))

register_backend(OpsBackend(
    # the shard_map schedule runs matfree's primitives per device; mesh
    # plumbing (partial-Gram psum, local TTM, resharding) lives in
    # core/distributed.py and is wired in by the plan layer
    name="sharded", loader=_load_matfree,
    dtypes=("*",), platforms=("*",), matricizes=False,
    requires_mesh=True, cost_scale=1.0))


def backend_ops(impl: str) -> OpsTriple:
    """(ttm, gram, ttt) for a registered backend name — the solver hot path."""
    return get_backend(impl).ops()
