"""Adaptive solver selector (a-Tucker Sec. IV).

Features (paper Table I), label = argmin(measured time of EIG vs ALS) on the
current platform.  A trained :class:`repro.core.dtree.DecisionTree` is stored
as JSON per ``(platform, backend)`` — ``matfree`` vs ``explicit`` vs
``pallas`` shift the EIG/ALS crossover, so the hardware axis the paper's
selector absorbs includes the ops backend, not just the chip.  Resolution
falls back gracefully: exact ``(platform, backend)`` model → platform-only
model → analytic Eq.4/5 cost model (hardware-calibrated when
:mod:`repro.tune.calibrate` has run, textbook constants otherwise), so the
flexible algorithm never blocks on training data.

Training lives in :mod:`repro.tune` (measurement store + stratified
training + calibration — the autotune flywheel); the ``collect_samples`` /
``train_selector`` / ``train_and_save`` names below are kept as thin
wrappers over it for existing call sites.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from .cost_model import DEFAULT_COST_MODEL, CostModel
from .dtree import DecisionTree

FEATURE_NAMES = (
    "I_n", "R_n", "J_n",
    "I_n*I_n", "R_n*R_n", "I_n*R_n",
    "R_n*R_n/I_n", "R_n*R_n/J_n", "I_n/J_n", "R_n/J_n",
)

_DEFAULT_MODEL_DIR = Path(os.environ.get(
    "ATUCKER_MODEL_DIR", Path(__file__).resolve().parent / "models"))

LABELS = ("eig", "als")   # class 0 = eig, class 1 = als

SELECTOR_FORMAT_VERSION = 2


def extract_features(i_n: int, r_n: int, j_n: int) -> np.ndarray:
    """Paper Table I: 3 raw shape features + 7 derived."""
    i_n, r_n, j_n = float(i_n), float(r_n), float(j_n)
    return np.array([
        i_n, r_n, j_n,
        i_n * i_n, r_n * r_n, i_n * r_n,
        r_n * r_n / i_n, r_n * r_n / j_n, i_n / j_n, r_n / j_n,
    ])


@dataclass
class Selector:
    """Callable solver selector: (i_n, r_n, j_n) → 'eig' | 'als'.

    Guardrail: decision trees extrapolate badly; queries outside the trained
    feature range (× margin) defer to the analytic Eq.4/5 cost model — the
    paper's huge-mode regime (Air: I_n = 30648) must never be mispredicted
    by a tree that was trained on smaller dims.  ``cost_model`` is that
    fallback's constants: textbook by default, hardware-fitted when the
    model file embeds a calibration (:mod:`repro.tune.calibrate`).

    ``backend`` records which ops backend the training measurements ran
    through (None = pooled across backends / unknown); ``meta`` carries the
    training provenance written by :mod:`repro.tune.train` (sample counts,
    CV/test accuracy, store digest, trained dim range).
    """
    tree: DecisionTree | None = None
    platform: str = "unknown"
    backend: str | None = None
    trained_range: tuple | None = None   # ((min_i, min_r, min_j), (max_i, max_r, max_j))
    range_margin: float = 2.0
    cost_model: CostModel = field(default_factory=lambda: DEFAULT_COST_MODEL)
    meta: dict = field(default_factory=dict)

    def __call__(self, *, i_n: int, r_n: int, j_n: int,
                 candidates: tuple[str, ...] | None = None) -> str:
        """Solver for one mode solve.  ``candidates=None`` is the legacy
        EIG-vs-ALS decision (what the trained tree answers directly).  A
        wider tuple — e.g. ``("eig", "als", "rand")`` — keeps the tree's
        eig/als call but lets the calibrated cost model overrule it with
        any extra candidate it prices cheaper (backend capability gating
        is the planner's job; candidates passed here are assumed runnable).
        """
        if self.tree is None or self._out_of_range(i_n, r_n, j_n):
            return self.cost_model.predicted_best(
                i_n, r_n, j_n, methods=candidates or ("eig", "als"))
        pick = LABELS[self.tree.predict_one(
            extract_features(i_n, r_n, j_n))]
        extras = tuple(c for c in candidates or () if c not in LABELS)
        if not extras:
            return pick
        # tree's winner first: ties and un-priceable cases keep the tree
        return self.cost_model.predicted_best(
            i_n, r_n, j_n, methods=(pick,) + extras)

    def _out_of_range(self, i_n, r_n, j_n) -> bool:
        if self.trained_range is None:
            return False
        lo, hi = self.trained_range
        m = self.range_margin
        for v, l, h in zip((i_n, r_n, j_n), lo, hi):
            if v < l / m or v > h * m:
                return True
        return False

    # -- persistence ---------------------------------------------------------
    def save(self, path: str | Path) -> None:
        if self.tree is None:
            raise ValueError(
                "cannot save a selector with no trained tree (the cost-model "
                "fallback needs no file); train one first, e.g. "
                "`python -m repro.tune train`")
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"version": SELECTOR_FORMAT_VERSION,
             "platform": self.platform, "backend": self.backend,
             "tree": self.tree.to_dict(),
             "trained_range": self.trained_range,
             "cost_model": self.cost_model.to_dict(),
             "meta": self.meta}, indent=1))

    @classmethod
    def load(cls, path: str | Path) -> "Selector":
        d = json.loads(Path(path).read_text())
        rng = d.get("trained_range")
        if rng is not None:
            rng = (tuple(rng[0]), tuple(rng[1]))
        cm = d.get("cost_model")
        return cls(tree=DecisionTree.from_dict(d["tree"]),
                   platform=d["platform"], backend=d.get("backend"),
                   trained_range=rng,
                   cost_model=(CostModel.from_dict(cm) if cm
                               else DEFAULT_COST_MODEL),
                   meta=d.get("meta", {}))


def model_dir() -> Path:
    """The selector/calibration model directory (``ATUCKER_MODEL_DIR`` env
    override, default ``repro/core/models`` — where the shipped CPU model
    lives)."""
    return _DEFAULT_MODEL_DIR


def model_path(platform: str | None = None,
               backend: str | None = None) -> Path:
    import jax
    platform = platform or jax.default_backend()
    stem = f"selector_{platform}" + (f"_{backend}" if backend else "")
    return _DEFAULT_MODEL_DIR / f"{stem}.json"


def calibration_path(platform: str, backend: str) -> Path:
    """Standalone calibrated-cost-model file (written by
    ``python -m repro.tune calibrate``); also embedded into selector files
    at train time."""
    return _DEFAULT_MODEL_DIR / f"cost_{platform}_{backend}.json"


def load_calibration(platform: str, backend: str | None) -> CostModel | None:
    """The fitted CostModel for (platform, backend) if one is on disk."""
    if backend is None:
        return None
    p = calibration_path(platform, backend)
    if not p.exists():
        return None
    return CostModel.from_dict(json.loads(p.read_text()))


_DEFAULT_BY_PLATFORM: dict[tuple[str, str | None], Selector] = {}


def default_selector(platform: str | None = None,
                     backend: str | None = None) -> Selector:
    """Trained tree for ``(platform, backend)`` if present, else the
    platform-pooled tree, else cost-model fallback (hardware-calibrated when
    a calibration file exists for the pair).  Cached per (platform, backend),
    so CPU and GPU model files — and per-backend refinements — resolve
    correctly side by side in one process.
    """
    import jax
    platform = platform or jax.default_backend()
    key = (platform, backend)
    sel = _DEFAULT_BY_PLATFORM.get(key)
    if sel is None:
        for p in ([model_path(platform, backend)] if backend else []) + \
                [model_path(platform)]:
            if p.exists():
                sel = Selector.load(p)
                break
        if sel is None:
            sel = Selector(platform=platform, backend=backend,
                           cost_model=load_calibration(platform, backend)
                           or DEFAULT_COST_MODEL)
        _DEFAULT_BY_PLATFORM[key] = sel
    return sel


def clear_selector_cache() -> None:
    """Drop cached default selectors (tests / after retraining in-process)."""
    _DEFAULT_BY_PLATFORM.clear()


# ---------------------------------------------------------------------------
# Training pipeline — thin wrappers over repro.tune (the autotune subsystem)
# ---------------------------------------------------------------------------

def collect_samples(*args, **kw):
    """Legacy shim → :func:`repro.tune.collect.collect_samples` (same
    signature/return: ``(features, labels, times)`` arrays)."""
    from ..tune.collect import collect_samples as _collect
    return _collect(*args, **kw)


def train_selector(*args, **kw):
    """Legacy shim → :func:`repro.tune.train.train_selector`."""
    from ..tune.train import train_selector as _train
    return _train(*args, **kw)


def train_and_save(platform: str | None = None, **collect_kw) -> dict:
    """Legacy shim → :func:`repro.tune.train.train_and_save`.  The trained
    selector is labeled with, saved under, and cached for ONE platform
    string: ``platform`` if given, else the current JAX backend."""
    from ..tune.train import train_and_save as _tas
    return _tas(platform=platform, **collect_kw)


if __name__ == "__main__":  # pragma: no cover
    import sys
    print("the selector training CLI moved to the autotune subsystem:\n"
          "  python -m repro.tune collect && python -m repro.tune train\n"
          "(see README §Autotuning)", file=sys.stderr)
    sys.exit(2)
