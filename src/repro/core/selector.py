"""Adaptive solver selector (a-Tucker Sec. IV).

Features (paper Table I), label = argmin(measured time of EIG vs ALS) on the
current platform.  A trained :class:`repro.core.dtree.DecisionTree` is stored
as JSON per platform; when absent, the analytic Eq.4/5 cost model is the
fallback so the flexible algorithm never blocks on training data.

The training harness (:func:`collect_samples` + :func:`train_selector`)
mirrors the paper's pipeline: random third-order tensors, dims in a
configurable range (paper: [10, 10000]; scaled down by default for this
1-core box — see DESIGN.md §8), truncation in [max(1, 10), 0.5·I_n],
70/30 train/test split, grid-search CV over max_depth and class weights.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .cost_model import predicted_best
from .dtree import DecisionTree, grid_search_cv

FEATURE_NAMES = (
    "I_n", "R_n", "J_n",
    "I_n*I_n", "R_n*R_n", "I_n*R_n",
    "R_n*R_n/I_n", "R_n*R_n/J_n", "I_n/J_n", "R_n/J_n",
)

_DEFAULT_MODEL_DIR = Path(os.environ.get(
    "ATUCKER_MODEL_DIR", Path(__file__).resolve().parent / "models"))

LABELS = ("eig", "als")   # class 0 = eig, class 1 = als


def extract_features(i_n: int, r_n: int, j_n: int) -> np.ndarray:
    """Paper Table I: 3 raw shape features + 7 derived."""
    i_n, r_n, j_n = float(i_n), float(r_n), float(j_n)
    return np.array([
        i_n, r_n, j_n,
        i_n * i_n, r_n * r_n, i_n * r_n,
        r_n * r_n / i_n, r_n * r_n / j_n, i_n / j_n, r_n / j_n,
    ])


@dataclass
class Selector:
    """Callable solver selector: (i_n, r_n, j_n) → 'eig' | 'als'.

    Guardrail: decision trees extrapolate badly; queries outside the trained
    feature range (× margin) defer to the analytic Eq.4/5 cost model — the
    paper's huge-mode regime (Air: I_n = 30648) must never be mispredicted
    by a tree that was trained on smaller dims.
    """
    tree: DecisionTree | None = None
    platform: str = "unknown"
    trained_range: tuple | None = None   # ((min_i, min_r, min_j), (max_i, max_r, max_j))
    range_margin: float = 2.0

    def __call__(self, *, i_n: int, r_n: int, j_n: int) -> str:
        if self.tree is None or self._out_of_range(i_n, r_n, j_n):
            return predicted_best(i_n, r_n, j_n)
        return LABELS[self.tree.predict_one(extract_features(i_n, r_n, j_n))]

    def _out_of_range(self, i_n, r_n, j_n) -> bool:
        if self.trained_range is None:
            return False
        lo, hi = self.trained_range
        m = self.range_margin
        for v, l, h in zip((i_n, r_n, j_n), lo, hi):
            if v < l / m or v > h * m:
                return True
        return False

    # -- persistence ---------------------------------------------------------
    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"platform": self.platform, "tree": self.tree.to_dict(),
             "trained_range": self.trained_range}))

    @classmethod
    def load(cls, path: str | Path) -> "Selector":
        d = json.loads(Path(path).read_text())
        rng = d.get("trained_range")
        if rng is not None:
            rng = (tuple(rng[0]), tuple(rng[1]))
        return cls(tree=DecisionTree.from_dict(d["tree"]),
                   platform=d["platform"], trained_range=rng)


def model_path(platform: str | None = None) -> Path:
    import jax
    platform = platform or jax.default_backend()
    return _DEFAULT_MODEL_DIR / f"selector_{platform}.json"


_DEFAULT_BY_PLATFORM: dict[str, Selector] = {}


def default_selector(platform: str | None = None) -> Selector:
    """Trained tree for ``platform`` (default: current JAX backend) if present,
    else cost-model fallback.  Cached per platform, so CPU and GPU model files
    resolve correctly side by side in one process."""
    import jax
    platform = platform or jax.default_backend()
    sel = _DEFAULT_BY_PLATFORM.get(platform)
    if sel is None:
        p = model_path(platform)
        sel = Selector.load(p) if p.exists() else Selector(platform=platform)
        _DEFAULT_BY_PLATFORM[platform] = sel
    return sel


# ---------------------------------------------------------------------------
# Training pipeline (paper Sec. IV-B)
# ---------------------------------------------------------------------------

def _time_solver(y, mode, rank, method: str, reps: int = 2) -> float:
    import jax
    from .solvers import SOLVERS
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(SOLVERS[method](y, mode, rank))
        best = min(best, time.perf_counter() - t0)
    return best


def collect_samples(
    n_tensors: int = 120,
    dim_range: tuple[int, int] = (10, 192),
    seed: int = 0,
    order: int = 3,
    dtype=np.float32,
    verbose: bool = False,
):
    """Time EIG vs ALS per mode on random tensors → (features, labels, times).

    One record per (tensor, mode), as in the paper ("the statistics of each
    mode constitute a record").  Warm-up compile is excluded by timing the
    best of ``reps`` runs after a throwaway call.
    """
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)

    def log_uniform(lo, hi):
        return int(round(np.exp(rng.uniform(np.log(lo), np.log(hi)))))

    feats, labels, times = [], [], []
    for t in range(n_tensors):
        # log-uniform dims/ranks: covers the asymmetric shapes (one huge mode,
        # tiny others — the paper's Air-tensor regime) where the EIG/ALS
        # crossover lives, even at scaled-down absolute sizes.
        dims = tuple(log_uniform(dim_range[0], dim_range[1]) for _ in range(order))
        ranks = tuple(log_uniform(max(1, min(4, d // 2)), max(2, d // 2))
                      for d in dims)
        x = jnp.asarray(rng.standard_normal(dims), dtype=dtype)
        for mode in range(order):
            i_n, r_n = dims[mode], ranks[mode]
            j_n = int(np.prod(dims)) // i_n
            # throwaway to exclude compile time, then measure
            _time_solver(x, mode, r_n, "eig", reps=1)
            _time_solver(x, mode, r_n, "als", reps=1)
            te = _time_solver(x, mode, r_n, "eig")
            ta = _time_solver(x, mode, r_n, "als")
            feats.append(extract_features(i_n, r_n, j_n))
            labels.append(0 if te <= ta else 1)
            times.append((te, ta))
        if verbose and (t + 1) % 10 == 0:
            print(f"[selector] {t + 1}/{n_tensors} tensors sampled")
    return np.array(feats), np.array(labels), np.array(times)


def train_selector(
    feats: np.ndarray,
    labels: np.ndarray,
    test_split: float = 0.3,
    seed: int = 0,
) -> tuple[Selector, dict]:
    """70/30 split + grid-search CV (paper defaults)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(labels))
    n_test = int(len(labels) * test_split)
    test, train = perm[:n_test], perm[n_test:]
    tree, info = grid_search_cv(feats[train], labels[train])
    info["test_accuracy"] = tree.score(feats[test], labels[test])
    info["n_train"], info["n_test"] = len(train), len(test)
    import jax
    rng3 = (tuple(float(v) for v in feats[:, :3].min(0)),
            tuple(float(v) for v in feats[:, :3].max(0)))
    sel = Selector(tree=tree, platform=jax.default_backend(),
                   trained_range=rng3)
    return sel, info


def train_and_save(platform: str | None = None, **collect_kw) -> dict:
    import jax
    feats, labels, _ = collect_samples(**collect_kw)
    sel, info = train_selector(feats, labels)
    sel.save(model_path(platform))
    _DEFAULT_BY_PLATFORM[platform or jax.default_backend()] = sel
    return info


if __name__ == "__main__":  # pragma: no cover
    import argparse
    ap = argparse.ArgumentParser(description="Train the a-Tucker solver selector")
    ap.add_argument("--n-tensors", type=int, default=120)
    ap.add_argument("--max-dim", type=int, default=192)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    info = train_and_save(n_tensors=args.n_tensors,
                          dim_range=(10, args.max_dim), verbose=args.verbose)
    print(json.dumps(info, indent=2))
