"""Failure taxonomy for the a-Tucker stack: every failure classified.

The execution layers (``plan.execute``, the serve waves, the eager
runners) raise — or wrap foreign exceptions into — one hierarchy rooted at
:class:`TuckerError`, so callers can catch by failure CLASS instead of
pattern-matching XLA message strings:

  * :class:`InputError`       — the caller's tensor/config is bad (NaN/Inf
    inputs, shape/dtype mismatch).  Subclasses ``ValueError``.
  * :class:`NumericalError`   — the computation broke down (Cholesky
    failure in ALS, non-finite solver outputs).  Subclasses
    ``FloatingPointError``.
  * :class:`ResourceError`    — the runtime ran out of something (XLA
    ``RESOURCE_EXHAUSTED`` / OOM, a dead or abandoned worker).
  * :class:`DeadlineError`    — a serve request missed its ``deadline_s``
    before dispatch.  Subclasses ``TimeoutError``.
  * :class:`CancelledError`   — the caller retracted the request via
    ``TuckerService.cancel``.

:func:`classify_exception` maps raw JAX/XLA exceptions onto the taxonomy
(`None` when it cannot — programming errors stay themselves), and
:func:`coerce_exception` always returns a ``TuckerError`` (wrapping
unclassifiable failures in the base class) — the serve layer's guarantee
that no unclassified exception escapes to a caller.  The subclassing of
the matching builtins keeps every pre-taxonomy ``except ValueError`` /
``except TimeoutError`` call site working unchanged.

The execute-time fallback ladder (see ``TuckerPlan.execute``) keys its
hops off these classes: rand→eig on a sketch error-target miss, als→eig
on :class:`NumericalError`, pallas→matfree on a kernel failure,
donated→undonated→replanned-under-a-tighter-cap on
:class:`ResourceError`.
"""

from __future__ import annotations

__all__ = [
    "CancelledError", "DeadlineError", "InputError", "NumericalError",
    "ResourceError", "TuckerError", "check_finite", "check_result_finite",
    "classify_exception", "coerce_exception",
]


class TuckerError(RuntimeError):
    """Base of the classified-failure hierarchy (see module docstring)."""


class InputError(TuckerError, ValueError):
    """The caller's input is unusable: non-finite entries, or a tensor that
    does not match the plan's shape/dtype.  ``mode`` names the tensor mode
    whose slices concentrate the corruption (None when not applicable)."""

    def __init__(self, message: str, *, mode: int | None = None):
        super().__init__(message)
        self.mode = mode


class NumericalError(TuckerError, FloatingPointError):
    """The computation broke down numerically: a Cholesky factorization
    failed past its re-regularization ladder, or a solver produced
    non-finite factors from a finite input."""


class ResourceError(TuckerError):
    """The runtime ran out of a resource: XLA ``RESOURCE_EXHAUSTED``/OOM,
    an allocation failure, or a serve worker that died/was abandoned."""


class DeadlineError(TuckerError, TimeoutError):
    """A served request's ``deadline_s`` expired before it was dispatched
    (checked at admission and again at wave formation)."""


class CancelledError(TuckerError):
    """The request was retracted via ``TuckerService.cancel`` before it
    was dispatched."""


#: message fragments that mark an XLA/runtime allocation failure
_RESOURCE_MARKERS = (
    "RESOURCE_EXHAUSTED", "RESOURCE EXHAUSTED", "Out of memory",
    "out of memory", "OOM", "failed to allocate", "Failed to allocate",
    "Resource exhausted",
)
#: message fragments that mark a numerical breakdown
_NUMERICAL_MARKERS = (
    "Cholesky", "cholesky", "not positive definite", "non-finite",
    "not finite", "NaN", "nan produced", "singular matrix",
    "did not converge",
)


def classify_exception(exc: BaseException) -> TuckerError | None:
    """Map a raw exception onto the taxonomy, or None when it defies
    classification (shape errors, programming bugs — those should stay
    themselves).  Already-classified errors pass through unchanged; a
    fresh wrapper chains the original via ``__cause__``."""
    if isinstance(exc, TuckerError):
        return exc
    msg = str(exc)
    wrapped: TuckerError | None = None
    if isinstance(exc, MemoryError) or \
            any(m in msg for m in _RESOURCE_MARKERS):
        wrapped = ResourceError(f"resource exhausted: {msg}")
    elif isinstance(exc, (FloatingPointError, ZeroDivisionError)) or \
            any(m in msg for m in _NUMERICAL_MARKERS):
        wrapped = NumericalError(f"numerical breakdown: {msg}")
    if wrapped is not None:
        wrapped.__cause__ = exc
    return wrapped


def coerce_exception(exc: BaseException) -> TuckerError:
    """Like :func:`classify_exception`, but total: unclassifiable failures
    come back wrapped in the :class:`TuckerError` base (original chained
    via ``__cause__``) — the serve layer's no-unclassified-escapes
    guarantee."""
    t = classify_exception(exc)
    if t is None:
        t = TuckerError(f"unclassified failure: {exc!r}")
        t.__cause__ = exc
    return t


def check_finite(x, *, name: str = "input") -> None:
    """Raise :class:`InputError` when ``x`` holds NaN/Inf, naming the
    tensor mode whose slices concentrate the corruption (the diagnosis
    walk runs only on the failure path; the pass path is one fused
    ``isfinite`` reduction)."""
    import jax.numpy as jnp
    finite = jnp.isfinite(x)
    if bool(jnp.all(finite)):
        return
    bad = jnp.logical_not(finite)
    n_bad = int(jnp.sum(bad))
    ndim = getattr(x, "ndim", 0)
    if ndim == 0:
        raise InputError(f"{name} is non-finite ({float(x)!r})")
    worst = (0, 0, -1)   # (mode, slice index, bad count in that slice)
    for mode in range(ndim):
        axes = tuple(a for a in range(ndim) if a != mode)
        per_slice = jnp.sum(bad, axis=axes) if axes else bad.astype(jnp.int32)
        idx = int(jnp.argmax(per_slice))
        cnt = int(per_slice[idx])
        if cnt > worst[2]:
            worst = (mode, idx, cnt)
    mode, idx, cnt = worst
    raise InputError(
        f"{name} contains {n_bad} non-finite value(s); the worst "
        f"concentration is mode {mode} (slice {idx} holds {cnt} of them)",
        mode=mode)


def check_result_finite(core, factors, *, context: str = "sweep") -> None:
    """Raise :class:`NumericalError` when a solve's outputs carry NaN/Inf
    (the post-execution guard of the fused ``validate="finite"`` path and
    the serve layer's lane quarantine)."""
    import jax.numpy as jnp
    if not bool(jnp.all(jnp.isfinite(core))):
        raise NumericalError(
            f"{context} produced a non-finite core tensor")
    for m, u in enumerate(factors):
        if not bool(jnp.all(jnp.isfinite(u))):
            raise NumericalError(
                f"{context} produced a non-finite mode-{m} factor")
