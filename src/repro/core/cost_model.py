"""FLOP cost model for the flexible st-HOSVD solvers (a-Tucker Eq. 4/5).

Used (a) as the analytic fallback of the adaptive selector when no trained
decision tree is available for the current platform, and (b) to derive the
Table-I features.  LAPACK-kernel constants follow standard operation counts
(Golub & Van Loan); the paper leaves f_eig/f_qr/f_inv symbolic.
"""

from __future__ import annotations

from .solvers import DEFAULT_ALS_ITERS


def f_eig(n: int) -> float:
    """Symmetric eigendecomposition (tridiagonalization + QL): ~9n^3."""
    return 9.0 * n ** 3


def f_qr(m: int, n: int) -> float:
    """Householder QR of an m×n (m ≥ n) matrix: 2mn² − (2/3)n³."""
    return 2.0 * m * n * n - (2.0 / 3.0) * n ** 3


def f_inv(n: int) -> float:
    """Inverse of an n×n SPD matrix (Cholesky + triangular solves): 2n³."""
    return 2.0 * n ** 3


def eig_flops(i_n: int, r_n: int, j_n: int) -> float:
    """Eq. (4): Gram (I_n² J_n) + TTM (2 I_n R_n J_n) + eig."""
    return float(i_n) * i_n * j_n + 2.0 * i_n * r_n * j_n + f_eig(i_n)


def als_flops(i_n: int, r_n: int, j_n: int,
              num_iters: int = DEFAULT_ALS_ITERS) -> float:
    """Eq. (5): per-iteration 2 TTM + 2 TTT + 2 GEMM + 2 inversions, plus the
    closing TTM and QR."""
    per_iter = (
        2.0 * i_n * j_n * r_n + 2.0 * j_n * r_n * r_n     # R-update TTM + scale
        + 2.0 * i_n * j_n * r_n + 2.0 * j_n * r_n * r_n   # L-update TTT + scale
        + 4.0 * i_n * r_n * r_n                           # GEMMs with inverses
        + 2.0 * f_inv(r_n)
    )
    return per_iter * num_iters + 2.0 * j_n * r_n * r_n + f_qr(i_n, r_n)


def svd_flops(i_n: int, r_n: int, j_n: int) -> float:
    """Thin SVD of the I_n×J_n unfolding (Golub–Van Loan R-SVD count,
    2mn² + 11n³ with n = min dim) plus the Σ·Vᵀ core update.  Only used for
    schedule cost annotations — the paper's Alg. 1 baseline is never the
    predicted-best solver."""
    m, n = max(i_n, j_n), min(i_n, j_n)
    return 2.0 * m * n * n + 11.0 * n ** 3 + float(r_n) * j_n


def predicted_best(i_n: int, r_n: int, j_n: int,
                   num_iters: int = DEFAULT_ALS_ITERS) -> str:
    """Analytic solver choice: smaller modeled FLOP count wins."""
    return "eig" if eig_flops(i_n, r_n, j_n) <= als_flops(i_n, r_n, j_n, num_iters) else "als"
