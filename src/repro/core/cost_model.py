"""FLOP cost model for the flexible st-HOSVD solvers (a-Tucker Eq. 4/5).

Used (a) as the analytic fallback of the adaptive selector when no trained
decision tree is available for the current platform, and (b) to derive the
Table-I features.  The paper leaves the LAPACK-kernel constants f_eig/f_qr/
f_inv symbolic; :class:`CostModel` makes them *data*: the textbook defaults
(Golub & Van Loan operation counts) ship as ``DEFAULT_COST_MODEL``, and
:mod:`repro.tune.calibrate` fits hardware-specific constants — plus a
seconds-per-FLOP scale per solver — from measured records, so the same
Eq. 4/5 structure predicts wall-clock on the box it was calibrated on.

The module-level functions (``eig_flops`` & friends) delegate to
``DEFAULT_COST_MODEL`` and keep the pre-CostModel call sites working.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .solvers import DEFAULT_ALS_ITERS, DEFAULT_OVERSAMPLE, DEFAULT_POWER_ITERS

#: model JSON schema version (bumped when the constant set changes)
COST_MODEL_VERSION = 1


@dataclass(frozen=True)
class CostModel:
    """Eq. 4/5 with explicit (calibratable) kernel constants.

    c_eig
        Symmetric eigendecomposition constant: f_eig(n) = c_eig·n³
        (textbook tridiagonalization + QL: 9).
    c_qr
        Scale on the Householder QR count 2mn² − (2/3)n³ (textbook: 1).
    c_inv
        SPD inverse constant: f_inv(n) = c_inv·n³ (textbook Cholesky +
        triangular solves: 2).
    eig_scale / als_scale
        Seconds per modeled FLOP for each solver, fitted by calibration.
        At the textbook default (1.0) the "seconds" methods return plain
        FLOP counts — ``predicted_best`` still works (a common scale
        cancels) but ``predict_seconds`` is only meaningful once
        ``source == "calibrated"``.
    eig_overhead_s / als_overhead_s
        Fitted per-solve constant overhead (dispatch/launch cost) in
        seconds.  Pure FLOP models mispredict small modes badly — ALS
        launches many more kernels per solve than EIG — so the intercept is
        part of the model, not noise (textbook default: 0).
    source
        ``"textbook"`` or ``"calibrated"`` — whether the constants came
        from operation counts or from measured records
        (:func:`repro.tune.calibrate.fit_cost_model`).
    """
    c_eig: float = 9.0
    c_qr: float = 1.0
    c_inv: float = 2.0
    eig_scale: float = 1.0
    als_scale: float = 1.0
    rand_scale: float | None = None
    eig_overhead_s: float = 0.0
    als_overhead_s: float = 0.0
    rand_overhead_s: float = 0.0
    source: str = "textbook"

    @property
    def rand_scale_eff(self) -> float:
        """rand seconds-per-FLOP actually used for pricing: the fitted
        value when a rand calibration exists, else eig's scale — the sketch
        is the same GEMM-bound TTM/TTT/QR kernel mix, so eig's per-FLOP
        rate is the closest proxy (and a calibrated model stays sane for
        rand instead of falling back to 1 s/FLOP).  Textbook models degrade
        to plain FLOP counts either way."""
        return self.eig_scale if self.rand_scale is None else self.rand_scale

    # -- kernel counts -------------------------------------------------------
    def f_eig(self, n: int) -> float:
        return self.c_eig * float(n) ** 3

    def f_qr(self, m: int, n: int) -> float:
        return self.c_qr * (2.0 * m * float(n) * n - (2.0 / 3.0) * float(n) ** 3)

    def f_inv(self, n: int) -> float:
        return self.c_inv * float(n) ** 3

    # -- Eq. 4/5 -------------------------------------------------------------
    def eig_flops(self, i_n: int, r_n: int, j_n: int) -> float:
        """Eq. (4): Gram (I_n² J_n) + TTM (2 I_n R_n J_n) + eig."""
        return float(i_n) * i_n * j_n + 2.0 * i_n * r_n * j_n + self.f_eig(i_n)

    def als_flops(self, i_n: int, r_n: int, j_n: int,
                  num_iters: int = DEFAULT_ALS_ITERS) -> float:
        """Eq. (5): per-iteration 2 TTM + 2 TTT + 2 GEMM + 2 inversions,
        plus the closing TTM and QR."""
        per_iter = (
            2.0 * i_n * j_n * r_n + 2.0 * j_n * r_n * r_n   # R-update TTM + scale
            + 2.0 * i_n * j_n * r_n + 2.0 * j_n * r_n * r_n  # L-update TTT + scale
            + 4.0 * i_n * r_n * r_n                          # GEMMs with inverses
            + 2.0 * self.f_inv(r_n)
        )
        return per_iter * num_iters + 2.0 * j_n * r_n * r_n \
            + self.f_qr(i_n, r_n)

    def rand_flops(self, i_n: int, r_n: int, j_n: int,
                   oversample: int = DEFAULT_OVERSAMPLE,
                   power_iters: int = DEFAULT_POWER_ITERS) -> float:
        """Randomized range finder at sketch width ℓ = min(I_n, R_n + p):
        range sample TTT (2 I_n ℓ J_n) + QR, per power iteration a
        project-TTM + expand-TTT + QR (4 I_n ℓ J_n + QR), the final
        projection TTM (2 I_n ℓ J_n), the ℓ×ℓ sketched Gram (ℓ² J_n) +
        eig, and the ℓ→R_n core rotation (2 ℓ R_n J_n).  Linear in I_n
        where EIG's Gram is quadratic — this is the whole point."""
        ell = min(i_n, r_n + oversample)
        sketch = 2.0 * i_n * ell * j_n + self.f_qr(i_n, ell)
        power = power_iters * (4.0 * i_n * ell * j_n + self.f_qr(i_n, ell))
        project = 2.0 * i_n * ell * j_n
        ritz = float(ell) * ell * j_n + self.f_eig(ell) + i_n * ell * r_n
        rotate = 2.0 * ell * r_n * j_n
        return sketch + power + project + ritz + rotate

    def svd_flops(self, i_n: int, r_n: int, j_n: int) -> float:
        """Thin SVD of the I_n×J_n unfolding (Golub–Van Loan R-SVD count,
        2mn² + 11n³ with n = min dim) plus the Σ·Vᵀ core update.  Only used
        for schedule cost annotations — never the predicted-best solver."""
        m, n = max(i_n, j_n), min(i_n, j_n)
        return 2.0 * m * n * n + 11.0 * n ** 3 + float(r_n) * j_n

    # -- predictions ---------------------------------------------------------
    @property
    def calibrated(self) -> bool:
        return self.source == "calibrated"

    def predict_seconds(self, method: str, i_n: int, r_n: int, j_n: int,
                        num_iters: int = DEFAULT_ALS_ITERS) -> float:
        """Predicted wall-clock for one mode solve.  Only meaningful for a
        calibrated model (the scales are then seconds per modeled FLOP)."""
        if method == "eig":
            return self.eig_overhead_s \
                + self.eig_scale * self.eig_flops(i_n, r_n, j_n)
        if method == "als":
            return self.als_overhead_s \
                + self.als_scale * self.als_flops(i_n, r_n, j_n, num_iters)
        if method == "rand":
            return self.rand_overhead_s \
                + self.rand_scale_eff * self.rand_flops(i_n, r_n, j_n)
        # svd has no dedicated scale; the eig scale is the closest GEMM proxy
        return self.eig_scale * self.svd_flops(i_n, r_n, j_n)

    def predicted_best(self, i_n: int, r_n: int, j_n: int,
                       num_iters: int = DEFAULT_ALS_ITERS,
                       methods: tuple = ("eig", "als")) -> str:
        """Analytic solver choice over ``methods``: smallest scaled cost wins
        (ties break toward the earlier entry, so the default keeps the
        historical eig-on-tie behavior)."""
        return min(methods, key=lambda m: (
            self.predict_seconds(m, i_n, r_n, j_n, num_iters),
            methods.index(m)))

    # -- persistence ---------------------------------------------------------
    def to_dict(self) -> dict:
        return {"version": COST_MODEL_VERSION, "c_eig": self.c_eig,
                "c_qr": self.c_qr, "c_inv": self.c_inv,
                "eig_scale": self.eig_scale, "als_scale": self.als_scale,
                "rand_scale": self.rand_scale,
                "eig_overhead_s": self.eig_overhead_s,
                "als_overhead_s": self.als_overhead_s,
                "rand_overhead_s": self.rand_overhead_s,
                "source": self.source}

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        return cls(c_eig=float(d.get("c_eig", 9.0)),
                   c_qr=float(d.get("c_qr", 1.0)),
                   c_inv=float(d.get("c_inv", 2.0)),
                   eig_scale=float(d.get("eig_scale", 1.0)),
                   als_scale=float(d.get("als_scale", 1.0)),
                   rand_scale=(None if d.get("rand_scale") is None
                               else float(d["rand_scale"])),
                   eig_overhead_s=float(d.get("eig_overhead_s", 0.0)),
                   als_overhead_s=float(d.get("als_overhead_s", 0.0)),
                   rand_overhead_s=float(d.get("rand_overhead_s", 0.0)),
                   source=str(d.get("source", "textbook")))

    def with_(self, **kw) -> "CostModel":
        return replace(self, **kw)


DEFAULT_COST_MODEL = CostModel()


# ---------------------------------------------------------------------------
# Module-level back-compat surface (textbook constants)
# ---------------------------------------------------------------------------

def f_eig(n: int) -> float:
    """Symmetric eigendecomposition (tridiagonalization + QL): ~9n^3."""
    return DEFAULT_COST_MODEL.f_eig(n)


def f_qr(m: int, n: int) -> float:
    """Householder QR of an m×n (m ≥ n) matrix: 2mn² − (2/3)n³."""
    return DEFAULT_COST_MODEL.f_qr(m, n)


def f_inv(n: int) -> float:
    """Inverse of an n×n SPD matrix (Cholesky + triangular solves): 2n³."""
    return DEFAULT_COST_MODEL.f_inv(n)


def eig_flops(i_n: int, r_n: int, j_n: int) -> float:
    return DEFAULT_COST_MODEL.eig_flops(i_n, r_n, j_n)


def als_flops(i_n: int, r_n: int, j_n: int,
              num_iters: int = DEFAULT_ALS_ITERS) -> float:
    return DEFAULT_COST_MODEL.als_flops(i_n, r_n, j_n, num_iters)


def svd_flops(i_n: int, r_n: int, j_n: int) -> float:
    return DEFAULT_COST_MODEL.svd_flops(i_n, r_n, j_n)


def rand_flops(i_n: int, r_n: int, j_n: int,
               oversample: int = DEFAULT_OVERSAMPLE,
               power_iters: int = DEFAULT_POWER_ITERS) -> float:
    return DEFAULT_COST_MODEL.rand_flops(i_n, r_n, j_n, oversample, power_iters)


def predicted_best(i_n: int, r_n: int, j_n: int,
                   num_iters: int = DEFAULT_ALS_ITERS) -> str:
    """Analytic solver choice: smaller modeled FLOP count wins."""
    return DEFAULT_COST_MODEL.predicted_best(i_n, r_n, j_n, num_iters)
