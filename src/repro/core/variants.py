"""Tucker-decomposition variants beyond st-HOSVD (paper §II-B / §VIII).

The paper focuses on st-HOSVD and names t-HOSVD and HOOI as the natural
extensions ("owning to the similar algorithm structure, the proposed ideas
and optimizations can also be extended") — both are built here on the same
matricization-free solvers and the same adaptive selector:

  * t-HOSVD: every factor computed from the ORIGINAL tensor (no sequential
    shrinking) — more flops, sometimes preferred for parallel factor
    computation.
  * HOOI: higher-order orthogonal iteration — alternating refinement of the
    factors, initialized from st-HOSVD (the standard pairing).  Each inner
    subproblem is a mode solve of the partially-projected tensor, so the
    EIG/ALS switch and the selector apply verbatim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import tensor_ops as T
from .solvers import DEFAULT_ALS_ITERS, SOLVERS
from .sthosvd import SthosvdResult, ModeTrace, TuckerTensor, sthosvd


def thosvd(x: jax.Array, ranks, methods: str = "auto", *,
           selector=None, als_iters: int = DEFAULT_ALS_ITERS) -> SthosvdResult:
    """Truncated HOSVD: factors from the original tensor, one projection."""
    n = x.ndim
    ranks = tuple(int(r) for r in ranks)
    if methods == "auto" and selector is None:
        from .selector import default_selector
        selector = default_selector()

    factors = []
    trace = []
    for mode in range(n):
        i_n, r_n = x.shape[mode], ranks[mode]
        j_n = x.size // i_n
        method = (selector(i_n=i_n, r_n=r_n, j_n=j_n) if methods == "auto"
                  else (methods if isinstance(methods, str) else methods[mode]))
        kw = {"num_iters": als_iters} if method == "als" else {}
        res = SOLVERS[method](x, mode, r_n, **kw)
        factors.append(res.u)
        trace.append(ModeTrace(mode, method, i_n, r_n, j_n, 0.0))
    core = x
    for mode, u in enumerate(factors):
        core = T.ttm(core, u.T, mode)
    return SthosvdResult(TuckerTensor(core=core, factors=factors), trace=trace)


def hooi(x: jax.Array, ranks, *, n_iters: int = 3, methods: str = "auto",
         selector=None, als_iters: int = DEFAULT_ALS_ITERS,
         init: SthosvdResult | None = None) -> SthosvdResult:
    """Higher-order orthogonal iteration, st-HOSVD-initialized.

    Per sweep and mode: project x on all OTHER factors, then solve the mode
    with the flexible (selector-driven) solver.  Error is non-increasing in
    exact arithmetic; typically converges in 2–5 sweeps.
    """
    n = x.ndim
    ranks = tuple(int(r) for r in ranks)
    if methods == "auto" and selector is None:
        from .selector import default_selector
        selector = default_selector()

    base = init or sthosvd(x, ranks, methods=methods, selector=selector,
                           als_iters=als_iters)
    factors = list(base.tucker.factors)
    trace = list(base.trace)

    for _ in range(n_iters):
        for mode in range(n):
            # project on every factor except `mode`
            y = x
            for m, u in enumerate(factors):
                if m != mode:
                    y = T.ttm(y, u.T, m)
            i_n, r_n = y.shape[mode], ranks[mode]
            j_n = y.size // i_n
            method = (selector(i_n=i_n, r_n=r_n, j_n=j_n) if methods == "auto"
                      else (methods if isinstance(methods, str) else methods[mode]))
            kw = {"num_iters": als_iters} if method == "als" else {}
            res = SOLVERS[method](y, mode, r_n, **kw)
            factors[mode] = res.u
            trace.append(ModeTrace(mode, method, i_n, r_n, j_n, 0.0))

    core = x
    for mode, u in enumerate(factors):
        core = T.ttm(core, u.T, mode)
    return SthosvdResult(TuckerTensor(core=core, factors=factors), trace=trace)
