"""Tucker-decomposition variants beyond st-HOSVD (paper §II-B / §VIII).

The paper focuses on st-HOSVD and names t-HOSVD and HOOI as the natural
extensions ("owning to the similar algorithm structure, the proposed ideas
and optimizations can also be extended") — both are built here on the same
matricization-free solvers and the same adaptive selector:

  * t-HOSVD: every factor computed from the ORIGINAL tensor (no sequential
    shrinking) — more flops, sometimes preferred for parallel factor
    computation.
  * HOOI: higher-order orthogonal iteration — alternating refinement of the
    factors, initialized from st-HOSVD (the standard pairing).  Each inner
    subproblem is a mode solve of the partially-projected tensor, so the
    EIG/ALS switch and the selector apply verbatim.

Both route through :mod:`repro.core.plan`'s schedule resolution and solver
dispatch — the per-variant copies of the selector logic are gone, and
``impl=``/``block_until_ready=`` behave exactly as in :func:`sthosvd`.
"""

from __future__ import annotations

import time

import jax

from . import tensor_ops as T
from .backend import resolve_backend
from .plan import TimedSelector, resolve_schedule, run_schedule, solve_step
from .solvers import DEFAULT_ALS_ITERS
from .sthosvd import ModeTrace, SthosvdResult, TuckerTensor, sthosvd


def _auto_selector(methods, selector):
    if methods == "auto" and selector is None:
        from .selector import default_selector
        selector = default_selector()
    return TimedSelector(selector) if methods == "auto" else None


def thosvd(x: jax.Array, ranks, methods: str = "auto", *,
           selector=None, als_iters: int = DEFAULT_ALS_ITERS,
           impl: str = "matfree", memory_cap_bytes: int | None = None,
           block_until_ready: bool = False) -> SthosvdResult:
    """Truncated HOSVD: factors from the original tensor, one projection.

    ``memory_cap_bytes`` fails the plan loudly when any mode solve's modeled
    peak exceeds it — t-HOSVD has no order freedom, so the cap can only be
    met by a smaller solver (or not at all)."""
    backend = resolve_backend(impl, dtype=x.dtype)
    timed = _auto_selector(methods, selector)
    schedule = resolve_schedule(
        x.shape, ranks, variant="thosvd", methods=methods,
        selector=timed or selector, als_iters=als_iters,
        itemsize=x.dtype.itemsize, backend=backend.name,
        memory_cap_bytes=memory_cap_bytes)
    _, factors, seconds = run_schedule(
        x, schedule, sequential=False, als_iters=als_iters,
        block_until_ready=block_until_ready)
    trace = [ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, dt,
                       backend=s.backend, predicted_s=s.predicted_s)
             for s, dt in zip(schedule, seconds)]
    core = x
    for mode in range(x.ndim):
        core = T.ttm(core, factors[mode].T, mode)
    return SthosvdResult(
        TuckerTensor(core=core, factors=[factors[m] for m in range(x.ndim)]),
        trace=trace, select_overhead_s=timed.seconds if timed else 0.0)


def hooi(x: jax.Array, ranks, *, n_iters: int = 3, methods: str = "auto",
         selector=None, als_iters: int = DEFAULT_ALS_ITERS,
         impl: str = "matfree", mode_order=None,
         memory_cap_bytes: int | None = None,
         block_until_ready: bool = False,
         init: SthosvdResult | None = None) -> SthosvdResult:
    """Higher-order orthogonal iteration, st-HOSVD-initialized.

    Per sweep and mode: project x on all OTHER factors, then solve the mode
    with the flexible (selector-driven) solver.  Error is non-increasing in
    exact arithmetic; typically converges in 2–5 sweeps.

    ``mode_order`` (incl. ``"shrink"``/``"opt"``) orders the st-HOSVD INIT
    sweep — refinement sweeps always cycle 0..N-1; ``memory_cap_bytes``
    caps every step (init and refinements) at plan time.
    """
    backend = resolve_backend(impl, dtype=x.dtype)
    timed = _auto_selector(methods, selector)
    base = init or sthosvd(x, ranks, methods=methods,
                           selector=timed or selector, als_iters=als_iters,
                           impl=impl, mode_order=mode_order,
                           memory_cap_bytes=memory_cap_bytes,
                           block_until_ready=block_until_ready)
    factors = list(base.tucker.factors)
    trace = list(base.trace)

    schedule = resolve_schedule(
        x.shape, ranks, variant="hooi", methods=methods,
        selector=timed or selector, als_iters=als_iters, hooi_iters=n_iters,
        include_init=False, itemsize=x.dtype.itemsize, backend=backend.name,
        memory_cap_bytes=memory_cap_bytes)
    for step in schedule:
        y = x
        for m, u in enumerate(factors):
            if m != step.mode:
                y = T.ttm(y, u.T, m)
        t0 = time.perf_counter()
        res = solve_step(y, step, als_iters=als_iters)
        if block_until_ready:
            jax.block_until_ready(res.u)
        factors[step.mode] = res.u
        trace.append(ModeTrace(step.mode, step.method, step.i_n, step.r_n,
                               step.j_n, time.perf_counter() - t0,
                               backend=step.backend,
                               predicted_s=step.predicted_s))

    core = x
    for mode, u in enumerate(factors):
        core = T.ttm(core, u.T, mode)
    return SthosvdResult(TuckerTensor(core=core, factors=factors),
                         trace=trace,
                         select_overhead_s=timed.seconds if timed else 0.0)
