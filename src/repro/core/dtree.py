"""Pure-numpy CART decision tree (a-Tucker Sec. IV substrate).

scikit-learn is not available in this environment, and the paper's selector
only needs a small binary classifier, so we implement CART directly:
gini-impurity splits, class weights ('balanced' | 'uniform'), max_depth /
min_samples_leaf regularization, and a grid-search-with-CV helper mirroring
the paper's hyper-parameter tuning (max_depth ∈ [1,10], class_weight ∈
{'balanced','uniform'}).

Inference is vectorized (arrays of node thresholds) and also exportable as a
flat rule table for microsecond single-sample dispatch inside the st-HOSVD
mode loop (paper Fig. 7: 23–90 µs overhead).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class _Node:
    feature: int = -1          # -1 → leaf
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: int = 0             # majority class at leaf
    prob: float = 0.0          # weighted P(class=1)


@dataclass
class DecisionTree:
    max_depth: int = 6
    min_samples_leaf: int = 8
    class_weight: str = "uniform"   # 'uniform' | 'balanced'
    nodes: list[_Node] = field(default_factory=list)

    # -- training ----------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> "DecisionTree":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.int64)
        if self.class_weight == "balanced":
            counts = np.bincount(y, minlength=2).astype(np.float64)
            counts[counts == 0] = 1.0
            cw = y.size / (2.0 * counts)
        else:
            cw = np.ones(2)
        w = cw[y]
        self.nodes = []
        self._build(x, y, w, depth=0)
        return self

    def _gini_gain(self, y, w, mask):
        """Weighted gini impurity decrease for a boolean split mask."""
        def gini(ys, ws):
            tot = ws.sum()
            if tot <= 0:
                return 0.0, 0.0
            p1 = ws[ys == 1].sum() / tot
            return 2.0 * p1 * (1.0 - p1), tot

        g0, t0 = gini(y, w)
        gl, tl = gini(y[mask], w[mask])
        gr, tr = gini(y[~mask], w[~mask])
        if t0 <= 0:
            return 0.0
        return g0 - (tl / t0) * gl - (tr / t0) * gr

    def _build(self, x, y, w, depth) -> int:
        idx = len(self.nodes)
        node = _Node()
        self.nodes.append(node)
        tot = w.sum()
        p1 = w[y == 1].sum() / tot if tot > 0 else 0.0
        node.value = int(p1 >= 0.5)
        node.prob = float(p1)

        if depth >= self.max_depth or y.size < 2 * self.min_samples_leaf or p1 in (0.0, 1.0):
            return idx

        best = (0.0, -1, 0.0)  # gain, feature, threshold
        n_feat = x.shape[1]
        for f in range(n_feat):
            xs = np.unique(x[:, f])
            if xs.size < 2:
                continue
            # candidate thresholds: midpoints of up to 64 quantile cuts
            if xs.size > 64:
                qs = np.quantile(xs, np.linspace(0, 1, 65)[1:-1])
            else:
                qs = (xs[:-1] + xs[1:]) / 2.0
            for t in np.unique(qs):
                mask = x[:, f] <= t
                nl = int(mask.sum())
                if nl < self.min_samples_leaf or (y.size - nl) < self.min_samples_leaf:
                    continue
                gain = self._gini_gain(y, w, mask)
                if gain > best[0] + 1e-12:
                    best = (gain, f, float(t))

        if best[1] < 0:
            return idx
        _, f, t = best
        mask = x[:, f] <= t
        node.feature = f
        node.threshold = t
        node.left = self._build(x[mask], y[mask], w[mask], depth + 1)
        node.right = self._build(x[~mask], y[~mask], w[~mask], depth + 1)
        return idx

    # -- inference -----------------------------------------------------------
    def predict_one(self, feats) -> int:
        i = 0
        nodes = self.nodes
        while nodes[i].feature >= 0:
            i = nodes[i].left if feats[nodes[i].feature] <= nodes[i].threshold else nodes[i].right
        return nodes[i].value

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        return np.fromiter((self.predict_one(row) for row in x), dtype=np.int64, count=len(x))

    def score(self, x, y) -> float:
        return float((self.predict(x) == np.asarray(y)).mean())

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    # -- (de)serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "max_depth": self.max_depth,
            "min_samples_leaf": self.min_samples_leaf,
            "class_weight": self.class_weight,
            "nodes": [
                [n.feature, n.threshold, n.left, n.right, n.value, n.prob]
                for n in self.nodes
            ],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionTree":
        t = cls(d["max_depth"], d["min_samples_leaf"], d["class_weight"])
        t.nodes = [_Node(int(f), float(th), int(l), int(r), int(v), float(p))
                   for f, th, l, r, v, p in d["nodes"]]
        return t


def grid_search_cv(
    x: np.ndarray,
    y: np.ndarray,
    max_depths=range(1, 11),
    class_weights=("uniform", "balanced"),
    n_folds: int = 3,
    seed: int = 0,
) -> tuple[DecisionTree, dict]:
    """Exhaustive grid search with k-fold CV (paper Sec. IV-B)."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.int64)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(y))
    folds = np.array_split(perm, n_folds)

    best_acc, best_params = -1.0, None
    for d in max_depths:
        for cw in class_weights:
            accs = []
            for k in range(n_folds):
                val = folds[k]
                trn = np.concatenate([folds[j] for j in range(n_folds) if j != k])
                t = DecisionTree(max_depth=d, class_weight=cw).fit(x[trn], y[trn])
                accs.append(t.score(x[val], y[val]))
            acc = float(np.mean(accs))
            if acc > best_acc:
                best_acc, best_params = acc, {"max_depth": d, "class_weight": cw}
    final = DecisionTree(**best_params).fit(x, y)
    return final, {"cv_accuracy": best_acc, **best_params}
