"""Distributed st-HOSVD for tensors sharded across a mesh (TuckerMPI pattern,
JAX-native) — the execution engine behind the ``sharded`` ops backend.

Decomposition of a tensor sharded along one mode over a mesh axis:

  * Gram (mode n ≠ shard mode m): each device contracts its local slab —
    the shard axis lives inside the merged contraction dims — giving a
    *partial* I_n×I_n Gram; one ``psum`` over the shard axis completes it.
    (Explicit ``shard_map`` so the collective schedule is visible.)
  * eigh on the replicated small Gram runs redundantly on every device
    (standard practice; I_n×I_n is tiny next to the tensor).
  * TTM (mode n ≠ m): embarrassingly local; output stays sharded on m.
  * Before processing the currently-sharded mode the tensor is resharded to
    the largest *remaining* mode (one all-to-all, amortized by the shrink).

The ALS path runs under GSPMD (sharding constraints inside jit) — its inner
TTM/TTT chain contracts sharded dims, and XLA inserts the same psum pattern
automatically; we keep it as the reference for the manual schedule.

The distribution *decisions* (which mode to shard per step, where the
reshards land) are frozen at plan time by
:func:`repro.core.plan.resolve_schedule` via :func:`pick_shard_mode`; this
module only executes frozen :class:`~repro.core.plan.ModeStep` schedules:

  * :func:`run_sharded_schedule` — eager per-step runner with real per-mode
    wall-clock (the legacy :func:`sthosvd_distributed` entry point).
  * :func:`sweep_sharded` — the same schedule as one pure function, compiled
    whole by ``TuckerPlan``'s process-wide sweep cache (zero recompiles on
    plan reuse, exactly like the single-device backends).
  * :func:`sweep_mode_parallel` — the group-aware sweep for schedules whose
    steps carry ``group`` ids: every member of a group computes its factor
    from the SAME un-shrunk tensor (all eig Grams fused into ONE shard_map
    with one psum each — one mesh barrier for the whole group instead of
    one per mode), then a single fused multi-TTM truncates all group modes
    at once.  Lower latency, more FLOPs; the plan-time DP
    (:mod:`repro.core.schedule_opt`) decides when that trade wins.
"""

from __future__ import annotations

import time
from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..obs import drift as _drift
from ..obs import trace as _obs
from . import tensor_ops as T
from .plan import ModeStep, solve_step
from .solvers import DEFAULT_ALS_ITERS, als_solve
from .sthosvd import ModeTrace, SthosvdResult, TuckerTensor

try:  # jax.core.Tracer is deprecated on newer jax; _src.core keeps it
    from jax.core import Tracer as _Tracer
except (ImportError, AttributeError):  # pragma: no cover - jax-version dependent
    from jax._src.core import Tracer as _Tracer


def _spec_for(ndim: int, mode: int | None, axis: str) -> P:
    parts = [None] * ndim
    if mode is not None:
        parts[mode] = axis
    return P(*parts)


def _reshard(x: jax.Array, mesh: Mesh, mode: int | None, axis: str) -> jax.Array:
    """Move ``x`` onto the mesh, sharded on ``mode`` (None = replicated).
    Inside a jit trace this lowers to a sharding constraint (GSPMD inserts
    the all-to-all); eagerly it is a device_put."""
    sh = NamedSharding(mesh, _spec_for(x.ndim, mode, axis))
    if isinstance(x, _Tracer):
        return jax.lax.with_sharding_constraint(x, sh)
    return jax.device_put(x, sh)


@lru_cache(maxsize=256)
def _gram_psum(mesh: Mesh, axis: str, ndim: int, mode: int, shard_mode: int):
    """shard_map'd partial-Gram + psum over the shard axis (cached per
    (mesh, schedule-position) so eager reuse never rebuilds the jit)."""
    @jax.jit
    def run(x):
        def body(xl):
            s_local = T.gram(xl, mode)
            return jax.lax.psum(s_local, axis)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=_spec_for(ndim, shard_mode, axis),
            out_specs=P(),
        )(x)
    return run


@lru_cache(maxsize=256)
def _ttm_local(mesh: Mesh, axis: str, ndim: int, mode: int, shard_mode: int):
    """shard_map'd local TTM (contraction mode fully local)."""
    @jax.jit
    def run(x, ut):
        def body(xl, utl):
            return T.ttm(xl, utl, mode)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(_spec_for(ndim, shard_mode, axis), P()),
            out_specs=_spec_for(ndim, shard_mode, axis),
        )(x, ut)
    return run


@lru_cache(maxsize=256)
def _gram_group_psum(mesh: Mesh, axis: str, ndim: int, modes: tuple,
                     shard_mode: int):
    """ONE shard_map producing every group member's psum'd Gram from the
    same local slab — the mode-parallel latency win: a single mesh barrier
    amortized over ``len(modes)`` Grams instead of one barrier each."""
    @jax.jit
    def run(x):
        def body(xl):
            return tuple(jax.lax.psum(T.gram(xl, m), axis) for m in modes)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=_spec_for(ndim, shard_mode, axis),
            out_specs=tuple(P() for _ in modes),
        )(x)
    return run


@lru_cache(maxsize=256)
def _ttm_group_local(mesh: Mesh, axis: str, ndim: int, modes: tuple,
                     shard_mode: int):
    """shard_map'd fused multi-TTM: chain every group member's truncation
    over the local slab in one program (all contraction modes ≠ the shard
    mode, so no collective is needed at all)."""
    @jax.jit
    def run(x, *uts):
        def body(xl, *utl):
            for m, u in zip(modes, utl):
                xl = T.ttm(xl, u, m)
            return xl
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(_spec_for(ndim, shard_mode, axis),)
            + (P(),) * len(modes),
            out_specs=_spec_for(ndim, shard_mode, axis),
        )(x, *uts)
    return run


def pick_shard_mode(shape: tuple[int, ...], exclude: int, n_shards: int) -> int | None:
    """Largest mode ≠ ``exclude`` divisible by the shard count; None → the
    (shrunk) tensor no longer shards evenly and is cheap enough to replicate
    — st-HOSVD's sequential shrinking makes the late modes tiny."""
    return pick_shard_mode_group(shape, (exclude,), n_shards)


def pick_shard_mode_group(shape: tuple[int, ...], exclude,
                          n_shards: int) -> int | None:
    """Largest mode outside ``exclude`` (an iterable of modes) divisible by
    the shard count.  A mode-parallel group's shard mode must lie OUTSIDE
    the group: the Gram of the sharded mode itself would need an all-gather,
    so a group covering every shardable mode runs replicated (``None``) —
    the memory model prices exactly that, which is how a per-device cap can
    refuse an all-modes group."""
    excluded = frozenset(exclude)
    order = sorted(range(len(shape)), key=lambda m: -shape[m])
    for m in order:
        if m not in excluded and shape[m] % n_shards == 0:
            return m
    return None


# ---------------------------------------------------------------------------
# Frozen-schedule execution (shared by the plan layer and the legacy entry)
# ---------------------------------------------------------------------------

def _eig_u(s: jax.Array, r_n: int, dtype) -> jax.Array:
    """Top-r_n eigvecs of a (replicated) Gram, descending, in compute dtype."""
    _, vecs = jnp.linalg.eigh(
        s.astype(jnp.promote_types(s.dtype, jnp.float32)))
    return vecs[:, -r_n:][:, ::-1].astype(dtype)


def solve_step_sharded(y: jax.Array, step: ModeStep, mesh: Mesh, axis: str,
                       *, als_iters: int = DEFAULT_ALS_ITERS):
    """One frozen mode solve on the mesh: reshard to the step's recorded
    shard mode, then run its solver's collective schedule.  Returns
    ``(u, y_new)`` with ``y_new`` sharded on ``step.shard_mode``.

    Works both eagerly (``run_sharded_schedule``) and under an enclosing jit
    trace (``sweep_sharded``): resharding becomes a device_put or a GSPMD
    constraint accordingly.
    """
    n = y.ndim
    y = _reshard(y, mesh, step.shard_mode, axis)
    if step.shard_mode is None:
        # replicated fallback: every device runs the plain local solve
        # (matfree primitives — same contract as the single-device path)
        res = solve_step(y, step, als_iters=als_iters, impl="matfree")
        return res.u, res.y_new
    if step.method == "eig":
        s = _gram_psum(mesh, axis, n, step.mode, step.shard_mode)(y)
        u = _eig_u(s, step.r_n, y.dtype)
        y = _ttm_local(mesh, axis, n, step.mode, step.shard_mode)(y, u.T)
        return u, y
    if step.method == "als":
        # GSPMD path: y carries the shard constraint, XLA inserts the psums
        u, y_new = als_solve(y, step.mode, step.r_n, num_iters=als_iters)
        return u, _reshard(y_new, mesh, step.shard_mode, axis)
    raise ValueError(f"unknown distributed method {step.method!r}")


def solve_group_sharded(y: jax.Array, group, mesh: Mesh, axis: str, *,
                        als_iters: int = DEFAULT_ALS_ITERS):
    """One frozen mode-parallel group on the mesh: every member's factor is
    computed from the SAME un-shrunk tensor — all eig Grams through ONE
    fused shard_map+psum, ALS members under GSPMD against the shared input
    — then a single fused multi-TTM truncates every group mode at once.
    Returns ``(factors, y_new)`` with ``factors`` keyed by mode and
    ``y_new`` sharded on the group's (shared) shard mode.

    Like :func:`solve_step_sharded` this works both eagerly and under an
    enclosing jit trace.
    """
    n = y.ndim
    for step in group:
        if step.method not in ("eig", "als"):
            raise ValueError(
                f"method {step.method!r} cannot run in a mode-parallel "
                "group (plan-time resolution should have rejected it)")
    shard = group[0].shard_mode   # one shard mode serves the whole group
    y = _reshard(y, mesh, shard, axis)
    factors: dict[int, jax.Array] = {}
    if shard is None:
        # replicated group (it covered every shardable mode): plain local
        # Grams / ALS on the full tensor, then the fused truncation chain
        for step in group:
            if step.method == "eig":
                factors[step.mode] = _eig_u(T.gram(y, step.mode),
                                            step.r_n, y.dtype)
            else:
                u, _ = als_solve(y, step.mode, step.r_n,
                                 num_iters=als_iters)
                factors[step.mode] = u
        y_new = y
        for step in group:
            y_new = T.ttm(y_new, factors[step.mode].T, step.mode)
        return factors, y_new
    eig_steps = [s for s in group if s.method == "eig"]
    if eig_steps:
        modes = tuple(s.mode for s in eig_steps)
        grams = _gram_group_psum(mesh, axis, n, modes, shard)(y)
        for step, s in zip(eig_steps, grams):
            factors[step.mode] = _eig_u(s, step.r_n, y.dtype)
    for step in group:
        if step.method == "als":
            # GSPMD from the shared (still un-shrunk) input; the eager
            # y_new it also produces is unused and DCE'd under jit
            u, _ = als_solve(y, step.mode, step.r_n, num_iters=als_iters)
            factors[step.mode] = u
    modes_all = tuple(s.mode for s in group)
    uts = tuple(factors[m].T for m in modes_all)
    y = _ttm_group_local(mesh, axis, n, modes_all, shard)(y, *uts)
    return factors, y


def run_sharded_schedule(x: jax.Array, steps, mesh: Mesh, axis: str, *,
                         als_iters: int = DEFAULT_ALS_ITERS,
                         block_until_ready: bool = True):
    """Eager runner: per-step execution with real wall-clock per mode.

    Mode-parallel groups run as one unit; their wall-clock is attributed
    evenly across the members so ``seconds`` stays index-aligned with
    ``steps``.  Returns ``(y, factors, seconds)`` like
    :func:`repro.core.plan.run_schedule` (``factors`` keyed by mode).
    """
    from .plan import iter_groups
    y = x
    factors: dict[int, jax.Array] = {}
    seconds: list[float] = []
    platform = jax.default_backend()
    for batch in iter_groups(steps):
        wall0 = time.time()
        t0 = time.perf_counter()
        if len(batch) == 1:
            u, y = solve_step_sharded(y, batch[0], mesh, axis,
                                      als_iters=als_iters)
            factors[batch[0].mode] = u
        else:
            fs, y = solve_group_sharded(y, batch, mesh, axis,
                                        als_iters=als_iters)
            factors.update(fs)
        if block_until_ready:
            jax.block_until_ready(y)
        dt = time.perf_counter() - t0
        seconds.extend([dt / len(batch)] * len(batch))
        if block_until_ready:
            for s in batch:
                # group wall-clock attributed evenly, matching ``seconds``
                _obs.event("span", t=wall0, name="solve",
                           dur_s=dt / len(batch), mode=s.mode,
                           solver=s.method, backend="sharded",
                           platform=platform, rank=s.r_n, i_n=s.i_n,
                           j_n=s.j_n, n_shards=s.n_shards,
                           group=s.group, predicted_s=s.predicted_s)
                _drift.MONITOR.observe(platform=platform, backend="sharded",
                                       solver=s.method,
                                       predicted_s=s.predicted_s,
                                       actual_s=dt / len(batch),
                                       source="execute")
    return y, factors, seconds


def sweep_sharded(x, steps, *, mesh: Mesh, axis: str, als_iters: int):
    """The whole sharded sweep as one pure function, jit-compiled by
    ``TuckerPlan`` — inner shard_maps and sharding constraints inline into a
    single XLA program with the reshard collectives at the frozen points."""
    y = x
    factors: dict[int, jax.Array] = {}
    for step in steps:
        u, y = solve_step_sharded(y, step, mesh, axis, als_iters=als_iters)
        factors[step.mode] = u
    return y, [factors[m] for m in range(x.ndim)]


def sweep_mode_parallel(x, steps, *, mesh: Mesh, axis: str, als_iters: int):
    """Group-aware whole-sweep: like :func:`sweep_sharded` but schedules
    carrying ``group`` ids run each group through
    :func:`solve_group_sharded` (concurrent Grams, one fused multi-TTM).
    Pure — compiled by the same ``TuckerPlan`` sweep cache, so repeated
    execution of a mode-parallel plan stays zero-recompile."""
    from .plan import iter_groups
    y = x
    factors: dict[int, jax.Array] = {}
    for batch in iter_groups(steps):
        if len(batch) == 1:
            u, y = solve_step_sharded(y, batch[0], mesh, axis,
                                      als_iters=als_iters)
            factors[batch[0].mode] = u
        else:
            fs, y = solve_group_sharded(y, batch, mesh, axis,
                                        als_iters=als_iters)
            factors.update(fs)
    return y, [factors[m] for m in range(x.ndim)]


# ---------------------------------------------------------------------------
# Legacy entry point — thin wrapper over the shared schedule machinery
# ---------------------------------------------------------------------------

def sthosvd_distributed(
    x: jax.Array,
    ranks,
    mesh: Mesh,
    *,
    axis: str = "data",
    methods: str = "eig",
    als_iters: int = DEFAULT_ALS_ITERS,
    selector=None,
    mode_order=None,
    memory_cap_bytes: int | None = None,
    mode_parallel: str | int = "off",
    block_until_ready: bool = True,
) -> SthosvdResult:
    """Distributed flexible st-HOSVD.  ``methods``: 'eig' | 'als' | 'auto'.

    ``mode_order="opt"`` runs the subset-DP schedule search against the
    PER-DEVICE peak model (shard participation per state follows
    :func:`pick_shard_mode`); ``memory_cap_bytes`` is the per-device cap —
    the regime where sharding decides whether a mode fits at all.
    ``mode_parallel`` ("off" | "auto" | int) opts steps into concurrent
    mode-parallel groups — see :func:`repro.core.plan.resolve_schedule`.

    Thin wrapper over the shared plan machinery: the per-mode solver AND
    shard-mode schedule is resolved ahead of time
    (:func:`repro.core.plan.resolve_schedule` with ``backend="sharded"``),
    then run eagerly with real per-mode wall-clock in the trace — exactly
    how :func:`repro.core.sthosvd.sthosvd` wraps the single-device runner.
    For amortized/batched execution build a plan instead:
    ``plan(shape, dtype, TuckerConfig(..., impl="sharded", mesh=mesh))``.
    """
    from .plan import TimedSelector, resolve_schedule

    timed = None
    if methods == "auto":
        if selector is None:
            from .selector import default_selector
            selector = default_selector()
        selector = timed = TimedSelector(selector)
    schedule = resolve_schedule(
        x.shape, ranks, variant="sthosvd", methods=methods, selector=selector,
        mode_order=mode_order, als_iters=als_iters,
        itemsize=x.dtype.itemsize, backend="sharded",
        n_shards=mesh.shape[axis], memory_cap_bytes=memory_cap_bytes,
        mode_parallel=mode_parallel)

    y, factors, seconds = run_sharded_schedule(
        x, schedule, mesh, axis, als_iters=als_iters,
        block_until_ready=block_until_ready)
    trace = [ModeTrace(s.mode, s.method, s.i_n, s.r_n, s.j_n, dt,
                       backend=s.backend, predicted_s=s.predicted_s)
             for s, dt in zip(schedule, seconds)]
    tucker = TuckerTensor(core=y, factors=[factors[m] for m in range(x.ndim)])
    return SthosvdResult(tucker=tucker, trace=trace,
                         select_overhead_s=timed.seconds if timed else 0.0)
