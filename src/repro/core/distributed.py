"""Distributed st-HOSVD for tensors sharded across a mesh (TuckerMPI pattern,
JAX-native).

Decomposition of a tensor sharded along one mode over a mesh axis:

  * Gram (mode n ≠ shard mode m): each device contracts its local slab —
    the shard axis lives inside the merged contraction dims — giving a
    *partial* I_n×I_n Gram; one ``psum`` over the shard axis completes it.
    (Explicit ``shard_map`` so the collective schedule is visible.)
  * eigh on the replicated small Gram runs redundantly on every device
    (standard practice; I_n×I_n is tiny next to the tensor).
  * TTM (mode n ≠ m): embarrassingly local; output stays sharded on m.
  * Before processing the currently-sharded mode the tensor is resharded to
    the largest *remaining* mode (one all-to-all, amortized by the shrink).

The ALS path runs under GSPMD (jit + shardings) — its inner TTM/TTT chain
contracts sharded dims, and XLA inserts the same psum pattern automatically;
we keep it as the reference for the manual schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from . import tensor_ops as T
from .solvers import DEFAULT_ALS_ITERS
from .sthosvd import SthosvdResult, ModeTrace, TuckerTensor


def _spec_for(ndim: int, mode: int | None, axis: str) -> P:
    parts = [None] * ndim
    if mode is not None:
        parts[mode] = axis
    return P(*parts)


def _shard(x: jax.Array, mesh: Mesh, mode: int | None, axis: str) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, _spec_for(x.ndim, mode, axis)))


def _gram_psum(mesh: Mesh, axis: str, ndim: int, mode: int, shard_mode: int):
    """shard_map'd partial-Gram + psum over the shard axis."""
    @jax.jit
    def run(x):
        def body(xl):
            s_local = T.gram(xl, mode)
            return jax.lax.psum(s_local, axis)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=_spec_for(ndim, shard_mode, axis),
            out_specs=P(),
        )(x)
    return run


def _ttm_local(mesh: Mesh, axis: str, ndim: int, mode: int, shard_mode: int):
    """shard_map'd local TTM (contraction mode fully local)."""
    @jax.jit
    def run(x, ut):
        def body(xl, utl):
            return T.ttm(xl, utl, mode)
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(_spec_for(ndim, shard_mode, axis), P()),
            out_specs=_spec_for(ndim, shard_mode, axis),
        )(x, ut)
    return run


def pick_shard_mode(shape: tuple[int, ...], exclude: int, n_shards: int) -> int | None:
    """Largest mode ≠ ``exclude`` divisible by the shard count; None → the
    (shrunk) tensor no longer shards evenly and is cheap enough to replicate
    — st-HOSVD's sequential shrinking makes the late modes tiny."""
    order = sorted(range(len(shape)), key=lambda m: -shape[m])
    for m in order:
        if m != exclude and shape[m] % n_shards == 0:
            return m
    return None


def sthosvd_distributed(
    x: jax.Array,
    ranks,
    mesh: Mesh,
    *,
    axis: str = "data",
    methods: str = "eig",
    als_iters: int = DEFAULT_ALS_ITERS,
) -> SthosvdResult:
    """Distributed flexible st-HOSVD.  ``methods``: 'eig' | 'als' | 'auto'.

    'eig' runs the explicit shard_map schedule above.  'als'/'auto' route the
    per-mode solve through GSPMD-sharded jit (collectives inserted by XLA);
    'auto' consults the adaptive selector per mode exactly as the
    single-device path does.
    """
    from .solvers import als_solve
    from .selector import default_selector

    n = x.ndim
    ranks = tuple(int(r) for r in ranks)
    n_shards = mesh.shape[axis]
    selector = default_selector() if methods == "auto" else None

    y = x
    factors: list[jax.Array | None] = [None] * n
    trace: list[ModeTrace] = []

    for mode in range(n):
        i_n, r_n = y.shape[mode], ranks[mode]
        j_n = y.size // i_n
        shard_mode = pick_shard_mode(y.shape, mode, n_shards)
        y = _shard(y, mesh, shard_mode, axis)

        if methods == "auto":
            method = selector(i_n=i_n, r_n=r_n, j_n=j_n)
        else:
            method = methods

        if shard_mode is None:
            # replicated fallback: tensor already shrunk below shardability
            from .solvers import SOLVERS
            if method == "als":
                res = SOLVERS["als"](y, mode, r_n, num_iters=als_iters)
            else:
                res = SOLVERS["eig"](y, mode, r_n)
            u, y = res.u, res.y_new
        elif method == "eig":
            s = _gram_psum(mesh, axis, n, mode, shard_mode)(y)
            _, vecs = jnp.linalg.eigh(s)
            u = vecs[:, -r_n:][:, ::-1].astype(y.dtype)
            y = _ttm_local(mesh, axis, n, mode, shard_mode)(y, u.T)
        elif method == "als":
            in_sh = NamedSharding(mesh, _spec_for(n, shard_mode, axis))
            out_sh = (NamedSharding(mesh, P()),
                      NamedSharding(mesh, _spec_for(n, shard_mode, axis)))
            solve = jax.jit(
                lambda yy: tuple(als_solve(yy, mode, r_n, num_iters=als_iters)),
                in_shardings=in_sh, out_shardings=out_sh)
            u, y = solve(y)
        else:
            raise ValueError(f"unknown distributed method {method!r}")

        factors[mode] = u
        trace.append(ModeTrace(mode, method, i_n, r_n, j_n, 0.0))

    return SthosvdResult(TuckerTensor(core=y, factors=factors), trace=trace)
