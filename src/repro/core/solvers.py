"""Per-mode factor/core solvers for the flexible st-HOSVD (a-Tucker Sec. III).

Each solver consumes the current (partially shrunk) tensor ``y`` and a mode,
and returns ``(U, y_new)`` where ``U`` (I_n × R_n) has orthonormal columns
and ``y_new`` is the tensor with mode ``n`` shrunk to R_n:

  EIG  (paper Alg. 2 lines 6–8):  S = Y_(n)Y_(n)^T  → leading eigvecs → TTM.
  ALS  (paper Alg. 2 lines 10–13 + Alg. 3): rank-R_n alternating LS on
       Y_(n) ≈ L R^T, then QR(L) for orthonormality, core = TTM(R-tensor, R̂).
  SVD  (paper Alg. 1; baseline only — always slowest, kept for Fig. 2).
  RAND (randomized range finder / sketched Gram, Minster–Saibaba–Kilmer
       [1905.07311]): Y_(n) Ω for a Gaussian test tensor Ω with
       ℓ = R_n + oversample columns → QR → optional power iterations →
       Rayleigh–Ritz rotation of the ℓ-dim sketch basis (an eig step on the
       ℓ×ℓ sketched Gram) truncated to R_n.  Cheap when R_n ≪ I_n: the
       I_n²·J_n Gram is replaced by O(I_n·ℓ·J_n) sketch contractions, all
       expressed through the same TTM/TTT/Gram backend primitives (no
       matricization).  Its singular-value tail is what rank-adaptive
       (``error_target``) plans read the per-mode rank off — see
       :func:`rand_sketch` and :meth:`repro.core.api.TuckerPlan.resolve_ranks`.

Everything is matricization-free (built on whichever registered
:mod:`repro.core.backend` supplies TTM/TTT/Gram); ``impl`` names an ops
backend — ``matfree`` (jnp contractions), ``explicit`` (unfold-based
baseline for the Fig. 8 comparison), ``pallas`` (hand-written TPU kernels),
or any custom-registered name.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import tensor_ops as T
from .backend import backend_ops, get_backend

DEFAULT_ALS_ITERS = 5  # paper Sec. III-B default


class SolveResult(NamedTuple):
    u: jax.Array       # (I_n, R_n) orthonormal factor
    y_new: jax.Array   # tensor with mode shrunk to R_n


# ---------------------------------------------------------------------------
# EIG solver
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "rank", "impl"))
def eig_solve(y: jax.Array, mode: int, rank: int, *, impl: str = "matfree") -> SolveResult:
    ttm, gram, _ = backend_ops(impl)
    s = gram(y, mode)                                   # (I_n, I_n), fp32+ accum
    _, vecs = jnp.linalg.eigh(s.astype(jnp.promote_types(s.dtype, jnp.float32)))
    u = vecs[:, -rank:][:, ::-1].astype(y.dtype)        # leading R_n eigvecs
    y_new = ttm(y, u.T, mode)                           # core update
    return SolveResult(u, y_new)


# ---------------------------------------------------------------------------
# ALS solver (Alg. 3)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "rank", "num_iters", "impl"))
def als_solve(y: jax.Array, mode: int, rank: int, *,
              num_iters: int = DEFAULT_ALS_ITERS,
              seed: int = 0,
              impl: str = "matfree") -> SolveResult:
    if num_iters < 1:
        # the loop must run at least once: the R-tensor carry is only
        # written inside the body (zero iterations would return a zero core)
        raise ValueError(f"als_solve needs num_iters >= 1, got {num_iters}")
    ttm, gram, ttt = backend_ops(impl)
    i_n = y.shape[mode]
    # sub-fp32 inputs (bf16/fp16) iterate in fp32 (the peak_bytes model in
    # plan.py assumes exactly this); fp32/fp64 keep their own precision
    cdtype = jnp.promote_types(y.dtype, jnp.float32)
    key = jax.random.PRNGKey(seed)
    l0 = jax.random.normal(key, (i_n, rank), dtype=cdtype)

    yc = y.astype(cdtype)

    def body(_, carry):
        l, _ = carry
        # R_k ← (Y_(n)^T L)(L^T L)^{-1}; tensorized: R-tensor = TTM(y, L^T, n) ×_n (LᵀL)^{-1}
        r_t = ttm(yc, l.T, mode)
        ltl = jnp.dot(l.T, l, precision=jax.lax.Precision.HIGHEST)
        r_t = ttm(r_t, _spd_inverse(ltl), mode)
        # L_{k+1} ← (Y_(n) R)(RᵀR)^{-1};  Y_(n) R = TTT(y, R-tensor, n)
        yr = ttt(yc, r_t, mode)                          # (I_n, R_n)
        rtr = gram(r_t, mode)                            # (R_n, R_n)
        l_new = jnp.dot(yr, _spd_inverse(rtr),
                        precision=jax.lax.Precision.HIGHEST)
        return l_new, r_t

    # carrying the R-tensor out of the loop skips the closing "recompute R
    # for the final L" (one extra TTM + Cholesky solve per solve): the loop
    # exits with (L_k, R_{k-1}), a consistent ALS pair — L_k is the exact LS
    # optimum FOR R_{k-1} — so the sweep ends on an L-update instead of
    # paying an extra R-update of negligible accuracy benefit.
    r_shape = y.shape[:mode] + (rank,) + y.shape[mode + 1:]
    l, r_t = jax.lax.fori_loop(
        0, num_iters, body, (l0, jnp.zeros(r_shape, cdtype)))
    # orthonormalize:  L = Q̂ R̂,  U ← Q̂,  core ← TTM(R-tensor, R̂)
    q, rhat = jnp.linalg.qr(l)
    y_new = ttm(r_t, rhat, mode).astype(y.dtype)
    return SolveResult(q.astype(y.dtype), y_new)


#: escalating relative re-regularization ladder: the baseline 1e-12·tr(A)
#: jitter first (bitwise-identical to the historical behaviour whenever it
#: succeeds), then two stronger rungs for genuinely ill-conditioned Grams
_SPD_JITTERS = (1e-12, 1e-8, 1e-4)


def _spd_inverse(a: jax.Array) -> jax.Array:
    """Inverse of a small SPD matrix via Cholesky (paper uses explicit inverse;
    Cholesky is the numerically robust equivalent at identical O(R³) cost).

    Cholesky breakdown on a rank-deficient/ill-conditioned Gram (which XLA
    reports as NaNs, not an exception) is detected in-jit and retried with
    escalating jitter; the last rung adds an absolute floor so even an
    exactly-zero Gram yields a finite (pseudo-)inverse instead of poisoning
    the whole sweep.  Because selection is by ``jnp.where`` on the FIRST
    finite factorization, well-posed solves keep their historical bitwise
    results."""
    eye = jnp.eye(a.shape[0], dtype=a.dtype)
    scale = jnp.trace(a)
    inv = jnp.full_like(a, jnp.nan)
    for i, jitter in enumerate(_SPD_JITTERS):
        reg = jitter * scale
        if i == len(_SPD_JITTERS) - 1:
            reg = reg + jnp.asarray(1e-6, a.dtype)   # absolute floor
        c = jax.scipy.linalg.cho_factor(a + reg * eye)
        cand = jax.scipy.linalg.cho_solve(c, eye)
        ok = jnp.all(jnp.isfinite(inv))
        inv = jnp.where(ok, inv, cand)
    return inv


# ---------------------------------------------------------------------------
# SVD solver (original st-HOSVD; baseline)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "rank", "impl"))
def svd_solve(y: jax.Array, mode: int, rank: int, *, impl: str = "matfree") -> SolveResult:
    """SVD mode solve (paper Alg. 1 line 3): thin SVD of the unfolding.

    The SVD solver *inherently* matricizes — the decomposition is defined on
    the explicit I_n×J_n unfolding, so no backend can supply a
    matricization-free version (this is why ``OpsBackend.matricizes`` is a
    backend property but SVD steps pay the unfold copy on every backend).
    ``impl`` is still validated against the registry so unknown backends are
    rejected here exactly as in the EIG/ALS solvers, instead of being
    silently accepted.
    """
    get_backend(impl)  # reject unknown backends; ops themselves unused
    y2 = T.unfold(y, mode)
    cdtype = jnp.promote_types(y.dtype, jnp.float32)
    u, s, vt = jnp.linalg.svd(y2.astype(cdtype), full_matrices=False)
    u = u[:, :rank]
    core2 = s[:rank, None] * vt[:rank]                  # Σ V^T
    out_shape = y.shape[:mode] + (rank,) + y.shape[mode + 1:]
    return SolveResult(u.astype(y.dtype), T.fold(core2, mode, out_shape).astype(y.dtype))


# ---------------------------------------------------------------------------
# RAND solver (randomized range finder, Minster–Saibaba–Kilmer 1905.07311)
# ---------------------------------------------------------------------------

DEFAULT_OVERSAMPLE = 8   # ℓ = R_n + oversample sketch columns
DEFAULT_POWER_ITERS = 1  # subspace iterations sharpening the sketch basis


@partial(jax.jit, static_argnames=("mode", "width", "power_iters", "seed", "impl"))
def rand_sketch(y: jax.Array, mode: int, width: int, *,
                power_iters: int = DEFAULT_POWER_ITERS,
                seed: int = 0,
                impl: str = "matfree"):
    """One-shot mode sketch: everything a rank decision needs, in one pass.

    Draws a Gaussian test tensor Ω (mode ``mode`` sized ``width`` = ℓ),
    forms the range sample ``Y_(n) Ω_(n)^T`` via the backend TTT kernel
    (never materializing an unfolding), orthonormalizes it, optionally
    runs ``power_iters`` subspace iterations (TTM project → TTT expand →
    QR), and Rayleigh–Ritz diagonalizes the ℓ×ℓ sketched Gram.

    Returns ``(q, b, evals, vecs, energy)``:

    - ``q``      (I_n, ℓ)  orthonormal sketch basis,
    - ``b``      tensor with mode shrunk to ℓ: ``TTM(y, qᵀ, mode)``,
    - ``evals``  (ℓ,) ascending eigenvalues of ``Gram(b, mode)`` — the
      squared sketched singular values of the unfolding,
    - ``vecs``   (ℓ, ℓ) matching eigenvectors,
    - ``energy`` scalar ``||y||_F²``.

    The captured energy of a rank-r truncation of this basis is exactly
    ``sum(evals[-r:])``, so the *actual* discarded energy at rank r is
    ``energy - sum(evals[-r:])`` — an exact tail for the factor that will
    really be used, which is what makes the per-mode HOSVD error budget
    check in rank-adaptive execution a guarantee rather than an estimate.
    """
    ttm, gram, ttt = backend_ops(impl)
    cdtype = jnp.promote_types(y.dtype, jnp.float32)
    yc = y.astype(cdtype)
    energy = jnp.sum(jnp.square(yc))
    w_shape = y.shape[:mode] + (width,) + y.shape[mode + 1:]
    w = jax.random.normal(jax.random.PRNGKey(seed), w_shape, dtype=cdtype)
    ym = ttt(yc, w, mode)                                # (I_n, ℓ) range sample
    q, _ = jnp.linalg.qr(ym)
    for _ in range(power_iters):
        b = ttm(yc, q.T, mode)                           # project: mode → ℓ
        ym = ttt(yc, b, mode)                            # expand: Y_(n)Y_(n)ᵀ Q
        q, _ = jnp.linalg.qr(ym)
    b = ttm(yc, q.T, mode)
    gb = gram(b, mode)                                   # (ℓ, ℓ) sketched Gram
    evals, vecs = jnp.linalg.eigh(gb.astype(jnp.promote_types(gb.dtype, jnp.float32)))
    return q, b, evals, vecs, energy


@partial(jax.jit, static_argnames=("mode", "rank", "oversample", "power_iters",
                                   "seed", "impl"))
def rand_solve(y: jax.Array, mode: int, rank: int, *,
               oversample: int = DEFAULT_OVERSAMPLE,
               power_iters: int = DEFAULT_POWER_ITERS,
               seed: int = 0,
               impl: str = "matfree") -> SolveResult:
    """Randomized mode solve: sketch at width ℓ = rank + oversample, then the
    existing eig machinery refines within the sketch — the Rayleigh–Ritz
    rotation *is* an eig step on the ℓ×ℓ sketched Gram, truncated to R_n."""
    width = min(y.shape[mode], rank + oversample)
    q, b, _, vecs, _ = rand_sketch(
        y, mode, width, power_iters=power_iters, seed=seed, impl=impl)
    v = vecs[:, -rank:][:, ::-1].astype(q.dtype)         # leading R_n Ritz vecs
    ttm, _, _ = backend_ops(impl)
    u = jnp.dot(q, v, precision=jax.lax.Precision.HIGHEST)
    y_new = ttm(b, v.T, mode)                            # rotate core: ℓ → R_n
    return SolveResult(u.astype(y.dtype), y_new.astype(y.dtype))


SOLVERS = {"eig": eig_solve, "als": als_solve, "svd": svd_solve, "rand": rand_solve}
EIG, ALS, SVD, RAND = "eig", "als", "svd", "rand"
