"""Matricization-free dense tensor operations (a-Tucker, Sec. V).

The paper's insight: TTM / TTT / Gram on mode ``n`` never need an explicit
unfold.  Split the loop nest into (outer, along, inner) the target mode and
merge outer/inner — the computation becomes a single GEMM when ``n`` is the
first or last mode and a batched GEMM for interior modes (paper Fig. 4).

In C-order (row-major) JAX the *last* axis is contiguous, so the roles of
"first" and "last" are mirrored w.r.t. the paper's column-major layout; the
structure is identical.  ``jnp.reshape`` that only merges adjacent axes is
free (no data movement), so the 3-way view ``(A, I_n, B)`` below costs
nothing; the contraction then runs directly on native storage.

``*_explicit`` variants materialize the mode-n unfolding first (moveaxis →
copy → GEMM → fold) and exist as the paper's explicit-matricization baseline
(Fig. 8 benchmark).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Shape helpers
# ---------------------------------------------------------------------------

def split_dims(shape: tuple[int, ...], mode: int) -> tuple[int, int, int]:
    """Return (A, I_n, B): dims merged before / along / after ``mode``."""
    a = math.prod(shape[:mode]) if mode > 0 else 1
    b = math.prod(shape[mode + 1:]) if mode < len(shape) - 1 else 1
    return a, shape[mode], b


def _as3(x: jax.Array, mode: int) -> jax.Array:
    """Free (adjacent-merge) reshape to the (A, I_n, B) view."""
    a, i, b = split_dims(x.shape, mode)
    return x.reshape(a, i, b)


# ---------------------------------------------------------------------------
# Matricization-free ops
# ---------------------------------------------------------------------------

def ttm(x: jax.Array, u: jax.Array, mode: int, *,
        precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """Mode-``mode`` tensor-times-matrix:  Y = X ×_mode U,  U: (R, I_mode).

    Matricization-free: contracts directly on the (A, I_n, B) view.
    mode == 0      → one GEMM   (R,I) @ (I, B)        -> (R, B)
    mode == N-1    → one GEMM   (A, I) @ (I, R)       -> (A, R)
    interior       → batched GEMM over A: (R,I)@(I,B) -> (A, R, B)
    """
    if u.ndim != 2 or u.shape[1] != x.shape[mode]:
        raise ValueError(f"ttm: U {u.shape} incompatible with mode {mode} of {x.shape}")
    r = u.shape[0]
    out_shape = x.shape[:mode] + (r,) + x.shape[mode + 1:]
    n = x.ndim
    if mode == 0:
        x2 = x.reshape(x.shape[0], -1)
        y = jnp.dot(u, x2, precision=precision)
    elif mode == n - 1:
        x2 = x.reshape(-1, x.shape[-1])
        y = jnp.dot(x2, u.T, precision=precision)
    else:
        x3 = _as3(x, mode)
        # einsum 'anb,rn->arb' — XLA lowers to a batched GEMM; no unfold copy.
        y = jnp.einsum("anb,rn->arb", x3, u, precision=precision)
    return y.reshape(out_shape)


def ttm_chain(x: jax.Array, us: dict[int, jax.Array] | list, *,
              precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """Apply TTMs on several distinct modes (order-independent result)."""
    items = us.items() if isinstance(us, dict) else enumerate(us)
    y = x
    for mode, u in items:
        if u is not None:
            y = ttm(y, u, mode, precision=precision)
    return y


def gram(x: jax.Array, mode: int, *,
         precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """S = Y_(n) Y_(n)^T  (I_n × I_n) without forming Y_(n).

    Special case of TTT with both inputs equal (paper Sec. V).  Contracts the
    merged outer and inner axes directly: einsum 'anb,amb->nm'.
    """
    x3 = _as3(x, mode)
    return jax.lax.dot_general(
        x3, x3,
        dimension_numbers=(((0, 2), (0, 2)), ((), ())),
        precision=precision,
        preferred_element_type=jnp.float32 if x.dtype != jnp.float64 else None,
    ).astype(jnp.promote_types(x.dtype, jnp.float32))


def ttt(x: jax.Array, y: jax.Array, mode: int, *,
        precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """Mode-(I,J) product contracting every mode except ``mode``.

    x: (I_1..I_n..I_N), y: (I_1..R_n..I_N) with all non-``mode`` dims equal.
    Returns Z (I_n × R_n):  z[i,r] = Σ_other x[..i..] y[..r..].
    """
    if x.ndim != y.ndim:
        raise ValueError("ttt: rank mismatch")
    for m in range(x.ndim):
        if m != mode and x.shape[m] != y.shape[m]:
            raise ValueError(f"ttt: common mode {m} differs: {x.shape} vs {y.shape}")
    x3 = _as3(x, mode)
    y3 = _as3(y, mode)
    return jax.lax.dot_general(
        x3, y3,
        dimension_numbers=(((0, 2), (0, 2)), ((), ())),
        precision=precision,
        preferred_element_type=jnp.float32 if x.dtype != jnp.float64 else None,
    ).astype(jnp.promote_types(x.dtype, jnp.float32))


# ---------------------------------------------------------------------------
# Explicit-matricization baseline (paper Fig. 3 workflow; used by Fig. 8)
# ---------------------------------------------------------------------------

def unfold(x: jax.Array, mode: int) -> jax.Array:
    """Mode-n matricization Y_(n) (I_n × J_n).  Materializes a copy."""
    return jnp.moveaxis(x, mode, 0).reshape(x.shape[mode], -1)


def fold(mat: jax.Array, mode: int, shape: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`unfold` for a tensor of target ``shape``."""
    full = (shape[mode],) + shape[:mode] + shape[mode + 1:]
    return jnp.moveaxis(mat.reshape(full), 0, mode)


def ttm_explicit(x: jax.Array, u: jax.Array, mode: int, *,
                 precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """TTM via explicit matricization: unfold → GEMM → fold."""
    y2 = jnp.dot(u, unfold(x, mode), precision=precision)
    out_shape = x.shape[:mode] + (u.shape[0],) + x.shape[mode + 1:]
    return fold(y2, mode, out_shape)


def gram_explicit(x: jax.Array, mode: int, *,
                  precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    y2 = unfold(x, mode)
    return jnp.dot(y2, y2.T, precision=precision)


def ttt_explicit(x: jax.Array, y: jax.Array, mode: int, *,
                 precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    return jnp.dot(unfold(x, mode), unfold(y, mode).T, precision=precision)


# ---------------------------------------------------------------------------
# Norms / reconstruction
# ---------------------------------------------------------------------------

def fro_norm(x: jax.Array) -> jax.Array:
    xf = x.reshape(-1)
    return jnp.sqrt(jnp.dot(xf, xf, precision=jax.lax.Precision.HIGHEST))


def reconstruct(core: jax.Array, factors: list[jax.Array]) -> jax.Array:
    """X̂ = G ×_1 U^(1) ··· ×_N U^(N).  factors[n]: (I_n, R_n)."""
    y = core
    for mode, u in enumerate(factors):
        y = ttm(y, u, mode)  # u is (I_n, R_n): contracts R_n, expands to I_n
    return y


def rel_error(x: jax.Array, core: jax.Array, factors: list[jax.Array]) -> jax.Array:
    """‖X − X̂‖_F / ‖X‖_F (paper Table III metric)."""
    return fro_norm(x - reconstruct(core, factors)) / fro_norm(x)
