"""Uniform per-architecture API: init / loss / prefill / decode / input_specs.

``build(cfg)`` returns a ModelBundle whose entry points close over the
config; ``input_specs`` produces weak-type-correct ShapeDtypeStructs for
every step input so the multi-pod dry-run lowers without allocating.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, lm
from .config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, Any], tuple[jax.Array, dict]]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_cache: Callable[..., Any]

    def abstract_params(self, seed: int = 0):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(seed)))


def build(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "encdec":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            loss=lambda p, b: encdec.encdec_loss(p, cfg, b),
            prefill=lambda p, b, cache: encdec.encdec_prefill(
                p, cfg, b["frames"], b["tokens"], cache),
            decode=lambda p, tok, cache, pos, total=None: encdec.encdec_decode_step(
                p, cfg, tok, cache, pos),
            init_cache=lambda batch, seq: encdec.init_cache(
                cfg, batch, seq, seq),
        )

    ring = lambda seq: lm.cache_len(cfg, seq) < seq

    return ModelBundle(
        cfg=cfg,
        init=lambda key: lm.init_params(key, cfg),
        loss=lambda p, b: lm.lm_loss(p, cfg, b),
        prefill=lambda p, b, cache: lm.prefill(
            p, cfg, b["tokens"], cache, patches=b.get("patches"),
            ring=ring(b["tokens"].shape[1])),
        decode=lambda p, tok, cache, pos, total=None: lm.decode_step(
            p, cfg, tok, cache, pos,
            ring=(ring(total) if total is not None else False)),
        init_cache=lambda batch, seq: lm.init_cache(cfg, batch, seq),
    )


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    train  → {'batch': {tokens[, patches, frames]}}
    prefill→ {'batch': …, 'cache': zeroed layout}
    decode → {'token', 'cache', 'pos'}
    """
    b = batch_override or shape.global_batch
    t = shape.seq_len
    d = cfg.d_model

    if shape.kind == "train":
        batch: dict[str, Any] = {"tokens": _sds((b, t + 1), jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = _sds((b, cfg.n_patches, d), jnp.float32)
        if cfg.family == "encdec":
            batch = {"frames": _sds((b, t, d), jnp.float32),
                     "tokens": _sds((b, t + 1), jnp.int32)}
        return {"batch": batch}

    bundle_cache = cache_specs(cfg, b, t)
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, t), jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = _sds((b, cfg.n_patches, d), jnp.float32)
        if cfg.family == "encdec":
            batch = {"frames": _sds((b, t, d), jnp.float32),
                     "tokens": _sds((b, t), jnp.int32)}
        return {"batch": batch, "cache": bundle_cache}

    # decode
    return {"token": _sds((b, 1), jnp.int32),
            "cache": bundle_cache,
            "pos": _sds((), jnp.int32)}


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    if cfg.family == "encdec":
        zeros = encdec.init_cache
        return jax.eval_shape(lambda: zeros(cfg, batch, seq, seq))
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq))
