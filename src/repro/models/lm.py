"""Decoder-only LM assembly (dense / moe / ssm / hybrid / vlm families).

Layer stack is a single ``lax.scan`` over stacked per-layer params (compact
HLO, fast 512-device compile).  Heterogeneous attention patterns (gemma
local:global) are expressed as *traced per-layer scalars* — effective window
and rope theta ride through the scan as xs, so one attention code path
serves every layer and no ``switch`` branches multiply the HLO.  zamba2's
shared attention block (one param set, many sites) is a ``lax.cond`` on a
per-layer site flag with the shared params closed over.

Big-vocab safety: logits are only materialized inside the loss (sharded over
the model axis); ``forward_hidden`` returns hidden states.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .config import ATTN_LOCAL, ModelConfig
from .layers import (attn_apply, attn_init, dense_init, mlp_apply, mlp_init,
                     norm_apply, norm_init)
from .moe import moe_apply, moe_init
from .ssm import mamba1_apply, mamba1_init, mamba2_apply, mamba2_init
from . import shardings

BIG_WINDOW = 1 << 30


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelConfig):
    dtype = _dt(cfg)
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        p["norm_ssm"] = norm_init(cfg)
        p["ssm"] = (mamba1_init if cfg.ssm_version == 1 else mamba2_init)(ks[0], cfg, dtype)
        return p
    p["norm_attn"] = norm_init(cfg)
    p["attn"] = attn_init(ks[0], cfg, dtype)
    p["norm_mlp"] = norm_init(cfg)
    if cfg.n_experts:
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg, dtype)
    if cfg.post_norm:
        p["post_attn"] = norm_init(cfg)
        p["post_mlp"] = norm_init(cfg)
    return p


def init_params(key, cfg: ModelConfig):
    dtype = _dt(cfg)
    k_embed, k_layers, k_shared, k_head, k_vis = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "embed": dense_init(k_embed, (cfg.vocab_padded, cfg.d_model),
                            scale=cfg.d_model ** -0.5, dtype=dtype),
        "final_norm": norm_init(cfg),
    }
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        params["shared"] = {
            "norm_attn": norm_init(cfg),
            "attn": attn_init(k_shared, cfg, dtype),
            "norm_mlp": norm_init(cfg),
            "mlp": mlp_init(k_head, cfg, dtype),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_padded),
                                       dtype=dtype)
    if cfg.family == "vlm" and cfg.n_patches:
        params["vis_proj"] = dense_init(k_vis, (cfg.d_model, cfg.d_model), dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# per-layer static schedules (traced through scan as xs)
# ---------------------------------------------------------------------------

def layer_schedule(cfg: ModelConfig):
    kinds = cfg.layer_kinds()
    window = jnp.array(
        [cfg.sliding_window if k == ATTN_LOCAL else BIG_WINDOW for k in kinds],
        jnp.int32)
    theta = jnp.array(
        [cfg.rope_theta if (k == ATTN_LOCAL or cfg.rope_theta_global is None)
         else cfg.rope_theta_global for k in kinds], jnp.float32)
    sites = jnp.array(cfg.shared_attn_sites(), jnp.int32)
    return {"window": window, "theta": theta, "site": sites}


# ---------------------------------------------------------------------------
# transformer block bodies
# ---------------------------------------------------------------------------

def _attn_block(lp, h, cfg, *, positions, window, theta, cache=None,
                cache_pos=None, ring=False):
    x = norm_apply(lp["norm_attn"], h, cfg)
    out, new_cache = attn_apply(
        lp["attn"], x, cfg, positions=positions, kind="win",
        cache=cache, cache_pos=cache_pos, window=window, theta=theta, ring=ring)
    if cfg.post_norm:
        out = norm_apply(lp["post_attn"], out, cfg)
    return h + out, new_cache


def _mlp_block(lp, h, cfg):
    x = norm_apply(lp["norm_mlp"], h, cfg)
    if cfg.n_experts:
        out, aux = moe_apply(lp["moe"], x, cfg)
    else:
        out, aux = mlp_apply(lp["mlp"], x, cfg), jnp.zeros((), jnp.float32)
    if cfg.post_norm:
        out = norm_apply(lp["post_mlp"], out, cfg)
    return h + out, aux


def _ssm_block(lp, h, cfg, *, cache=None):
    x = norm_apply(lp["norm_ssm"], h, cfg)
    apply = mamba1_apply if cfg.ssm_version == 1 else mamba2_apply
    out, new_cache = apply(lp["ssm"], x, cfg, cache=cache)
    return h + out, new_cache


def _shared_attn_block(sp, h, cfg, *, positions, cache, cache_pos):
    x = norm_apply(sp["norm_attn"], h, cfg)
    out, new_cache = attn_apply(
        sp["attn"], x, cfg, positions=positions, kind="win",
        cache=cache, cache_pos=cache_pos, ring=False,
        window=jnp.int32(BIG_WINDOW), theta=jnp.float32(cfg.rope_theta))
    h = h + out
    x = norm_apply(sp["norm_mlp"], h, cfg)
    return h + mlp_apply(sp["mlp"], x, cfg), new_cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, patches=None):
    h = params["embed"][tokens].astype(_dt(cfg))
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    if cfg.family == "vlm" and patches is not None:
        vis = patches.astype(_dt(cfg)) @ params["vis_proj"]
        h = jnp.concatenate([vis, h], axis=1)
    return h


def forward_hidden(params, cfg: ModelConfig, tokens, *, patches=None,
                   positions=None, cache=None, cache_pos=None, ring=False):
    """Run the stack.  Returns (hidden (B,T,d), new_cache, aux_loss).

    ``ring``: static — the KV cache is a ring buffer shorter than the total
    context (pure sliding-window models); slot indices then aren't absolute
    positions and the window mask is implied by residency.
    """
    h = embed_tokens(params, cfg, tokens, patches)
    h = shardings.constrain_batch(h)
    b, t, _ = h.shape
    if positions is None:
        if cache_pos is not None:
            cp = jnp.asarray(cache_pos, jnp.int32)
            positions = (jnp.broadcast_to(cp.reshape(-1, 1), (b, t))
                         if cp.ndim == 1 else jnp.full((b, t), cp, jnp.int32))
        else:
            positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    sched = layer_schedule(cfg)
    shared = params.get("shared")

    def body(carry, xs):
        h, aux = carry
        lp, sch, lcache = xs
        if cfg.seq_shard_residual and t > 1:
            # sequence-parallel residual stream: T-sharded between blocks
            h = shardings.constrain(h, (("pod", "data"), "model", None))
        new_cache = lcache
        if cfg.family in ("ssm", "hybrid"):
            h, c = _ssm_block(lp, h, cfg, cache=lcache if lcache is None else
                              {"conv": lcache["conv"], "ssm": lcache["ssm"]})
            if lcache is not None:
                new_cache = dict(lcache, conv=c["conv"], ssm=c["ssm"])
            if cfg.family == "hybrid" and shared is not None:
                def with_attn(args):
                    h_, cache_ = args
                    ac = None if lcache is None else {"k": cache_["k"], "v": cache_["v"]}
                    h2, c2 = _shared_attn_block(shared, h_, cfg, positions=positions,
                                                cache=ac, cache_pos=cache_pos)
                    if lcache is None:
                        return h2, cache_
                    return h2, dict(cache_, k=c2["k"], v=c2["v"])

                def without(args):
                    return args

                h, new_cache = jax.lax.cond(sch["site"] == 1, with_attn, without,
                                            (h, new_cache))
        else:
            ac = None if lcache is None else {"k": lcache["k"], "v": lcache["v"]}
            h, c = _attn_block(lp, h, cfg, positions=positions,
                               window=sch["window"], theta=sch["theta"],
                               cache=ac, cache_pos=cache_pos, ring=ring)
            if lcache is not None:
                new_cache = dict(lcache, k=c["k"], v=c["v"])
            h, aux_l = _mlp_block(lp, h, cfg)
            aux = aux + aux_l
        return (h, aux), new_cache

    if cfg.remat:
        body = jax.checkpoint(body)

    xs = (params["layers"], sched, cache)
    (h, aux), new_cache = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), xs)
    h = norm_apply(params["final_norm"], h, cfg)
    return h, (new_cache if cache is not None else None), aux


def logits_from_hidden(params, cfg: ModelConfig, h):
    """Logits over the PADDED vocab (model-axis-shardable); the padded tail
    is masked to -inf so softmax/sampling are exact w.r.t. the true vocab."""
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


# ---------------------------------------------------------------------------
# task-level entry points
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch):
    """batch: {tokens (B, T+1), [patches (B, Np, d)]} → (loss, aux_metrics)."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    h, _, aux = forward_hidden(params, cfg, inputs,
                               patches=batch.get("patches"))
    if cfg.family == "vlm" and batch.get("patches") is not None:
        h = h[:, batch["patches"].shape[1]:]     # loss on text positions only
    logits = logits_from_hidden(params, cfg, h)
    # one-hot contraction instead of take_along_axis: the label logit becomes
    # a reduction over the (model-sharded) vocab dim -> a small psum, never an
    # all-gather of the logits
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_padded, dtype=logits.dtype)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - label_logit
    loss = nll.mean() + aux
    return loss, {"nll": nll.mean(), "aux": aux}


def prefill(params, cfg: ModelConfig, tokens, cache, *, patches=None, ring=False):
    """Full-sequence pass that returns last-position logits + the populated
    decode cache.  ``cache`` supplies the (zeroed) layout to fill."""
    h, new_cache, _ = forward_hidden(params, cfg, tokens, patches=patches,
                                     cache=cache, ring=ring)
    logits = logits_from_hidden(params, cfg, h[:, -1:])
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, token, cache, cache_pos, *, ring=False):
    """One-token serve step.  token: (B, 1) int32; cache: stacked per-layer
    pytree; cache_pos: scalar int32 position of this token."""
    h, new_cache, _ = forward_hidden(params, cfg, token,
                                     cache=cache, cache_pos=cache_pos, ring=ring)
    logits = logits_from_hidden(params, cfg, h)
    return logits, new_cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    kinds = cfg.layer_kinds()
    if cfg.family in ("ssm",):
        return 0
    if cfg.sliding_window is not None and all(k == ATTN_LOCAL for k in kinds) \
            and cfg.family != "hybrid":
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Zeroed stacked decode cache for every layer."""
    dtype = _dt(cfg)
    l = cfg.n_layers
    c: dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        di, n = cfg.d_inner, cfg.ssm_state
        c["conv"] = jnp.zeros((l, batch, cfg.ssm_conv - 1, di), dtype)
        if cfg.ssm_version == 1:
            c["ssm"] = jnp.zeros((l, batch, di, n), jnp.float32)
        else:
            c["ssm"] = jnp.zeros((l, batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                                 jnp.float32)
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            s = cache_len(cfg, seq_len)
            c["k"] = jnp.zeros((l, batch, s, cfg.n_kv_heads, cfg.hd), dtype)
            c["v"] = jnp.zeros((l, batch, s, cfg.n_kv_heads, cfg.hd), dtype)
    else:
        s = cache_len(cfg, seq_len)
        c["k"] = jnp.zeros((l, batch, s, cfg.n_kv_heads, cfg.hd), dtype)
        c["v"] = jnp.zeros((l, batch, s, cfg.n_kv_heads, cfg.hd), dtype)
    return c
