"""Top-k routed Mixture-of-Experts with sort-based capacity dispatch.

Dispatch is computed PER GROUP (one group per batch row, vmapped) so the
argsort and the (E, C, d) staging buffers stay sharded over the data axes —
no global token sort, no global-capacity buffers (the standard GShard
grouping, with megablocks-style gather instead of the (T, E, C) one-hot
einsum whose dispatch tensor dwarfs the expert GEMMs at 32k context).

Expert FFNs are single batched einsums over the expert dim, which shard
cleanly over the model axis (d_ff sharding; expert sharding when E divides).

Aux (load-balance) loss is the Switch formulation: E · Σ_e f_e · p̄_e.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import shardings
from .config import ModelConfig
from .layers import dense_init


def moe_init(key, cfg: ModelConfig, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), scale=1.0 / math.sqrt(d), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype=dtype),
    }


def _dispatch_group(xg, router, e: int, k: int, capacity: int):
    """Per-group routing.  xg: (T, d) → (buf (E, C, d), combine info)."""
    t, d = xg.shape
    logits = xg.astype(jnp.float32) @ router                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # integer-only sort: no float keys ⇒ no (broken-in-this-jaxlib) sort JVP
    flat_e = jax.lax.stop_gradient(top_e.reshape(-1))         # (T*K,) int32
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, stok, sp = flat_e[order], flat_tok[order], flat_p[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(t * k) - starts[se]
    keep = slot < capacity
    dest = jnp.where(keep, se * capacity + slot, e * capacity)

    buf = jnp.zeros((e * capacity + 1, d), xg.dtype).at[dest].set(xg[stok])
    buf = buf[:-1].reshape(e, capacity, d)
    return buf, (dest, stok, sp, keep), (probs, top_e)


def _ffn_combine(p_w, buf, dest, stok, sp, keep, e, capacity, t, d):
    """Expert SwiGLU + scatter-combine.  With ff-sharded weights the output
    is a PARTIAL sum over the ff shard — the caller decides where to reduce."""
    gate = jnp.einsum("gecd,edf->gecf", buf, p_w["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, p_w["w_up"])
    h = jax.nn.silu(gate) * up
    out_buf = jnp.einsum("gecf,efd->gecd", h, p_w["w_down"])
    b = buf.shape[0]
    out_flat = out_buf.reshape(b, e * capacity, d)

    def combine(ob, dest_g, stok_g, sp_g, keep_g):
        contrib = ob[jnp.minimum(dest_g, e * capacity - 1)]
        contrib = contrib * (keep_g * sp_g)[:, None].astype(ob.dtype)
        return jnp.zeros((t, d), ob.dtype).at[stok_g].add(contrib)

    return jax.vmap(combine)(out_flat, dest, stok, sp, keep)


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, T, d) → (out (B, T, d), aux_loss scalar)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = max(4, int(math.ceil(t * k / e * cfg.capacity_factor)))

    disp = jax.vmap(lambda xg: _dispatch_group(xg, p["router"], e, k, capacity))
    buf, (dest, stok, sp, keep), (probs, top_e) = disp(x)     # buf: (B,E,C,d)
    # GSPMD loses the batch sharding through the dispatch scatter — re-pin the
    # group dim so expert GEMMs stay data-parallel (16x redundancy otherwise)
    buf = shardings.constrain_batch(buf)

    # Switch aux loss over all tokens (indices reused from top_k — no float
    # argsort, whose JVP is broken in this jaxlib build)
    frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (b * t * k)
    aux = cfg.router_aux_coef * e * jnp.sum(frac * probs.mean((0, 1)))

    mesh = shardings.activation_mesh()
    if cfg.moe_capacity_sharding:
        # capacity-sharded TP: expert weights replicated, slots split over
        # 'model' — expert GEMMs are local; the scatter/gather at dispatch
        # and combine move token-sized data only
        buf = shardings.constrain(buf, (("pod", "data"), None, "model", None))
        y = _ffn_combine(p, buf, dest, stok, sp, keep, e, capacity, t, d)
    elif cfg.moe_combine_shardmap and mesh is not None and "model" in mesh.shape:
        # explicit collective schedule: expert FFN + combine run manually over
        # the model axis; the psum then moves (T, d) tokens, not (E, C, d)
        # capacity slots (≈ top_k·capacity_factor× fewer bytes)
        from jax.sharding import PartitionSpec as P

        def body(wg, wu, wd, buf_l, dest_l, stok_l, sp_l, keep_l):
            y_part = _ffn_combine({"w_gate": wg, "w_up": wu, "w_down": wd},
                                  buf_l, dest_l, stok_l, sp_l, keep_l,
                                  e, capacity, t, d)
            # f32 psum: this XLA build's AllReducePromotion pass crashes on
            # bf16 all-reduce inside manual collectives
            return jax.lax.psum(y_part.astype(jnp.float32), "model").astype(y_part.dtype)

        y = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(None, None, "model"), P(None, None, "model"),
                      P(None, "model", None), P(), P(), P(), P(), P()),
            out_specs=P(),
            axis_names={"model"}, check_vma=False,
        )(p["w_gate"], p["w_up"], p["w_down"], buf, dest, stok, sp, keep)
    else:
        y = _ffn_combine(p, buf, dest, stok, sp, keep, e, capacity, t, d)

    y = shardings.constrain_batch(y)
    return y, aux
