"""Model configuration for the assigned architecture zoo.

One frozen dataclass covers all 10 families; per-arch files in
``repro/configs/`` instantiate it with the exact published numbers and a
reduced smoke variant.  Layer heterogeneity (gemma local:global, zamba2
shared-attention sites) is expressed as a per-layer kind pattern consumed by
``lax.switch``/``lax.cond`` inside the layer scan, so the stack still
compiles as a single scanned block.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


ATTN_GLOBAL = 0
ATTN_LOCAL = 1   # sliding-window


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm

    # transformer backbone
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int | None = None    # default: d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"              # silu (SwiGLU) | gelu | relu2 (non-gated)
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    post_norm: bool = False        # gemma2/3-style extra post-block norms
    qk_norm: bool = False          # gemma3-style RMSNorm on q/k
    tie_embeddings: bool = True
    embed_scale: bool = False      # gemma-style sqrt(d_model) embedding scale

    # attention pattern
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None   # gemma3: 1e6 on global layers
    sliding_window: int | None = None        # window for local layers
    local_global_pattern: tuple[int, int] = (0, 1)  # (n_local, n_global) per cycle
    attn_softcap: float | None = None        # gemma2: 50.0
    final_softcap: float | None = None       # gemma2: 30.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # perf: explicit shard_map expert-FFN with combine-BEFORE-psum — the
    # model-axis all-reduce then moves (T, d) tokens instead of (E, C, d)
    # capacity slots (~topk·cf× smaller).  Beyond-paper optimization, see
    # EXPERIMENTS.md §Perf.
    moe_combine_shardmap: bool = False
    # perf: shard the capacity dim over the model axis with REPLICATED expert
    # weights — expert GEMMs go fully local; remaining collectives are
    # token-sized (T·d) instead of slot-sized (E·C·d).  EXPERIMENTS.md §Perf.
    moe_capacity_sharding: bool = False
    # perf: expand GQA KV heads to the query-head count before attention so
    # the head dim shards cleanly (partial-score all-reduce otherwise when
    # kv_heads < model axis).  Applicable when n_heads % model_axis == 0.
    # EXPERIMENTS.md §Perf A3.
    gqa_expand_kv: bool = False
    # perf: context parallelism for prefill/train attention — shard the query
    # T dim over 'model' so attention is head-layout-independent and local
    # (the recipe for archs whose head counts don't divide the model axis).
    # EXPERIMENTS.md §Perf A4.
    seq_shard_attn: bool = False
    # perf: Megatron-style sequence parallelism for the residual stream —
    # h between blocks is T-sharded over 'model', so remat-saved layer inputs
    # shrink by the TP degree (AG before qkv / RS after wo replace the ARs at
    # equal wire volume).  EXPERIMENTS.md §Perf B7.
    seq_shard_residual: bool = False

    # SSM (mamba)
    ssm_version: int = 0           # 0 = none, 1 = mamba1/S6, 2 = mamba2/SSD
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # mamba2
    ssm_chunk: int = 64
    # hybrid (zamba2): shared attention block applied every N ssm layers
    shared_attn_every: int = 0

    # encoder-decoder
    enc_layers: int = 0

    # vlm
    n_patches: int = 0

    # numerics / distribution
    dtype: str = "float32"         # params/activations wire dtype
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False             # shard params over the data axes too

    # --- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded so the model axis always shards it
        (multiple of 256 covers any mesh axis ≤ 256 with MXU-aligned tiles).
        Logits over the padded tail are masked in the loss / sampler."""
        pad = 256
        return ((self.vocab + pad - 1) // pad) * pad

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def layer_kinds(self) -> tuple[int, ...]:
        """Per-layer attention kind (ATTN_GLOBAL/ATTN_LOCAL) for the decoder
        stack.  Pattern cycles (n_local, n_global); pure-global when no
        sliding window is configured."""
        if self.sliding_window is None:
            return tuple([ATTN_GLOBAL] * self.n_layers)
        n_local, n_global = self.local_global_pattern
        if n_global == 0:
            return tuple([ATTN_LOCAL] * self.n_layers)
        cycle = [ATTN_LOCAL] * n_local + [ATTN_GLOBAL] * n_global
        return tuple(cycle[i % len(cycle)] for i in range(self.n_layers))

    def shared_attn_sites(self) -> tuple[int, ...]:
        """zamba2: 1 at layers where the shared attention block fires."""
        if self.shared_attn_every <= 0:
            return tuple([0] * self.n_layers)
        return tuple(1 if (i + 1) % self.shared_attn_every == 0 else 0
                     for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        n_attn = self.n_heads * self.hd * d + 2 * self.n_kv_heads * self.hd * d + self.n_heads * self.hd * d
        gated = 3 if self.act == "silu" else 2
        n_mlp = gated * d * ff
        if self.n_experts:
            n_mlp = self.n_experts * gated * d * ff + d * self.n_experts
        n_ssm = 0
        if self.ssm_version:
            di, n = self.d_inner, self.ssm_state
            n_ssm = 2 * d * di + di * self.ssm_conv + di * d
            if self.ssm_version == 1:
                n_ssm += di * n * 2 + di * 2  # B,C proj via x_proj + dt
            else:
                n_ssm += d * 2 * n + self.ssm_heads * 2
        per_layer = n_ssm if self.family in ("ssm",) else n_attn + n_mlp
        if self.family == "hybrid":
            per_layer = n_ssm
        total = self.n_layers * per_layer + v * d
        if self.family == "hybrid" and self.shared_attn_every:
            total += n_attn + n_mlp
        if self.family == "encdec":
            total += self.enc_layers * (n_attn + n_mlp) + self.n_layers * (n_attn + n_mlp // 2)
        if not self.tie_embeddings:
            total += v * d
        return int(total)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatch: int | None = None  # grad-accumulation chunks (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
