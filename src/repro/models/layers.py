"""Transformer layer substrate: norms, RoPE, GQA attention, gated MLPs.

Attention is blockwise (flash-style streaming over KV blocks with running
max/sum carried through a ``lax.scan``) so 32k-prefill never materializes a
(T, S) score matrix; decode takes the single-token path against a (possibly
ring-buffered) KV cache.  Sliding-window, logit softcap (gemma2), qk-norm
(gemma3) and local:global layer kinds are all mask-/transform-level options
on one implementation.

All params live in plain nested dicts; ``shardings.py`` assigns logical mesh
axes by key-path pattern.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .config import ATTN_LOCAL, ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return out.astype(x.dtype)


def rms_head_norm(x, scale):
    """Per-head RMSNorm (gemma3 qk-norm).  x: (..., D)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (B, T, H, D); positions: (B, T); theta may be a traced scalar."""
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs          # (B, T, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def blockwise_attention(q, k, v, *, mask_fn, block_kv: int = 1024,
                        softcap: float | None = None):
    """Streaming softmax attention.  q: (B,T,Hq,D), k/v: (B,S,Hkv,D).

    ``mask_fn(t_idx, s_idx) -> bool (T_blk, S_blk)`` gives position validity.
    Never materializes (T, S); the KV sweep is a lax.scan carrying running
    (max, sum, acc) — the flash-attention recurrence, XLA-fused on TPU.
    """
    b, t, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, t, hkv, g, d).astype(jnp.float32) / math.sqrt(d)

    n_blocks = (s + block_kv - 1) // block_kv
    s_pad = n_blocks * block_kv
    if s_pad != s:
        k = jnp.pad(k, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, block_kv, hkv, d)
    vb = v.reshape(b, n_blocks, block_kv, hkv, d)

    t_idx = jnp.arange(t)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, sblk = blk                        # (B, bkv, Hkv, D), s offsets
        scores = jnp.einsum("btkgd,bskd->btkgs", qg, kblk.astype(jnp.float32))
        scores = _softcap(scores, softcap)
        valid = mask_fn(t_idx, sblk) & (sblk < s)[None, :]          # (T, bkv)
        scores = jnp.where(valid[None, :, None, None, :], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "btkgs,bskd->btkgd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, t, hkv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, t, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, t, hkv, g, d), jnp.float32)
    s_offsets = (jnp.arange(n_blocks)[:, None] * block_kv
                 + jnp.arange(block_kv)[None, :])                    # (nb, bkv)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), s_offsets))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, t, hq, d)


def attn_apply(p, h, cfg: ModelConfig, *, positions, kind="win", theta=None,
               window=None, cache=None, cache_pos=None, ring=False,
               dtype=None, cross_kv=None):
    """One attention block (no residual / norm — the caller owns those).

    kind: 'win' (causal; ``window`` — a *traced* per-layer scalar — bounds
          the lookback; pass BIG for global) | 'bidir' | 'cross'
    theta: traced rope base (per-layer in local:global models).
    cache: dict {k, v} (B, S_c, Hkv, D).  T==1 → decode (ring write when
           ``ring``); T>1 with cache → prefill (populate cache slots).
    Returns (out (B,T,d), new_cache | None).
    """
    dtype = dtype or h.dtype
    b, t, _ = h.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if theta is None:
        theta = jnp.float32(cfg.rope_theta)

    q = (h @ p["wq"]).reshape(b, t, hq, hd)
    if kind == "cross":
        k, v = cross_kv
    else:
        k = (h @ p["wk"]).reshape(b, t, hkv, hd)
        v = (h @ p["wv"]).reshape(b, t, hkv, hd)

    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        if kind != "cross":
            k = rms_head_norm(k, p["k_norm"])

    if kind not in ("bidir", "cross"):
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    new_cache = None

    if cache is not None and kind != "cross" and t == 1 and cache_pos is not None:
        # ---- decode: write the token into the (ring) cache, attend over it.
        # cache_pos may be a scalar or a per-slot (B,) vector (serve engine).
        s_c = cache["k"].shape[1]
        cp = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (b,))
        slot = cp % s_c if ring else jnp.minimum(cp, s_c - 1)
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        idx = jnp.arange(s_c)
        written = jnp.minimum(cp + 1, s_c)                    # (B,)
        valid = idx[None, :] < written[:, None]               # (B, S)
        if ring:
            full = cp >= s_c
            valid = jnp.where(full[:, None], jnp.ones((b, s_c), bool), valid)
        elif window is not None:
            # linear cache: slot index == absolute position
            valid &= (cp[:, None] - idx[None, :]) < window
        qg = q.reshape(b, 1, hkv, hq // hkv, hd).astype(jnp.float32) / math.sqrt(hd)
        scores = jnp.einsum("btkgd,bskd->btkgs", qg, ck.astype(jnp.float32))
        scores = _softcap(scores, cfg.attn_softcap)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("btkgs,bskd->btkgd", w, cv.astype(jnp.float32))
        out = out.reshape(b, 1, hq * hd)
    else:
        if cache is not None and kind != "cross":
            # ---- prefill: populate cache slots with this sequence's k/v
            s_c = cache["k"].shape[1]
            if s_c >= t:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            else:  # ring: keep the last s_c tokens at their ring slots
                slots = jnp.arange(t - s_c, t) % s_c
                ck = cache["k"].at[:, slots].set(k[:, t - s_c:].astype(cache["k"].dtype))
                cv = cache["v"].at[:, slots].set(v[:, t - s_c:].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}

        if kind in ("bidir", "cross"):
            mask_fn = lambda ti, si: jnp.ones((ti.shape[0], si.shape[0]), bool)
        elif window is not None:
            w_ = window
            mask_fn = lambda ti, si: (si[None, :] <= ti[:, None]) & \
                                     ((ti[:, None] - si[None, :]) < w_)
        else:
            mask_fn = lambda ti, si: si[None, :] <= ti[:, None]

        def mk(ti, sblk):
            return mask_fn(ti, sblk.reshape(-1)).reshape(ti.shape[0], -1)

        if cfg.seq_shard_attn and t > 1:
            from . import shardings
            # context parallelism: queries sharded over 'model' on T; KV
            # replicated over 'model' (GSPMD all-gathers them once per
            # layer — tokens, not scores).  §Perf A4.
            q = shardings.constrain(q, (("pod", "data"), "model", None, None))
            k = shardings.constrain(k, (("pod", "data"), None, None, None))
            v = shardings.constrain(v, (("pod", "data"), None, None, None))
        if cfg.gqa_expand_kv and hq != hkv:
            # GQA-expand: repeat KV to the full query-head count BEFORE the
            # attention contractions.  The (kv, group) split of a sharded
            # fused head dim defeats GSPMD when kv < mesh axis (it reverts to
            # partial-sum scores → a per-KV-block all-reduce); expanded heads
            # shard cleanly and attention stays collective-free.  §Perf A3.
            g = hq // hkv
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        out = blockwise_attention(q, k, v, mask_fn=mk,
                                  softcap=cfg.attn_softcap)
        out = out.reshape(b, t, hq * hd)

    return (out.astype(dtype) @ p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "relu2":
        return {"wi": dense_init(ks[0], (d, ff), dtype=dtype),
                "wo": dense_init(ks[1], (ff, d), dtype=dtype)}
    return {"wi_gate": dense_init(ks[0], (d, ff), dtype=dtype),
            "wi_up": dense_init(ks[1], (d, ff), dtype=dtype),
            "wo": dense_init(ks[2], (ff, d), dtype=dtype)}


def mlp_apply(p, x, cfg: ModelConfig):
    if cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
        return h @ p["wo"]
    gate = x @ p["wi_gate"]
    act = jax.nn.gelu(gate) if cfg.act == "gelu" else jax.nn.silu(gate)
    return (act * (x @ p["wi_up"])) @ p["wo"]
