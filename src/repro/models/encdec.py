"""Encoder-decoder backbone (seamless-m4t-medium assignment).

The modality frontend is a stub per the brief: ``input_specs`` supplies
precomputed frame embeddings (B, T_enc, d_model); this module owns the
transformer encoder (bidirectional), the decoder (causal self-attn +
cross-attn), and the text head.  "12L" is realized as 12 encoder + 12
decoder layers (DESIGN.md §5).

Decode cache = decoder self-attn KV (ring-free) + per-layer cross-attn K/V
precomputed from the encoder memory once at prefill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import attn_apply, attn_init, dense_init, mlp_apply, mlp_init, \
    norm_apply, norm_init
from .lm import BIG_WINDOW, logits_from_hidden


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def enc_layer_init(key, cfg: ModelConfig):
    dtype = _dt(cfg)
    k1, k2 = jax.random.split(key)
    return {"norm_attn": norm_init(cfg), "attn": attn_init(k1, cfg, dtype),
            "norm_mlp": norm_init(cfg), "mlp": mlp_init(k2, cfg, dtype)}


def dec_layer_init(key, cfg: ModelConfig):
    dtype = _dt(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm_self": norm_init(cfg), "self_attn": attn_init(k1, cfg, dtype),
            "norm_cross": norm_init(cfg), "cross_attn": attn_init(k2, cfg, dtype),
            "norm_mlp": norm_init(cfg), "mlp": mlp_init(k3, cfg, dtype)}


def init_params(key, cfg: ModelConfig):
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": dense_init(kt, (cfg.vocab_padded, cfg.d_model),
                            scale=cfg.d_model ** -0.5, dtype=_dt(cfg)),
        "enc_layers": jax.vmap(lambda k: enc_layer_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: dec_layer_init(k, cfg))(dec_keys),
        "enc_norm": norm_init(cfg),
        "final_norm": norm_init(cfg),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T_enc, d) stubbed frontend embeddings → encoder memory."""
    h = frames.astype(_dt(cfg))
    b, t, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(h, lp):
        x = norm_apply(lp["norm_attn"], h, cfg)
        out, _ = attn_apply(lp["attn"], x, cfg, positions=positions, kind="bidir")
        h = h + out
        x = norm_apply(lp["norm_mlp"], h, cfg)
        return h + mlp_apply(lp["mlp"], x, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return norm_apply(params["enc_norm"], h, cfg)


def cross_kv(params, cfg: ModelConfig, memory):
    """Precompute per-decoder-layer cross-attention K/V from the memory."""
    b, s, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd

    def one(lp):
        k = (memory @ lp["cross_attn"]["wk"]).reshape(b, s, hkv, hd)
        v = (memory @ lp["cross_attn"]["wv"]).reshape(b, s, hkv, hd)
        return k, v

    return jax.vmap(one)(params["dec_layers"])   # each (L, B, S, Hkv, D)


def decode_hidden(params, cfg: ModelConfig, tokens, ckv, *, cache=None,
                  cache_pos=None):
    """Decoder stack.  ckv: (cross_k, cross_v) stacked per layer."""
    h = params["embed"][tokens].astype(_dt(cfg))
    b, t, _ = h.shape
    if cache_pos is not None:
        positions = jnp.full((b, t), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(h, xs):
        lp, ck, cv, lcache = xs
        x = norm_apply(lp["norm_self"], h, cfg)
        out, c = attn_apply(lp["self_attn"], x, cfg, positions=positions,
                            kind="win", window=jnp.int32(BIG_WINDOW),
                            cache=lcache, cache_pos=cache_pos)
        h = h + out
        x = norm_apply(lp["norm_cross"], h, cfg)
        out, _ = attn_apply(lp["cross_attn"], x, cfg, positions=positions,
                            kind="cross", cross_kv=(ck, cv))
        h = h + out
        x = norm_apply(lp["norm_mlp"], h, cfg)
        h = h + mlp_apply(lp["mlp"], x, cfg)
        return h, c

    if cfg.remat:
        body = jax.checkpoint(body)
    h, new_cache = jax.lax.scan(
        body, h, (params["dec_layers"], ckv[0], ckv[1], cache))
    h = norm_apply(params["final_norm"], h, cfg)
    return h, (new_cache if cache is not None else None)


def encdec_loss(params, cfg: ModelConfig, batch):
    """batch: {frames (B, T_enc, d), tokens (B, T_dec+1)}."""
    memory = encode(params, cfg, batch["frames"])
    ckv = cross_kv(params, cfg, memory)
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    h, _ = decode_hidden(params, cfg, inputs, ckv)
    logits = logits_from_hidden(params, cfg, h)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_padded, dtype=logits.dtype)
    nll = lse - jnp.sum(logits * onehot, axis=-1)
    return nll.mean(), {"nll": nll.mean(), "aux": jnp.zeros((), jnp.float32)}


def encdec_decode_step(params, cfg: ModelConfig, token, cache, cache_pos):
    """cache: {'k','v' (L,B,S_dec,...), 'ck','cv' (L,B,S_enc,...)}."""
    ckv = (cache["ck"], cache["cv"])
    self_cache = {"k": cache["k"], "v": cache["v"]}
    h, new_self = decode_hidden(params, cfg, token, ckv,
                                cache=self_cache, cache_pos=cache_pos)
    logits = logits_from_hidden(params, cfg, h)
    return logits, dict(cache, k=new_self["k"], v=new_self["v"])


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, cache):
    memory = encode(params, cfg, frames)
    ckv = cross_kv(params, cfg, memory)
    self_cache = {"k": cache["k"], "v": cache["v"]}
    h, new_self = decode_hidden(params, cfg, tokens, ckv, cache=self_cache)
    logits = logits_from_hidden(params, cfg, h[:, -1:])
    return logits, dict(cache, k=new_self["k"], v=new_self["v"],
                        ck=ckv[0], cv=ckv[1])


def init_cache(cfg: ModelConfig, batch: int, dec_len: int, enc_len: int):
    l, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    dt = _dt(cfg)
    return {
        "k": jnp.zeros((l, batch, dec_len, hkv, hd), dt),
        "v": jnp.zeros((l, batch, dec_len, hkv, hd), dt),
        "ck": jnp.zeros((l, batch, enc_len, hkv, hd), dt),
        "cv": jnp.zeros((l, batch, enc_len, hkv, hd), dt),
    }
