"""Param / activation sharding rules with divisibility-checked fallbacks.

MaxText-style logical rules resolved against a concrete mesh:
  * tensor-parallel ('model') axis: vocab dim of embeddings, the d_ff /
    heads output dim of up-projections, the contraction dim of
    down-projections — picked by key-name pattern on the param path.
  * FSDP ('data' axis, optionally 'pod' too) on the largest remaining dim
    for configs flagged ``fsdp`` (the ≥7B archs).
  * every assignment is dropped silently when the dim doesn't divide the
    mesh axis (e.g. gemma3's 4 query heads vs model=16 → that dim stays
    replicated and d_ff carries the TP).

The resolver works on abstract (ShapeDtypeStruct) pytrees so the dry-run
never allocates.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

# (path-regex, dim-from-end, logical-role). First match wins per dim.
# dims are indexed from the END so stacked (leading L / E) axes don't shift
# the rule.
_TP_LAST = ("wq", "wk", "wv", "wi_gate", "wi_up", "wi", "in_proj", "bc_proj",
            "dt_proj", "w_gate", "w_up", "conv_w", "d_skip", "dt_bias")
_TP_SECOND = ("wo", "out_proj", "w_down", "x_proj", "a_log")
_EMBED = ("embed", "lm_head")


def _leaf_spec(path: str, shape: tuple[int, ...], mesh: Mesh, *,
               model_axis: str, fsdp_axes: tuple[str, ...] | None) -> P:
    parts: list[Any] = [None] * len(shape)
    name = path.rsplit("/", 1)[-1]

    def try_assign(dim_from_end: int, axis):
        i = len(shape) - dim_from_end
        if i < 0 or parts[i] is not None:
            return False
        size = _axes_size(mesh, axis)
        if shape[i] % size == 0 and shape[i] >= size:
            parts[i] = axis
            return True
        return False

    if name in _EMBED:
        # (V, d) or (d, V): shard the vocab dim
        vdim = 0 if shape[-2] >= shape[-1] else 1
        try_assign(2 - vdim, model_axis)
    elif name in _TP_LAST:
        try_assign(1, model_axis)
    elif name in _TP_SECOND:
        try_assign(2, model_axis)
    # norms / scalars / router: replicated for TP

    if fsdp_axes:
        # largest remaining dim takes the data axes (zero-redundancy style)
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if parts[i] is None and shape[i] % _axes_size(mesh, fsdp_axes) == 0 \
                    and shape[i] >= _axes_size(mesh, fsdp_axes):
                parts[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break

    return P(*parts)


def _axes_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def param_specs(params_abs: Any, mesh: Mesh, *, model_axis: str = "model",
                fsdp_axes: tuple[str, ...] | None = None,
                replicate_names: tuple[str, ...] = ()) -> Any:
    """PartitionSpec pytree for a (possibly abstract) param pytree.
    ``replicate_names``: leaf names exempted from TP (e.g. expert weights
    under capacity sharding)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abs)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if pstr.rsplit("/", 1)[-1] in replicate_names:
            specs.append(P(*([None] * leaf.ndim)))
            continue
        specs.append(_leaf_spec(pstr, tuple(leaf.shape), mesh,
                                model_axis=model_axis, fsdp_axes=fsdp_axes))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_abs: Any, mesh: Mesh, **kw) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_abs, mesh, **kw))


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_specs(batch_abs: Any, mesh: Mesh, *, batch_axes=("pod", "data")) -> Any:
    """Shard the leading (batch) dim of every input over the data axes; fall
    back to sequence sharding when batch doesn't divide (long-context B=1)."""
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    size = _axes_size(mesh, axes)

    def leaf(x):
        if x.ndim == 0:
            return P()
        if x.shape[0] % size == 0 and x.shape[0] >= size:
            return P(axes)
        if x.ndim >= 2 and x.shape[1] % size == 0:
            return P(None, axes)          # sequence sharding
        return P()

    return jax.tree.map(leaf, batch_abs)


def cache_specs_tree(cache_abs: Any, mesh: Mesh, *, batch_axes=("pod", "data")) -> Any:
    """KV/SSM caches are stacked (L, B, S, ...): shard batch; for B=1
    long-context, shard the sequence dim (ring-attention style residency)."""
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    size = _axes_size(mesh, axes)

    def leaf(x):
        parts = [None] * x.ndim
        if x.ndim >= 2 and x.shape[1] % size == 0 and x.shape[1] >= size:
            parts[1] = axes
        elif x.ndim >= 3 and x.shape[2] % size == 0:
            parts[2] = axes               # sequence dim of (L, B, S, …)
        return P(*parts)

    return jax.tree.map(leaf, cache_abs)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, spec_tree,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# activation-sharding context: model code calls ``constrain_batch`` on its
# (B, T, d) activations; outside a mesh context it's a no-op, so CPU tests
# never notice.  The dry-run / trainer set the axes once per process.
# ---------------------------------------------------------------------------

_ACT_MESH: Mesh | None = None
_ACT_BATCH_AXES: tuple[str, ...] = ("pod", "data")


def set_activation_mesh(mesh: Mesh | None, axes: tuple[str, ...] = ("pod", "data")):
    global _ACT_MESH, _ACT_BATCH_AXES
    _ACT_MESH = mesh
    _ACT_BATCH_AXES = tuple(axes)


def activation_mesh() -> Mesh | None:
    return _ACT_MESH


def constrain_batch(x):
    """Constrain dim 0 of an activation to the data axes (if a mesh was
    registered and the dim divides); identity otherwise — CPU tests never
    notice."""
    if _ACT_MESH is None:
        return x
    axes = tuple(a for a in _ACT_BATCH_AXES if a in _ACT_MESH.shape)
    if not axes:
        return x
    size = 1
    for a in axes:
        size *= _ACT_MESH.shape[a]
    if x.ndim == 0 or x.shape[0] % size or x.shape[0] < size:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(axes)))


def constrain(x, parts: tuple):
    """Constrain ``x`` to PartitionSpec(parts) on the registered mesh, with
    per-dim divisibility fallback (dims that don't divide stay unsharded).
    Axis entries may be tuples of mesh axes (e.g. ("pod", "data"))."""
    if _ACT_MESH is None:
        return x
    resolved = []
    for dim, axis in enumerate(parts):
        if axis is None:
            resolved.append(None)
            continue
        axes = tuple(a for a in (axis if isinstance(axis, tuple) else (axis,))
                     if a in _ACT_MESH.shape)
        if not axes:
            resolved.append(None)
            continue
        size = _axes_size(_ACT_MESH, axes)
        ok = dim < x.ndim and x.shape[dim] % size == 0 and x.shape[dim] >= size
        resolved.append((axes if len(axes) > 1 else axes[0]) if ok else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(*resolved)))
