"""State-space layers: Mamba-1 (S6 selective scan) and Mamba-2 (SSD).

TPU-native formulation: both use a *chunked* scan — quadratic-in-chunk
matmul work (MXU-friendly) inside each chunk, a tiny recurrent carry across
chunks via ``lax.scan``.  This is the hardware adaptation of the CUDA
selective-scan kernels: on TPU the win comes from casting the recurrence as
batched GEMMs over chunks, not from a warp-level scan.

Decode paths carry (conv_state, ssm_state) per layer — O(1) in sequence
length, which is what qualifies the ssm/hybrid archs for the 500k-context
shape.

Simplifications vs reference CUDA impls (documented in DESIGN.md):
  * mamba2: separate x/B/C/dt projections (reference fuses into one in_proj)
    and the short conv is applied to x only; n_groups = 1.
  * dt bias init is constant (softplus-space) rather than log-uniform.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B, T, C), w: (K, C).  Returns (y, new_state)
    where state carries the last K-1 inputs for decode."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba-1 (S6)
# ---------------------------------------------------------------------------

def mamba1_init(key, cfg: ModelConfig, dtype):
    d, di, n, ck = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (ck, di), scale=1.0 / math.sqrt(ck), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * n), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),   # softplus ≈ 0.018
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _s6_scan(x, dt, bmat, cmat, a, chunk: int, h0=None):
    """Chunked S6 scan.
    x, dt: (B, T, Di);  bmat, cmat: (B, T, N);  a: (Di, N) (negative).
    Returns (y (B,T,Di), h_final (B,Di,N))."""
    bsz, t, di = x.shape
    n = bmat.shape[-1]
    nc = (t + chunk - 1) // chunk
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))

    xs = x.reshape(bsz, nc, chunk, di)
    dts = dt.reshape(bsz, nc, chunk, di)
    bs = bmat.reshape(bsz, nc, chunk, n)
    cs = cmat.reshape(bsz, nc, chunk, n)

    def body(h, blk):
        xc, dtc, bc, cc = blk                       # (B, L, Di), (B, L, N)
        # decay exponent per (t, d, n): dt[t,d] * a[d,n]; cumulative over t
        la = dtc[..., None] * a[None, None]                        # (B,L,Di,N)
        cum = jnp.cumsum(la, axis=1)                               # Σ_{τ≤t}
        # contribution of h (chunk entry state): y_h[t] = C_t · (exp(cum_t) ⊙ h)
        decay_in = jnp.exp(cum)                                    # (B,L,Di,N)
        y_h = jnp.einsum("bln,bldn->bld", cc, decay_in * h[:, None])
        # intra-chunk: y_x[t] = Σ_{s≤t} C_t · exp(cum_t − cum_s) ⊙ (dt_s B_s x_s)
        # computed stably as exp(cum_t) ⊙ Σ_{s≤t} exp(−cum_s)(dt B x)_s
        w = jnp.exp(-cum) * (dtc * xc)[..., None] * bc[:, :, None, :]  # (B,L,Di,N)
        wsum = jnp.cumsum(w, axis=1)
        y_x = jnp.einsum("bln,bldn->bld", cc, decay_in * wsum)
        # chunk-exit state
        h_new = decay_in[:, -1] * (h + wsum[:, -1])
        return h_new, y_h + y_x

    h0 = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0
    h_fin, ys = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(xs, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dts, 1, 0).astype(jnp.float32),
         jnp.moveaxis(bs, 1, 0).astype(jnp.float32),
         jnp.moveaxis(cs, 1, 0).astype(jnp.float32)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * chunk, di)[:, :t]
    return y, h_fin


def mamba1_apply(p, h, cfg: ModelConfig, *, cache=None):
    """h: (B, T, d).  cache: {conv, ssm} decode state or None (train)."""
    bsz, t, _ = h.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    xz = h @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    x, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    x = jax.nn.silu(x)

    proj = x @ p["x_proj"]
    dt_in, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if cache is not None and t == 1:
        # single-token recurrence
        hprev = cache["ssm"]                                  # (B, Di, N)
        da = jnp.exp(dt[:, 0][..., None] * a[None])           # (B, Di, N)
        upd = (dt[:, 0] * x[:, 0])[..., None] * bmat[:, 0][:, None, :]
        hnew = da * hprev + upd
        y = jnp.einsum("bn,bdn->bd", cmat[:, 0].astype(jnp.float32), hnew)[:, None]
        new_cache = {"conv": new_conv, "ssm": hnew}
    else:
        y, h_fin = _s6_scan(x, dt, bmat, cmat, a, cfg.ssm_chunk,
                            h0=cache["ssm"] if cache is not None else None)
        new_cache = {"conv": new_conv, "ssm": h_fin} if cache is not None else None

    y = y.astype(h.dtype) + x * p["d_skip"].astype(h.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_cache


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg: ModelConfig, dtype):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, ck = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (ck, di), scale=1.0 / math.sqrt(ck), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "bc_proj": dense_init(ks[2], (d, 2 * n), dtype=dtype),
        "dt_proj": dense_init(ks[3], (d, nh), dtype=dtype),
        "dt_bias": jnp.full((nh,), -4.0, jnp.float32),
        "a_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(a_log) = -1
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _ssd_scan(x, dt, bmat, cmat, a, chunk: int, h0=None):
    """Chunked SSD (mamba2).  x: (B, T, H, P); dt: (B, T, H);
    bmat/cmat: (B, T, N); a: (H,) negative scalars.
    Returns (y (B,T,H,P), state (B,H,P,N))."""
    bsz, t, nh, pdim = x.shape
    n = bmat.shape[-1]
    nc = (t + chunk - 1) // chunk
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))

    xs = jnp.moveaxis(x.reshape(bsz, nc, chunk, nh, pdim), 1, 0)
    dts = jnp.moveaxis(dt.reshape(bsz, nc, chunk, nh), 1, 0)
    bs = jnp.moveaxis(bmat.reshape(bsz, nc, chunk, n), 1, 0)
    cs = jnp.moveaxis(cmat.reshape(bsz, nc, chunk, n), 1, 0)

    def body(h, blk):
        xc, dtc, bc, cc = blk
        xc = xc.astype(jnp.float32); dtc = dtc.astype(jnp.float32)
        bc = bc.astype(jnp.float32); cc = cc.astype(jnp.float32)
        la = dtc * a[None, None]                                  # (B,L,H)
        cum = jnp.cumsum(la, axis=1)
        # inter-chunk: y_h[t] = exp(cum_t) C_t · h
        y_h = jnp.einsum("bln,blh,bhpn->blhp", cc, jnp.exp(cum), h)
        # intra-chunk (attention-like): M[t,s] = exp(cum_t − cum_s), s ≤ t
        mdec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,L,L,H)
        causal = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        mdec = jnp.where(causal[None, :, :, None], mdec, 0.0)
        scores = jnp.einsum("bln,bsn->bls", cc, bc)[..., None] * mdec  # (B,L,S,H)
        y_x = jnp.einsum("blsh,bsh,bshp->blhp", scores, dtc, xc)
        # chunk-exit state
        decay_out = jnp.exp(cum[:, -1:, :] - cum)                 # (B,L,H)
        h_new = (jnp.exp(cum[:, -1])[:, :, None, None] * h
                 + jnp.einsum("blh,blh,blhp,bln->bhpn", decay_out, dtc, xc, bc))
        return h_new, y_h + y_x

    h0 = jnp.zeros((bsz, nh, pdim, n), jnp.float32) if h0 is None else h0
    h_fin, ys = jax.lax.scan(body, h0, (xs, dts, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * chunk, nh, pdim)[:, :t]
    return y, h_fin


def mamba2_apply(p, h, cfg: ModelConfig, *, cache=None):
    bsz, t, _ = h.shape
    di, n, nh, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = h @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    x, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    x = jax.nn.silu(x)

    bc = h @ p["bc_proj"]
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(h @ p["dt_proj"] + p["dt_bias"])     # (B,T,H)
    a = -jnp.exp(p["a_log"])
    xh = x.reshape(bsz, t, nh, pdim)

    if cache is not None and t == 1:
        hprev = cache["ssm"]                                  # (B,H,P,N)
        da = jnp.exp(dt[:, 0] * a[None])                      # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32), bmat[:, 0].astype(jnp.float32))
        hnew = da[:, :, None, None] * hprev + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), hnew)[:, None]
        new_cache = {"conv": new_conv, "ssm": hnew}
    else:
        y, h_fin = _ssd_scan(xh, dt, bmat, cmat, a, cfg.ssm_chunk,
                             h0=cache["ssm"] if cache is not None else None)
        new_cache = {"conv": new_conv, "ssm": h_fin} if cache is not None else None

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, t, di).astype(h.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_cache
