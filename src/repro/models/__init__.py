"""Model zoo substrate: layers, MoE, SSM, LM/enc-dec assemblies, registry,
sharding rules.  See repro/configs for the 10 assigned architectures."""

from . import config, layers, lm, moe, registry, shardings, ssm  # noqa: F401
from .config import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
from .registry import ModelBundle, build, input_specs  # noqa: F401
