"""Fault-tolerant training loop.

Production posture (scaled to this box, structure intact):
  * auto-restore from the newest valid checkpoint (atomic-rename commits →
    half-written checkpoints are invisible),
  * two checkpoint tiers: full every ``ckpt_every`` + cheap Tucker-compressed
    "safety" checkpoints every ``compressed_ckpt_every`` (the paper's codec),
  * deterministic (seed, step)-pure data ⇒ bit-exact resume and elastic
    re-sharding: a restarted job with a DIFFERENT mesh re-slices the same
    global batch stream,
  * straggler watchdog: per-step wall-clock EWMA; steps slower than
    ``straggler_factor``× the EWMA are logged (on a fleet this feeds the
    health controller that evicts the slow pod; here it exercises the code
    path),
  * metrics log (jsonl) for the benchmark harness.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..optim import grad_compress as gc


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    compressed_ckpt_every: int = 0       # 0 = off
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    straggler_factor: float = 3.0
    refresh_every: int = 20              # compressed-grad factor refresh


class Trainer:
    def __init__(self, tc: TrainerConfig, step_fn, state, source, *,
                 compressed_ckpt_cfg: gc.CompressionConfig | None = None,
                 log_path: str | None = None):
        """step_fn: callable(state, batch) → (state, metrics), or a
        {True/False: fn} dict for refresh-cadenced compressed training."""
        self.tc = tc
        self.step_fn = step_fn
        self.state = state
        self.source = source
        self.ckpt = Checkpointer(tc.ckpt_dir, keep=tc.keep)
        self.compressed_ckpt_cfg = compressed_ckpt_cfg
        self.log_path = Path(log_path) if log_path else None
        self.history: list[dict] = []
        self._ewma = None

    # -- fault tolerance ------------------------------------------------------
    def restore_if_available(self) -> int:
        restored = self.ckpt.restore(self.state)
        if restored is None:
            return 0
        self.state, step = restored
        print(f"[trainer] restored checkpoint at step {step}")
        return int(step)

    # -- main loop ---------------------------------------------------------
    def run(self, start_step: int | None = None) -> list[dict]:
        step = self.restore_if_available() if start_step is None else start_step
        tc = self.tc
        while step < tc.total_steps:
            batch = self.source.batch_at(step)
            t0 = time.perf_counter()
            fn = self.step_fn
            if isinstance(fn, dict):           # compressed variant pair
                refresh = (step % tc.refresh_every == 0)
                fn = self.step_fn[refresh]
            self.state, metrics = fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            self._watchdog(step, dt)
            step += 1

            if step % tc.log_every == 0 or step == tc.total_steps:
                rec = {"step": step, "dt_s": dt,
                       **{k: float(np.asarray(v)) for k, v in metrics.items()}}
                self.history.append(rec)
                print(f"[trainer] step {step}: loss={rec['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)")
                if self.log_path:
                    with self.log_path.open("a") as f:
                        f.write(json.dumps(rec) + "\n")

            if tc.ckpt_every and step % tc.ckpt_every == 0:
                self.ckpt.save(step, self.state)
            elif (tc.compressed_ckpt_every
                  and step % tc.compressed_ckpt_every == 0):
                self.ckpt.save(step, self.state,
                               compress_cfg=self.compressed_ckpt_cfg)
        self.ckpt.save(tc.total_steps, self.state, blocking=True)
        return self.history

    def _watchdog(self, step: int, dt: float):
        if self._ewma is None:
            self._ewma = dt
        if dt > self.tc.straggler_factor * self._ewma and step > 3:
            print(f"[trainer] WARNING straggler: step {step} took {dt:.2f}s "
                  f"(ewma {self._ewma:.2f}s) — flagged for eviction")
        self._ewma = 0.9 * self._ewma + 0.1 * dt
