"""Train-step factory: microbatched grad accumulation, optional Tucker-
compressed cross-pod gradient reduction, optimizer update.

Two flavors:
  * ``make_train_step``          — pure GSPMD step (dense all-reduce; XLA
                                   schedules/overlaps collectives).
  * ``make_compressed_train_step`` — ``shard_map(axis_names={'pod'})`` step:
                                   grads are pod-local, the cross-pod mean
                                   runs in the Tucker-compressed domain with
                                   error feedback (DESIGN.md §4.1).  Inside
                                   the body the remaining mesh axes stay in
                                   GSPMD auto mode, so TP/FSDP still apply.

The refresh cadence is static: the factory returns TWO jitted variants and
``TrainLoop`` picks per step (no collectives under traced conditionals).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.registry import ModelBundle
from ..optim import grad_compress as gc
from ..optim.adamw import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt_state: AdamWState
    step: jax.Array
    compressor: Any = None          # grad-compression state (or None)


def init_state(bundle: ModelBundle, optimizer: AdamW, key,
               compression: gc.CompressionConfig | None = None,
               n_pods: int = 1) -> TrainState:
    params = bundle.init(key)
    opt_state = optimizer.init(params)
    comp = None
    if compression is not None and compression.enabled:
        comp = gc.init_state(compression, params)
        comp = gc.stack_for_pods(comp, n_pods)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32), comp)


def _accumulate_grads(loss_fn, params, batch, n_micro: int):
    """lax.scan over microbatch slices; returns (mean grads, mean metrics)."""
    from ..models import shardings

    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return grads, dict(metrics, loss=loss)

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (x.shape, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def body(acc, mb):
        # the (B,)→(n_micro, B/n) reshape loses the batch sharding during
        # GSPMD propagation; re-pin each microbatch to the data axes
        mb = jax.tree.map(shardings.constrain_batch, mb)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_g, acc_m = acc
        acc_g = jax.tree.map(jnp.add, acc_g, grads)
        acc_m = jax.tree.map(jnp.add, acc_m, dict(metrics, loss=loss))
        return (acc_g, acc_m), None

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zero_m = {"loss": jnp.zeros(()), "nll": jnp.zeros(()), "aux": jnp.zeros(())}
    (g, m), _ = jax.lax.scan(body, (zero_g, zero_m), micro)
    scale = 1.0 / n_micro
    return jax.tree.map(lambda x: x * scale, g), jax.tree.map(lambda x: x * scale, m)


def make_train_step(bundle: ModelBundle, optimizer: AdamW, *, n_micro: int = 1,
                    donate: bool = True):
    """Plain GSPMD train step (dense grad reduction by XLA)."""

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        grads, metrics = _accumulate_grads(
            lambda p, b: bundle.loss(p, b), state.params, batch, n_micro)
        params, opt_state, om = optimizer.update(grads, state.opt_state, state.params)
        return (TrainState(params, opt_state, state.step + 1, state.compressor),
                {**metrics, **om})

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def make_compressed_train_step(bundle: ModelBundle, optimizer: AdamW,
                               compression: gc.CompressionConfig, mesh, *,
                               pod_axis: str = "pod", n_micro: int = 1):
    """Returns {True: refresh_step, False: plain_step} jitted variants.

    Batch must enter sharded over ``pod_axis`` on dim 0 (the pod's slice of
    the global batch); params/opt replicated over pods (kept identical by
    construction since the reduced grads are identical)."""

    def make(refresh: bool):
        def body(state: TrainState, batch):
            grads, metrics = _accumulate_grads(
                lambda p, b: bundle.loss(p, b), state.params, batch, n_micro)
            red, new_comp, stats = gc.compress_psum(
                compression, grads, gc.localize(state.compressor),
                refresh=refresh, axis_name=pod_axis)
            metrics = {**metrics,
                       "comp_ratio": jnp.float32(stats["ratio"]),
                       "loss": jax.lax.pmean(metrics["loss"], pod_axis)}
            params, opt_state, om = optimizer.update(red, state.opt_state, state.params)
            new_state = TrainState(params, opt_state, state.step + 1,
                                   gc.delocalize(new_comp))
            return new_state, {**metrics, **om}

        def wrapped(state: TrainState, batch):
            sspecs = gc.state_specs(state.compressor, pod_axis)
            state_specs = TrainState(P(), P(), P(), sspecs)
            # metrics out: replicated
            return jax.shard_map(
                body, mesh=mesh,
                in_specs=(state_specs, P(pod_axis)),
                out_specs=(state_specs, P()),
                axis_names={pod_axis},
                check_vma=False,
            )(state, batch)

        return jax.jit(wrapped, donate_argnums=(0,))

    return {True: make(True), False: make(False)}
