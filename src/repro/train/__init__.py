"""train substrate."""
