"""``python -m repro.tune`` — the autotune flywheel CLI.

    collect    sample EIG-vs-ALS timings offline into the measurement store
    harvest    execute demo plans with record=True and harvest their traces
               (the online path, runnable standalone for smoke/CI)
    train      (platform, backend)-stratified trees → versioned model files
    calibrate  fit Eq. 4/5 constants per backend from the same store
    report     store statistics + model inventory with embedded metadata

Typical flywheel:  collect/harvest → train (+calibrate) → plans pick the
trained model up through ``default_selector`` automatically.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from .records import RecordStore, default_store_path


def _store(args) -> RecordStore:
    return RecordStore(args.store)


def cmd_collect(args) -> int:
    from .collect import SMOKE, collect_into
    kw = dict(SMOKE) if args.smoke else dict(
        n_tensors=args.n_tensors, dim_range=(args.min_dim, args.max_dim),
        backends=tuple(args.backends.split(",")),
        orders=tuple(int(o) for o in args.orders.split(",")),
        reps=args.reps)
    kw.update(seed=args.seed, verbose=not args.quiet)
    n = collect_into(_store(args), **kw)
    print(f"collected {n} records into {args.store}")
    return 0


def cmd_harvest(args) -> int:
    """Run a few planned decompositions with record=True and harvest the
    timed traces — exercises the online path end to end (and doubles as a
    cheap store seeder: both fixed-eig and fixed-als plans run, so the
    harvested records pair into labeled examples)."""
    import jax.numpy as jnp
    import numpy as np

    from ..core.api import TuckerConfig, plan
    from . import recording

    rng = np.random.default_rng(args.seed)
    store = _store(args)
    shapes = [(24, 18, 12), (40, 10, 8)] if args.smoke else \
        [(48, 36, 24), (96, 16, 12), (20, 20, 20, 8)]
    n = 0
    with recording(store) as sink:
        for shape in shapes:
            ranks = tuple(max(2, s // 4) for s in shape)
            x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            for methods in ("eig", "als"):
                p = plan(shape, x.dtype, TuckerConfig(ranks=ranks,
                                                      methods=methods))
                p.execute(x, record=True)
        n = len(sink.measurements)
    print(f"harvested {n} records into {args.store}")
    return 0


def cmd_train(args) -> int:
    from .train import train_stratified
    written = train_stratified(
        _store(args), platform=args.platform, model_dir=args.model_dir,
        min_examples=args.min_examples, seed=args.seed,
        calibrate=not args.no_calibrate)
    if not written:
        print("no stratum had enough labeled examples; collect more "
              f"records (need >= {args.min_examples} eig/als pairs)")
        return 1
    for path, info in written.items():
        print(f"wrote {path}: backend={info['backend']} "
              f"n={info['n_examples']} cv={info['cv_accuracy']:.3f} "
              f"test={info['test_accuracy']}")
    return 0


def cmd_calibrate(args) -> int:
    from .calibrate import calibrate_store
    written = calibrate_store(_store(args), platform=args.platform,
                              model_dir=args.model_dir)
    if not written:
        print("no stratum had enough records to calibrate")
        return 1
    for path, doc in written.items():
        print(f"wrote {path}: c_eig={doc['c_eig']:.2f} "
              f"c_qr={doc['c_qr']:.2f} c_inv={doc['c_inv']:.2f} "
              f"eig_scale={doc['eig_scale']:.3g} "
              f"als_scale={doc['als_scale']:.3g}")
    return 0


def cmd_report(args) -> int:
    from ..core.selector import model_dir as default_model_dir
    store = _store(args)
    print(json.dumps(store.stats(), indent=2))
    mdir = Path(args.model_dir) if args.model_dir else default_model_dir()
    models = sorted(mdir.glob("selector_*.json")) + \
        sorted(mdir.glob("cost_*.json")) if mdir.exists() else []
    if not models:
        print(f"no model files under {mdir}")
        return 0
    print(f"\nmodels under {mdir}:")
    for p in models:
        d = json.loads(p.read_text())
        meta = d.get("meta", d)
        brief = {k: meta[k] for k in ("platform", "backend", "n_examples",
                                      "cv_accuracy", "test_accuracy",
                                      "store_digest", "trained_at", "c_eig",
                                      "source") if k in meta}
        if "store_digest" in brief:
            brief["store_digest"] = brief["store_digest"][:12]
        print(f"  {p.name}: {json.dumps(brief)}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="a-Tucker autotune flywheel (measurement store → "
                    "selector training → calibrated cost model)")
    shared = argparse.ArgumentParser(add_help=False)
    shared.add_argument("--store", default=str(default_store_path()),
                        help="measurement store JSONL path (default: "
                             "$ATUCKER_TUNE_STORE or ./tune_store.jsonl)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("collect", parents=[shared],
                       help="offline EIG-vs-ALS sampling")
    c.add_argument("--smoke", action="store_true",
                   help="tiny CI preset (8 tensors, matfree only)")
    c.add_argument("--n-tensors", type=int, default=120)
    c.add_argument("--min-dim", type=int, default=10)
    c.add_argument("--max-dim", type=int, default=192)
    c.add_argument("--backends", default="matfree",
                   help="comma-separated ops backends to sample through")
    c.add_argument("--orders", default="3",
                   help="comma-separated tensor orders to rotate through")
    c.add_argument("--reps", type=int, default=2)
    c.add_argument("--seed", type=int, default=0)
    c.add_argument("--quiet", action="store_true")
    c.set_defaults(fn=cmd_collect)

    h = sub.add_parser("harvest", parents=[shared],
                       help="run demo plans with record=True → store")
    h.add_argument("--smoke", action="store_true", help="smaller shapes")
    h.add_argument("--seed", type=int, default=0)
    h.set_defaults(fn=cmd_harvest)

    t = sub.add_parser("train", parents=[shared],
                       help="stratified trees → model files")
    t.add_argument("--platform", default=None,
                   help="platform slice to train (default: current backend)")
    t.add_argument("--model-dir", default=None,
                   help="write models here instead of the default model dir")
    t.add_argument("--min-examples", type=int, default=12)
    t.add_argument("--seed", type=int, default=0)
    t.add_argument("--no-calibrate", action="store_true",
                   help="skip embedding fitted cost-model constants")
    t.set_defaults(fn=cmd_train)

    k = sub.add_parser("calibrate", parents=[shared],
                       help="fit Eq.4/5 constants per backend")
    k.add_argument("--platform", default=None)
    k.add_argument("--model-dir", default=None)
    k.set_defaults(fn=cmd_calibrate)

    r = sub.add_parser("report", parents=[shared],
                       help="store stats + model inventory")
    r.add_argument("--model-dir", default=None)
    r.set_defaults(fn=cmd_report)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)
