"""Append-only JSONL measurement store for the autotune flywheel.

One :class:`Measurement` = one timed mode solve: where it ran (platform +
ops backend + device fingerprint), what it solved (``(I_n, R_n, J_n)``,
tensor order, dtype, ALS iteration count), which solver, and the measured
seconds.  Records come from two producers — the offline sampling harness
(:mod:`repro.tune.collect`) and the online harvester that converts the
``ModeTrace`` records of executed plans — and accumulate in a
:class:`RecordStore`, a plain JSONL file that is safe to append to from
repeated runs and to merge across boxes.

Dedup identity is everything except the measurement itself (seconds,
source): re-measuring the same problem on the same hardware *merges* by
keeping the fastest observation (best-of semantics, matching how the
collector times solvers).  ``digest()`` hashes the deduped canonical
content so trained models can pin the exact store state they saw.
"""

from __future__ import annotations

import hashlib
import json
import os
from dataclasses import asdict, dataclass, replace
from pathlib import Path
from typing import Iterable, Iterator

SCHEMA_VERSION = 1

#: record sources
COLLECT, HARVEST = "collect", "harvest"


def device_fingerprint() -> str:
    """Coarse hardware identity a measurement is valid for: jax platform +
    device kind + host core count.  Deliberately NOT a serial number — any
    identical box may reuse the records."""
    import jax
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "unknown")
    return f"{jax.default_backend()}/{kind}/x{os.cpu_count() or 1}"


@dataclass(frozen=True)
class Measurement:
    """One timed mode solve (see module docstring)."""
    platform: str            # jax backend name ("cpu" | "gpu" | "tpu")
    backend: str             # ops backend the solve ran through
    device: str              # device_fingerprint() of the measuring box
    i_n: int
    r_n: int
    j_n: int
    method: str              # "eig" | "als" | "svd"
    seconds: float           # measured wall-clock (best-of-reps)
    dtype: str = "float32"
    order: int = 3           # tensor order the (I_n, J_n) pair came from
    als_iters: int = 5       # ALS iteration count (ignored for eig/svd)
    source: str = COLLECT    # "collect" | "harvest"
    predicted_s: float = 0.0  # plan-time calibrated prediction the schedule
                              # optimizer priced this step at (0.0 = none) —
                              # harvested records make DP decisions auditable
                              # and expose calibration drift (see `report`)
    rel_err: float = 0.0     # achieved-error label: discarded energy of this
                             # step as a fraction of ||X||² (rank-adaptive
                             # rand executions; 0.0 = exact-at-rank / not
                             # measured).  Lets future selectors learn speed
                             # AND accuracy.  A measurement VALUE, not part
                             # of key(): re-observations of the same problem
                             # merge as usual.

    def key(self) -> tuple:
        """Dedup/merge identity: everything but (seconds, source)."""
        return (self.platform, self.backend, self.device, self.dtype,
                self.order, self.als_iters, self.i_n, self.r_n, self.j_n,
                self.method)

    def problem_key(self) -> tuple:
        """Pairing identity across methods (for labeling): key() sans
        method."""
        return self.key()[:-1]

    def to_dict(self) -> dict:
        d = asdict(self)
        d["v"] = SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        return cls(platform=str(d["platform"]), backend=str(d["backend"]),
                   device=str(d.get("device", "unknown")),
                   i_n=int(d["i_n"]), r_n=int(d["r_n"]), j_n=int(d["j_n"]),
                   method=str(d["method"]), seconds=float(d["seconds"]),
                   dtype=str(d.get("dtype", "float32")),
                   order=int(d.get("order", 3)),
                   als_iters=int(d.get("als_iters", 5)),
                   source=str(d.get("source", COLLECT)),
                   predicted_s=float(d.get("predicted_s", 0.0)),
                   rel_err=float(d.get("rel_err", 0.0)))


class RecordStore:
    """Append-only JSONL store of :class:`Measurement` rows.

    The file format is one JSON object per line — append-safe (interrupted
    runs lose at most their own tail; a trailing partial line is skipped on
    load with a count in :meth:`stats`), diff-able, and mergeable with
    ``cat``.  All read APIs parse the file fresh so concurrent appenders in
    one process see each other's records.
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)

    # -- write ---------------------------------------------------------------
    def append(self, measurements: Iterable[Measurement]) -> int:
        """Append records; returns how many were written."""
        rows = [json.dumps(m.to_dict()) for m in measurements]
        if rows:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self.path.open("a+b") as f:
                # a prior interrupted append may have left a partial line
                # with no trailing newline; never concatenate onto it
                f.seek(0, 2)
                lead = b"\n" if f.tell() and not self._ends_newline(f) else b""
                f.write(lead + ("\n".join(rows) + "\n").encode())
        return len(rows)

    @staticmethod
    def _ends_newline(f) -> bool:
        f.seek(-1, 2)
        last = f.read(1)
        f.seek(0, 2)
        return last == b"\n"

    def merge_from(self, other: "RecordStore | str | Path") -> int:
        """Append the OTHER store's records whose dedup key is absent here
        (or strictly faster than our best for that key).  Returns the count
        appended."""
        other = other if isinstance(other, RecordStore) else RecordStore(other)
        best = {m.key(): m.seconds for m in self}
        fresh = [m for m in other
                 if m.seconds < best.get(m.key(), float("inf"))]
        return self.append(fresh)

    # -- read ----------------------------------------------------------------
    def __iter__(self) -> Iterator[Measurement]:
        if not self.path.exists():
            return
        with self.path.open() as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield Measurement.from_dict(json.loads(line))
                except (json.JSONDecodeError, KeyError, ValueError):
                    continue   # partial tail line from an interrupted append

    def load(self) -> list[Measurement]:
        return list(self)

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def filter(self, *, platform: str | None = None,
               backend: str | None = None, dtype: str | None = None,
               method: str | None = None,
               source: str | None = None) -> list[Measurement]:
        out = []
        for m in self:
            if platform is not None and m.platform != platform:
                continue
            if backend is not None and m.backend != backend:
                continue
            if dtype is not None and m.dtype != dtype:
                continue
            if method is not None and m.method != method:
                continue
            if source is not None and m.source != source:
                continue
            out.append(m)
        return out

    def dedup(self) -> dict[tuple, Measurement]:
        """Best (fastest) measurement per dedup key — merge semantics for
        repeated observations of the same problem on the same hardware."""
        best: dict[tuple, Measurement] = {}
        for m in self:
            cur = best.get(m.key())
            if cur is None or m.seconds < cur.seconds:
                best[m.key()] = m
        return best

    def compact(self) -> int:
        """Rewrite the file as its deduped content; returns rows dropped."""
        before = len(self)
        kept = sorted(self.dedup().values(), key=lambda m: m.key())
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text("".join(json.dumps(m.to_dict()) + "\n" for m in kept))
        tmp.replace(self.path)
        return before - len(kept)

    def digest(self) -> str:
        """sha256 over the canonical (deduped, key-sorted) content — stable
        under append order, duplicate re-measurement that didn't improve,
        and compaction."""
        h = hashlib.sha256()
        for _, m in sorted(self.dedup().items()):
            h.update(json.dumps(m.to_dict(), sort_keys=True).encode())
        return h.hexdigest()

    def stats(self) -> dict:
        """Summary counts for ``python -m repro.tune report``, plus
        predicted-vs-actual drift over harvested rows that carry a
        calibrated plan-time prediction — the health signal for the
        schedule optimizer's cost model."""
        strata: dict[str, int] = {}
        methods: dict[str, int] = {}
        sources: dict[str, int] = {}
        n = 0
        drift_n, drift_sum = 0, 0.0
        for m in self:
            n += 1
            strata_key = f"{m.platform}/{m.backend}"
            strata[strata_key] = strata.get(strata_key, 0) + 1
            methods[m.method] = methods.get(m.method, 0) + 1
            sources[m.source] = sources.get(m.source, 0) + 1
            if m.predicted_s > 0.0 and m.seconds > 0.0:
                drift_n += 1
                drift_sum += abs(m.seconds - m.predicted_s) / m.seconds
        out = {"path": str(self.path), "records": n,
               "unique": len(self.dedup()), "strata": strata,
               "methods": methods, "sources": sources,
               "digest": self.digest() if n else None}
        if drift_n:
            out["prediction_drift"] = {
                "records_with_prediction": drift_n,
                "mean_abs_rel_error": drift_sum / drift_n}
        return out


def default_store_path() -> Path:
    """Default store location: ``ATUCKER_TUNE_STORE`` env override, else
    ``tune_store.jsonl`` next to the shipped models (kept OUT of the models
    dir so model dirs stay pure)."""
    env = os.environ.get("ATUCKER_TUNE_STORE")
    if env:
        return Path(env)
    return Path.cwd() / "tune_store.jsonl"


def mark_harvested(m: Measurement) -> Measurement:
    return replace(m, source=HARVEST)
