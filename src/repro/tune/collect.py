"""Measurement producers: offline sampling harness + online trace harvester.

Offline (:func:`collect`): random tensors, log-uniform dims/ranks (paper
Sec. IV-B; covers the asymmetric one-huge-mode shapes where the EIG/ALS
crossover lives), each mode timed with BOTH solvers through each requested
ops backend — the paired records are exactly what labeling needs.

Online (:func:`recording` / :func:`harvest_result`): every executed
``TuckerPlan`` already produces per-mode ``ModeTrace`` records; inside a
``recording()`` context (or with ``plan.execute(record=True)``) those traces
carry real wall-clock and are converted into :class:`Measurement` rows —
production traffic improves the selector for free.  Online records are
one-sided (only the solver the plan chose ran), so they sharpen the store
wherever offline coverage or OTHER plans supply the opposing method.
"""

from __future__ import annotations

import contextlib
import time
from typing import Iterable, Sequence

import numpy as np

from ..core.selector import extract_features
from ..core.solvers import DEFAULT_ALS_ITERS
from .records import (
    COLLECT,
    HARVEST,
    Measurement,
    RecordStore,
    device_fingerprint,
)

#: tiny preset for CI — a handful of tensors, one backend, dims small enough
#: that the whole collect→train loop finishes in well under a minute
SMOKE = dict(n_tensors=8, dim_range=(8, 40), backends=("matfree",),
             orders=(3,), reps=1)


def _time_solver(y, mode, rank, method: str, *, impl: str,
                 als_iters: int = DEFAULT_ALS_ITERS, reps: int = 2) -> float:
    import jax

    from ..core.solvers import SOLVERS
    kw = {"num_iters": als_iters} if method == "als" else {}
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(SOLVERS[method](y, mode, rank, impl=impl, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def collect(
    n_tensors: int = 120,
    dim_range: tuple[int, int] = (10, 192),
    seed: int = 0,
    *,
    orders: Sequence[int] = (3,),
    backends: Sequence[str] = ("matfree",),
    dtype=np.float32,
    als_iters: int = DEFAULT_ALS_ITERS,
    reps: int = 2,
    max_elements: int = 1 << 22,
    verbose: bool = False,
) -> list[Measurement]:
    """Time EIG vs ALS per (tensor, mode, backend) → paired Measurements.

    One eig + one als record per point, as in the paper ("the statistics of
    each mode constitute a record"), stratified across ``backends`` and
    tensor ``orders``.  Warm-up compile is excluded by timing the best of
    ``reps`` runs after a throwaway call.

    ``max_elements`` caps the sampled tensor volume (higher orders would
    otherwise explode: dim_range's top end to the 4th power is terabytes)
    by halving the largest sampled dim until the tensor fits.
    """
    import jax
    import jax.numpy as jnp

    from ..core.backend import get_backend
    for b in backends:
        get_backend(b)   # fail fast on unknown names
    rng = np.random.default_rng(seed)
    platform = jax.default_backend()
    device = device_fingerprint()
    dtype_name = str(jnp.dtype(dtype))

    def log_uniform(lo, hi):
        return int(round(np.exp(rng.uniform(np.log(lo), np.log(hi)))))

    out: list[Measurement] = []
    for t in range(n_tensors):
        order = int(orders[t % len(orders)])
        dims = [log_uniform(dim_range[0], dim_range[1])
                for _ in range(order)]
        while np.prod(dims) > max_elements and max(dims) > 4:
            k = int(np.argmax(dims))
            dims[k] = max(4, dims[k] // 2)
        if np.prod(dims) > max_elements:
            # even all-4 dims overflow the cap (absurd order): skip rather
            # than allocate a tensor the cap exists to prevent
            if verbose:
                print(f"[tune.collect] skipping order-{order} sample "
                      f"(4^{order} > max_elements)")
            continue
        dims = tuple(dims)
        ranks = tuple(log_uniform(max(1, min(4, d // 2)), max(2, d // 2))
                      for d in dims)
        x = jnp.asarray(rng.standard_normal(dims), dtype=dtype)
        for impl in backends:
            for mode in range(order):
                i_n, r_n = dims[mode], ranks[mode]
                j_n = int(np.prod(dims)) // i_n
                common = dict(platform=platform, backend=impl, device=device,
                              i_n=i_n, r_n=r_n, j_n=j_n, dtype=dtype_name,
                              order=order, als_iters=als_iters,
                              source=COLLECT)
                # throwaway to exclude compile time, then measure
                _time_solver(x, mode, r_n, "eig", impl=impl, reps=1)
                _time_solver(x, mode, r_n, "als", impl=impl,
                             als_iters=als_iters, reps=1)
                te = _time_solver(x, mode, r_n, "eig", impl=impl, reps=reps)
                ta = _time_solver(x, mode, r_n, "als", impl=impl,
                                  als_iters=als_iters, reps=reps)
                out.append(Measurement(method="eig", seconds=te, **common))
                out.append(Measurement(method="als", seconds=ta, **common))
        if verbose and (t + 1) % 10 == 0:
            print(f"[tune.collect] {t + 1}/{n_tensors} tensors sampled "
                  f"({len(out)} records)")
    return out


def collect_into(store: RecordStore, **kw) -> int:
    """``collect()`` straight into a store; returns records appended."""
    return store.append(collect(**kw))


def collect_samples(
    n_tensors: int = 120,
    dim_range: tuple[int, int] = (10, 192),
    seed: int = 0,
    order: int = 3,
    dtype=np.float32,
    verbose: bool = False,
):
    """Legacy array API: (features, labels, times) on the matfree backend —
    the pre-flywheel signature kept for existing call sites
    (benchmarks/paper_figs.py, repro.core.selector re-export)."""
    ms = collect(n_tensors, dim_range, seed, orders=(order,), dtype=dtype,
                 verbose=verbose)
    feats, labels, times = [], [], []
    for te, ta in zip(ms[::2], ms[1::2]):   # collect() emits (eig, als) pairs
        feats.append(extract_features(te.i_n, te.r_n, te.j_n))
        labels.append(0 if te.seconds <= ta.seconds else 1)
        times.append((te.seconds, ta.seconds))
    return np.array(feats), np.array(labels), np.array(times)


# ---------------------------------------------------------------------------
# Online harvesting: executed-plan traces → training records
# ---------------------------------------------------------------------------

class RecordSink:
    """In-memory accumulator the plan layer feeds timed traces into while a
    :func:`recording` context is active."""

    def __init__(self):
        self.measurements: list[Measurement] = []

    def add_traces(self, traces, *, platform: str, dtype: str,
                   order: int, als_iters: int = DEFAULT_ALS_ITERS) -> int:
        ms = measurements_from_traces(traces, platform=platform, dtype=dtype,
                                      order=order, als_iters=als_iters)
        self.measurements.extend(ms)
        return len(ms)

    def flush(self, store: RecordStore) -> int:
        n = store.append(self.measurements)
        self.measurements.clear()
        return n


_SINKS: list[RecordSink] = []


def active_sink() -> RecordSink | None:
    """The innermost active recording sink (None outside any context).
    Checked by ``TuckerPlan.execute`` — via ``sys.modules`` so plans that
    never meet the tune subsystem pay nothing."""
    return _SINKS[-1] if _SINKS else None


@contextlib.contextmanager
def recording(store: RecordStore | str | None = None):
    """Process-wide harvest context: every ``TuckerPlan.execute`` inside it
    runs the timed (eager) path and its per-mode wall-clock lands in the
    yielded :class:`RecordSink` — flushed to ``store`` on exit if given.

        with tune.recording(store):
            plan.execute(x)          # production call, now also a sample
    """
    if isinstance(store, (str, bytes)) or hasattr(store, "__fspath__"):
        store = RecordStore(store)
    sink = RecordSink()
    _SINKS.append(sink)
    try:
        yield sink
    finally:
        _SINKS.remove(sink)
        if store is not None:
            sink.flush(store)


def measurements_from_traces(traces, *, platform: str, dtype: str,
                             order: int,
                             als_iters: int = DEFAULT_ALS_ITERS,
                             ) -> list[Measurement]:
    """Convert timed ``ModeTrace`` records into harvest Measurements.

    Traces with no real timing (``seconds <= 0`` — e.g. from the fused
    jitted sweep, where per-step time is unobservable) and solver families
    outside EIG/ALS/RAND are skipped: only rows a trainer can label
    against belong in the store.

    Each row carries the trace's plan-time ``predicted_s`` (when a
    calibrated cost model priced the schedule), so decisions made by the
    schedule optimizer — which solver the DP picked and what it believed
    the step would cost — become auditable records the flywheel can check
    for drift (``python -m repro.tune report``).  Rank-adaptive ``rand``
    traces additionally carry their measured fractional tail energy, which
    lands as the row's ``rel_err`` achieved-error label — so future
    selectors can learn speed AND accuracy.
    """
    device = device_fingerprint()
    out = []
    for t in traces:
        if t.seconds <= 0.0 or t.method not in ("eig", "als", "rand"):
            continue
        out.append(Measurement(
            platform=platform, backend=t.backend, device=device,
            i_n=t.i_n, r_n=t.r_n, j_n=t.j_n, method=t.method,
            seconds=float(t.seconds), dtype=dtype, order=order,
            als_iters=als_iters, source=HARVEST,
            predicted_s=float(getattr(t, "predicted_s", 0.0)),
            rel_err=float(getattr(t, "tail_err", 0.0))))
    return out


def harvest_result(result, store: RecordStore | None = None, *,
                   platform: str | None = None, dtype: str = "float32",
                   als_iters: int = DEFAULT_ALS_ITERS) -> list[Measurement]:
    """Harvest one ``SthosvdResult`` (from ``plan.execute(record=True)`` or
    a legacy entry point, whose traces always carry wall-clock) into
    Measurements; appended to ``store`` when given."""
    import jax
    platform = platform or jax.default_backend()
    order = len({t.mode for t in result.trace})
    ms = measurements_from_traces(result.trace, platform=platform,
                                  dtype=dtype, order=order,
                                  als_iters=als_iters)
    if store is not None:
        store.append(ms)
    return ms


def harvest_results(results: Iterable, store: RecordStore, **kw) -> int:
    """Batch :func:`harvest_result`; returns total records appended."""
    return sum(len(harvest_result(r, store, **kw)) for r in results)
