"""Selector training over the measurement store (paper Sec. IV-B).

Pairing: a labeled example needs BOTH solvers measured on the same problem
on the same hardware — records are grouped by their problem key (platform,
backend, device, dtype, order, als_iters, I_n, R_n, J_n), the fastest
observation per method wins, and the label is argmin(eig, als).  One-sided
harvest records stay in the store unlabeled until traffic (or a collect
run) supplies the opposing method.

Stratification: one tree per ``(platform, backend)`` stratum — the backend
axis shifts the EIG/ALS crossover (each backend has its own cost profile) —
plus one platform-pooled tree as the graceful-fallback tier
``default_selector`` resolves when no per-backend model exists.  Every
model file embeds provenance metadata: sample counts, grid-search CV and
held-out test accuracy, the trained feature range (the out-of-range
guardrail), and the store digest it was trained from.
"""

from __future__ import annotations

import time
from pathlib import Path
from typing import Iterable, Sequence

import numpy as np

from ..core.cost_model import DEFAULT_COST_MODEL, CostModel
from ..core.dtree import grid_search_cv
from ..core.selector import (
    Selector,
    extract_features,
    model_path,
)
from .records import Measurement, RecordStore

#: below this many labeled examples a stratum is skipped (a tree fit on a
#: handful of points is worse than the cost-model fallback it replaces)
MIN_EXAMPLES = 12


def labeled_examples(measurements: Iterable[Measurement], *,
                     rel_err_tolerance: float | None = None):
    """Pair eig/als records per problem → (features, labels, times) arrays.

    ``times[k] = (eig_seconds, als_seconds)`` for example k; unpaired
    records are simply not emitted (count them via
    ``len(records) - 2*len(labels)`` if needed).

    ``rel_err_tolerance`` makes labeling accuracy-aware: records whose
    achieved-error label (``Measurement.rel_err`` — the fractional tail
    energy rank-adaptive rand executions report) exceeds the tolerance are
    dropped before pairing, so a fast-but-out-of-budget observation can
    never win a speed comparison.  eig/als records carry ``rel_err=0.0``
    (exact at their rank) and always pass; ``None`` (default) disables the
    filter entirely.
    """
    best: dict[tuple, dict[str, Measurement]] = {}
    for m in measurements:
        if rel_err_tolerance is not None and m.rel_err > rel_err_tolerance:
            continue
        slot = best.setdefault(m.problem_key(), {})
        cur = slot.get(m.method)
        if cur is None or m.seconds < cur.seconds:
            slot[m.method] = m
    feats, labels, times = [], [], []
    for slot in best.values():
        if "eig" not in slot or "als" not in slot:
            continue
        e, a = slot["eig"], slot["als"]
        feats.append(extract_features(e.i_n, e.r_n, e.j_n))
        labels.append(0 if e.seconds <= a.seconds else 1)
        times.append((e.seconds, a.seconds))
    if not feats:
        return (np.empty((0, len(extract_features(2, 1, 2)))),
                np.empty((0,), np.int64), np.empty((0, 2)))
    return np.array(feats), np.array(labels), np.array(times)


def train_selector(
    feats: np.ndarray,
    labels: np.ndarray,
    test_split: float = 0.3,
    seed: int = 0,
    *,
    platform: str | None = None,
    backend: str | None = None,
    cost_model: CostModel | None = None,
    meta: dict | None = None,
) -> tuple[Selector, dict]:
    """70/30 split + grid-search CV (paper defaults) → (Selector, info).

    ``platform`` labels the resulting selector (default: the current JAX
    backend) — the SAME string callers must use to save/cache it, so
    train/label/save never disagree.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(labels))
    n_test = int(len(labels) * test_split)
    test, train = perm[:n_test], perm[n_test:]
    tree, info = grid_search_cv(feats[train], labels[train])
    info["test_accuracy"] = tree.score(feats[test], labels[test]) \
        if n_test else None
    info["n_train"], info["n_test"] = len(train), len(test)
    if platform is None:
        import jax
        platform = jax.default_backend()
    rng3 = (tuple(float(v) for v in feats[:, :3].min(0)),
            tuple(float(v) for v in feats[:, :3].max(0)))
    sel = Selector(tree=tree, platform=platform, backend=backend,
                   trained_range=rng3,
                   cost_model=cost_model or DEFAULT_COST_MODEL,
                   meta={**info, **(meta or {})})
    return sel, info


def train_stratified(
    store: RecordStore,
    *,
    platform: str | None = None,
    backends: Sequence[str] | None = None,
    model_dir=None,
    min_examples: int = MIN_EXAMPLES,
    test_split: float = 0.3,
    seed: int = 0,
    calibrate: bool = True,
) -> dict[str, dict]:
    """Train per-(platform, backend) trees + the platform-pooled tree and
    write versioned model files.  Returns {written path: info}.

    ``platform`` restricts training to one platform's records (default: the
    current JAX backend — a store merged from several boxes trains only the
    local slice unless you loop yourself).  ``backends`` restricts the
    per-backend strata (default: every backend present in the store).
    ``calibrate=True`` additionally fits each stratum's cost-model
    constants (:mod:`repro.tune.calibrate`) and embeds them as the trained
    model's out-of-range guardrail fallback.
    """
    from ..core import selector as sel_mod
    if platform is None:
        import jax
        platform = jax.default_backend()
    records = store.filter(platform=platform)
    digest = store.digest()
    present = sorted({m.backend for m in records})
    if backends is not None:
        present = [b for b in present if b in backends]

    written: dict[str, dict] = {}

    def _fit(recs, backend: str | None):
        feats, labels, times = labeled_examples(recs)
        if len(labels) < min_examples:
            return None
        cm = None
        if calibrate:
            from .calibrate import fit_cost_model
            cm = fit_cost_model(recs if backend is not None else records)
        meta = {"format": "selector", "platform": platform,
                "backend": backend, "n_records": len(recs),
                "n_examples": int(len(labels)),
                "label_balance_als": float(labels.mean()),
                "store_digest": digest,
                "trained_at": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                            time.gmtime())}
        sel, info = train_selector(feats, labels, test_split, seed,
                                   platform=platform, backend=backend,
                                   cost_model=cm, meta=meta)
        path = model_path(platform, backend)
        if model_dir is not None:
            path = Path(model_dir) / path.name
        sel.save(path)
        # retraining must be visible in-process: refresh the resolution cache
        sel_mod._DEFAULT_BY_PLATFORM[(platform, backend)] = sel
        if backend is None:
            # the pooled model also serves (platform, b) lookups that found
            # no per-backend file — evict entries serving any fallback (an
            # old pooled tree: selector.backend None; the bare cost model:
            # tree None) so they re-resolve against the fresh pooled model
            for k in [k for k in sel_mod._DEFAULT_BY_PLATFORM
                      if k[0] == platform and k[1] is not None
                      and (sel_mod._DEFAULT_BY_PLATFORM[k].backend is None
                           or sel_mod._DEFAULT_BY_PLATFORM[k].tree is None)]:
                del sel_mod._DEFAULT_BY_PLATFORM[k]
        return path, {**info, **meta}

    for b in present:
        got = _fit([m for m in records if m.backend == b], b)
        if got:
            written[str(got[0])] = got[1]
    got = _fit(records, None)           # platform-pooled fallback tier
    if got:
        written[str(got[0])] = got[1]
    return written


def train_and_save(platform: str | None = None, **collect_kw) -> dict:
    """Legacy one-shot: collect on this box → train → save under ONE
    platform string (the passed ``platform``, else the current JAX
    backend) — the model's label, file name, and cache key all agree."""
    import jax

    from ..core import selector as sel_mod
    from .collect import collect_samples
    platform = platform or jax.default_backend()
    feats, labels, _ = collect_samples(**collect_kw)
    sel, info = train_selector(feats, labels, platform=platform)
    sel.save(model_path(platform))
    sel_mod._DEFAULT_BY_PLATFORM[(platform, None)] = sel
    return info
