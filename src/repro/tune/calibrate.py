"""Hardware calibration of the Eq. 4/5 cost model from measured records.

The paper leaves the LAPACK-kernel constants f_eig/f_qr/f_inv symbolic;
the textbook values (9n³, 2mn²−(2/3)n³, 2n³) assume every FLOP costs the
same, which no real BLAS does — eigendecomposition FLOPs on a 1-core CPU
are far slower than GEMM FLOPs, and each ops backend shifts the balance
again.  This module fits, per (platform, backend), a least-squares
decomposition of measured seconds onto the model's term structure:

    eig seconds ≈ o_e + α_e·(I²J + 2IRJ)       + β_e·I³
    als seconds ≈ o_a + α_a·(GEMM-family terms) + β_a·(iters·R³) + γ_a·QR(I,R)

which recovers c_eig = β_e/α_e, c_inv = β_a/(2α_a), c_qr = γ_a/α_a and —
because the fit is against *seconds* — the per-FLOP scales α_e, α_a and
per-solve dispatch overheads o_e, o_a that make
``CostModel.predict_seconds`` real wall-clock and ``predicted_best`` a
seconds comparison instead of a FLOP comparison.  (The intercepts matter:
on small modes kernel-launch overhead dominates, and ALS launches far more
kernels per solve than EIG — a pure FLOP model gets exactly the
small-problem regime wrong.)  The result feeds the trained selector's
out-of-range guardrail, so the paper's huge-mode regime is decided by
hardware-calibrated constants instead of textbook ones.

A constant whose fitted coefficient comes back non-positive (collinear or
starved design) silently keeps its textbook value — calibration degrades
toward the default, never past it.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable

import numpy as np

from ..core.cost_model import CostModel
from ..core.selector import calibration_path
from .records import Measurement, RecordStore

#: minimum records per method before a fit is attempted
MIN_RECORDS = 8


def _eig_basis(i, r, j):
    """(intercept, GEMM-family, I³) columns of the Eq. 4 decomposition."""
    i, r, j = float(i), float(r), float(j)
    return np.array([1.0, i * i * j + 2.0 * i * r * j, i ** 3])


def _als_basis(i, r, j, iters):
    """(intercept, GEMM-family, iters·R³, QR-count) columns of the Eq. 5
    decomposition — the iters·R³ column carries the inversions (textbook
    contribution 2·c_inv·iters·R³) and the QR column the Householder count
    at c_qr = 1."""
    i, r, j = float(i), float(r), float(j)
    gemm = (4.0 * i * j * r + 4.0 * j * r * r + 4.0 * i * r * r) * iters \
        + 2.0 * j * r * r
    return np.array([1.0, gemm, iters * r ** 3,
                     2.0 * i * r * r - (2.0 / 3.0) * r ** 3])


def _nonneg_lstsq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """lstsq with a poor man's non-negativity: columns whose coefficient
    comes back negative are dropped (zeroed) and the rest refit, so one
    collinear term cannot poison the whole calibration."""
    cols = list(range(a.shape[1]))
    coef = np.zeros(a.shape[1])
    for _ in range(a.shape[1]):
        c, *_ = np.linalg.lstsq(a[:, cols], b, rcond=None)
        if (c >= 0).all():
            coef[cols] = c
            return coef
        cols = [cols[k] for k in range(len(cols)) if c[k] >= 0]
        if not cols:
            return coef
    coef[cols] = np.linalg.lstsq(a[:, cols], b, rcond=None)[0]
    return np.maximum(coef, 0.0)


def fit_cost_model(measurements: Iterable[Measurement],
                   min_records: int = MIN_RECORDS) -> CostModel | None:
    """Fit a calibrated :class:`CostModel` from eig/als measurements.

    Returns None when either method has fewer than ``min_records`` deduped
    records (a starved fit is worse than the textbook default).  Records
    should come from ONE (platform, backend) stratum — mixing hardware
    mixes the very constants being fitted.
    """
    eig, als = {}, {}
    for m in measurements:
        slot = eig if m.method == "eig" else als if m.method == "als" else None
        if slot is None:
            continue
        cur = slot.get(m.problem_key())
        if cur is None or m.seconds < cur.seconds:
            slot[m.problem_key()] = m
    if len(eig) < min_records or len(als) < min_records:
        return None

    a_e = np.stack([_eig_basis(m.i_n, m.r_n, m.j_n) for m in eig.values()])
    b_e = np.array([m.seconds for m in eig.values()])
    ce = _nonneg_lstsq(a_e, b_e)

    a_a = np.stack([_als_basis(m.i_n, m.r_n, m.j_n, m.als_iters)
                    for m in als.values()])
    b_a = np.array([m.seconds for m in als.values()])
    ca = _nonneg_lstsq(a_a, b_a)

    o_e, a_e1, b_e1 = ce
    o_a, a_a1, b_a1, g_a1 = ca
    if a_e1 <= 0 and a_a1 <= 0:
        return None   # no usable per-FLOP signal — not a calibration
    default = CostModel()
    # constants are RATIOS to the GEMM coefficient; a zeroed GEMM column
    # (degenerate fit) keeps every dependent constant at textbook
    c_eig = b_e1 / a_e1 if a_e1 > 0 and b_e1 > 0 else default.c_eig
    c_inv = b_a1 / (2.0 * a_a1) if a_a1 > 0 and b_a1 > 0 else default.c_inv
    c_qr = g_a1 / a_a1 if a_a1 > 0 and g_a1 > 0 else default.c_qr
    return CostModel(c_eig=float(c_eig), c_qr=float(c_qr),
                     c_inv=float(c_inv),
                     eig_scale=float(a_e1) if a_e1 > 0 else 1.0,
                     als_scale=float(a_a1) if a_a1 > 0 else 1.0,
                     eig_overhead_s=float(max(o_e, 0.0)),
                     als_overhead_s=float(max(o_a, 0.0)),
                     source="calibrated")


def calibrate_store(store: RecordStore, *, platform: str | None = None,
                    model_dir=None,
                    min_records: int = MIN_RECORDS) -> dict[str, dict]:
    """Fit + save one calibration file per (platform, backend) stratum in
    the store.  Returns {written path: cost-model dict}."""
    if platform is None:
        import jax
        platform = jax.default_backend()
    records = store.filter(platform=platform)
    written: dict[str, dict] = {}
    for backend in sorted({m.backend for m in records}):
        cm = fit_cost_model([m for m in records if m.backend == backend],
                            min_records=min_records)
        if cm is None:
            continue
        path = calibration_path(platform, backend)
        if model_dir is not None:
            path = Path(model_dir) / path.name
        path.parent.mkdir(parents=True, exist_ok=True)
        doc = {**cm.to_dict(), "platform": platform, "backend": backend,
               "n_records": len([m for m in records if m.backend == backend]),
               "store_digest": store.digest()}
        path.write_text(json.dumps(doc, indent=1))
        written[str(path)] = doc
    return written
