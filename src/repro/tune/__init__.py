"""Autotune subsystem: the measurement flywheel behind the adaptive selector.

The paper's selector is trained once, offline (Sec. IV-B).  This package
turns that into a loop:

  * :mod:`repro.tune.records` — append-only JSONL measurement store
    (platform + backend + device fingerprint, (I_n, R_n, J_n), method,
    seconds) with dedup/merge/digest.
  * :mod:`repro.tune.collect` — offline sampling harness across registered
    ops backends and tensor orders, plus the ONLINE harvester:
    ``recording()`` / ``plan.execute(record=True)`` convert the ModeTrace
    records of production executions into training records for free.
  * :mod:`repro.tune.train` — (platform, backend)-stratified decision
    trees with embedded provenance metadata, resolved by
    ``repro.core.selector.default_selector`` per (platform, backend) with
    graceful fallback.
  * :mod:`repro.tune.calibrate` — least-squares fit of the symbolic
    f_eig/f_qr/f_inv constants (and seconds-per-FLOP scales) of the Eq. 4/5
    cost model per backend, hardware-calibrating the selector's
    out-of-range guardrail.

CLI: ``python -m repro.tune {collect | harvest | train | calibrate |
report}``.
"""

from .calibrate import calibrate_store, fit_cost_model
from .collect import (
    active_sink,
    collect,
    collect_into,
    harvest_result,
    harvest_results,
    recording,
)
from .records import Measurement, RecordStore, default_store_path
from .train import labeled_examples, train_selector, train_stratified

__all__ = [
    "Measurement", "RecordStore", "active_sink", "calibrate_store",
    "collect", "collect_into", "default_store_path", "fit_cost_model",
    "harvest_result", "harvest_results", "labeled_examples", "recording",
    "train_selector", "train_stratified",
]
