"""Matricization-free interior-mode TTM Pallas kernel (a-Tucker Sec. V).

Computes  out[a, r, b] = Σ_i  u[r, i] · x[a, i, b]  on the (A, I_n, B) view
of the tensor — i.e. the paper's batched-GEMM organization of mode-n TTM,
with the BlockSpec index maps playing the role of the (outer, along, inner)
loop split: grid dim 0 walks the merged *outer* loops (A), dims 1/2 tile the
output (R, B), and dim 3 is the contraction sweep along mode n.

The tensor is NEVER unfolded: the x BlockSpec reads (1, bi, bb) tiles
straight from the tensor's native row-major layout (B is the contiguous
axis → lane dimension; I_n is the sublane dimension), so HBM traffic equals
the tensor's footprint with zero transpose/copy — the TPU analogue of the
paper's in-place batched GEMM on CPU/GPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ttm_kernel(u_ref, x_ref, o_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (br, bi) @ (bi, bb) -> (br, bb), accumulated in fp32 on the MXU.
    o_ref[0, ...] += jax.lax.dot_general(
        u_ref[...], x_ref[0, ...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("br", "bb", "bi", "interpret"))
def ttm_interior(u: jax.Array, x3: jax.Array, *, br: int = 128, bb: int = 128,
                 bi: int = 128, interpret: bool = False) -> jax.Array:
    """out (A, R, B) = einsum('rn,anb->arb', u, x3).  Dims must tile evenly."""
    a, i, b = x3.shape
    r, i2 = u.shape
    assert i == i2, (u.shape, x3.shape)
    assert r % br == 0 and b % bb == 0 and i % bi == 0, (u.shape, x3.shape, br, bb, bi)
    grid = (a, r // br, b // bb, i // bi)
    return pl.pallas_call(
        _ttm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bi), lambda aa, rr, bbb, ii: (rr, ii)),
            pl.BlockSpec((1, bi, bb), lambda aa, rr, bbb, ii: (aa, ii, bbb)),
        ],
        out_specs=pl.BlockSpec((1, br, bb), lambda aa, rr, bbb, ii: (aa, rr, bbb)),
        out_shape=jax.ShapeDtypeStruct((a, r, b), jnp.float32),
        interpret=interpret,
    )(u, x3)
