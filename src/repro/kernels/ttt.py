"""Matricization-free TTT / Gram Pallas kernel (a-Tucker Sec. V).

Computes  z[i, r] = Σ_{a,b}  x[a, i, b] · y[a, r, b]  on (A, ·, B) views —
the mode-(I,J) tensor-times-tensor product contracting every mode except the
target one.  Gram (S = Y_(n) Y_(n)^T) is the special case y ≡ x, exactly as
the paper treats it.

Grid = (I/bi, R/br, A, B/bb) with BOTH reduction dims (A, B) innermost, so
the (bi, br) output tile stays resident in VMEM while the kernel streams the
two tensors tile-by-tile in their native layout (no unfold).  fp32
accumulation on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ttt_kernel(x_ref, y_ref, o_ref):
    @pl.when((pl.program_id(2) == 0) & (pl.program_id(3) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bi, bb) @ (br, bb)^T -> (bi, br)
    o_ref[...] += jax.lax.dot_general(
        x_ref[0, ...], y_ref[0, ...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bi", "br", "bb", "interpret"))
def ttt_pallas3(x3: jax.Array, y3: jax.Array, *, bi: int = 128, br: int = 128,
                bb: int = 128, interpret: bool = False) -> jax.Array:
    """z (I, R) = einsum('aib,arb->ir', x3, y3).  Dims must tile evenly."""
    a, i, b = x3.shape
    a2, r, b2 = y3.shape
    assert a == a2 and b == b2, (x3.shape, y3.shape)
    assert i % bi == 0 and r % br == 0 and b % bb == 0, (x3.shape, y3.shape, bi, br, bb)
    grid = (i // bi, r // br, a, b // bb)
    return pl.pallas_call(
        _ttt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bi, bb), lambda ii, rr, aa, bbb: (aa, ii, bbb)),
            pl.BlockSpec((1, br, bb), lambda ii, rr, aa, bbb: (aa, rr, bbb)),
        ],
        out_specs=pl.BlockSpec((bi, br), lambda ii, rr, aa, bbb: (ii, rr)),
        out_shape=jax.ShapeDtypeStruct((i, r), jnp.float32),
        interpret=interpret,
    )(x3, y3)
