"""Pallas TPU kernels for a-Tucker's matricization-free hot spots.

Kernels (each with BlockSpec VMEM tiling; validated vs ref.py in
tests/test_kernels.py via interpret mode):
  matmul.matmul        — tiled MXU GEMM (boundary-mode TTM)
  ttm.ttm_interior     — interior-mode batched-GEMM TTM
  ttt.ttt_pallas3      — TTT / Gram contraction over merged outer+inner dims

ops.py carries the jit'd, shape-padding public wrappers.
"""

from . import ops, ref
from .matmul import matmul
from .ttm import ttm_interior
from .ttt import ttt_pallas3

__all__ = ["matmul", "ops", "ref", "ttm_interior", "ttt_pallas3"]
