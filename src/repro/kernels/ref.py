"""Pure-jnp oracles for the Pallas kernels (standalone; no kernel imports).

These are the ground truth for the per-kernel allclose sweeps in
tests/test_kernels.py.  Written naively on purpose — correctness over speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   precision=jax.lax.Precision.HIGHEST)


def ttm_interior_ref(u: jax.Array, x3: jax.Array) -> jax.Array:
    """out (A, R, B) = einsum('rn,anb->arb')."""
    return jnp.einsum("rn,anb->arb", u.astype(jnp.float32), x3.astype(jnp.float32),
                      precision=jax.lax.Precision.HIGHEST)


def ttt_ref(x3: jax.Array, y3: jax.Array) -> jax.Array:
    """z (I, R) = einsum('aib,arb->ir')."""
    return jnp.einsum("aib,arb->ir", x3.astype(jnp.float32), y3.astype(jnp.float32),
                      precision=jax.lax.Precision.HIGHEST)


def gram_ref(x3: jax.Array) -> jax.Array:
    return ttt_ref(x3, x3)


def ttm_full_ref(x: jax.Array, u: jax.Array, mode: int) -> jax.Array:
    """Full mode-n TTM oracle via explicit matricization."""
    xm = jnp.moveaxis(x, mode, 0).astype(jnp.float32)
    y2 = jnp.dot(u.astype(jnp.float32), xm.reshape(x.shape[mode], -1),
                 precision=jax.lax.Precision.HIGHEST)
    out_shape = (u.shape[0],) + x.shape[:mode] + x.shape[mode + 1:]
    return jnp.moveaxis(y2.reshape(out_shape), 0, mode)


def gram_full_ref(x: jax.Array, mode: int) -> jax.Array:
    xm = jnp.moveaxis(x, mode, 0).astype(jnp.float32).reshape(x.shape[mode], -1)
    return jnp.dot(xm, xm.T, precision=jax.lax.Precision.HIGHEST)
