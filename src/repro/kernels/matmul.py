"""Tiled MXU matmul Pallas kernel: C (M,N) = A (M,K) @ B (K,N), fp32 accum.

Used for the first-mode / last-mode TTM cases of the matricization-free
st-HOSVD (paper Fig. 4: the boundary modes collapse to a single GEMM).

Blocking: (bm, bk) × (bk, bn) tiles streamed HBM→VMEM; grid =
(M/bm, N/bn, K/bk) with the contraction as the innermost (minor) grid dim so
the output tile stays resident in VMEM across the K sweep (revolving
accumulator pattern).  Tile defaults are MXU-aligned (128×128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """Pallas tiled matmul.  Requires M%bm == N%bn == K%bk == 0 (ops.py pads)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
