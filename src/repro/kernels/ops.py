"""jit'd public wrappers for the a-Tucker Pallas kernels.

These are the primitives behind the ``pallas`` ops backend
(:mod:`repro.core.backend`): ``TuckerConfig(impl="pallas")`` — or
``impl="auto"`` on TPU — routes every TTM/TTT/Gram of a plan's sweep through
this module.

Dispatch mirrors the paper's Fig. 4 structure:
  mode == 0    → single GEMM   u @ X_(0-view)          (matmul kernel)
  mode == N-1  → single GEMM   X_(view) @ uᵀ           (matmul kernel)
  interior     → batched GEMM over merged outer dims   (ttm_interior kernel)

Wrappers zero-pad every tiled dim up to the block multiple (exact for the
contraction dims, sliced off for output dims) and pick TPU-legal tiles:
lane (last) dim tiles are multiples of 128, sublane dims multiples of 8.

``interpret`` defaults to True off-TPU so the same code path validates on
CPU (Pallas interpreter) and compiles to Mosaic on the TPU target.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .matmul import matmul
from .ttm import ttm_interior
from .ttt import ttt_pallas3


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _tile(dim: int, cap: int, align: int) -> int:
    """Tile size ≤ cap, aligned to ``align``, no larger than needed."""
    return min(cap, _round_up(dim, align))


def _pad_to(x: jax.Array, targets: tuple[int, ...]) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(x.shape, targets)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def _as3(x: jax.Array, mode: int) -> jax.Array:
    a = math.prod(x.shape[:mode]) if mode else 1
    b = math.prod(x.shape[mode + 1:]) if mode < x.ndim - 1 else 1
    return x.reshape(a, x.shape[mode], b)


@partial(jax.jit, static_argnames=("mode", "interpret"))
def ttm(x: jax.Array, u: jax.Array, mode: int, *, interpret: bool | None = None) -> jax.Array:
    """Mode-n TTM via Pallas.  u: (R, I_mode).  Returns fp32."""
    interpret = _default_interpret() if interpret is None else interpret
    r, i = u.shape
    assert x.shape[mode] == i, (x.shape, u.shape, mode)
    out_shape = x.shape[:mode] + (r,) + x.shape[mode + 1:]
    n = x.ndim

    if mode == 0:
        x2 = x.reshape(i, -1)
        bm = _tile(r, 128, 8)
        bk = _tile(i, 128, 8)
        bn = _tile(x2.shape[1], 512, 128)
        up = _pad_to(u, (_round_up(r, bm), _round_up(i, bk)))
        xp = _pad_to(x2, (_round_up(i, bk), _round_up(x2.shape[1], bn)))
        y = matmul(up, xp, bm=bm, bn=bn, bk=bk, interpret=interpret)
        y = y[:r, :x2.shape[1]]
    elif mode == n - 1:
        x2 = x.reshape(-1, i)
        m = x2.shape[0]
        bm = _tile(m, 128, 8)
        bk = _tile(i, 128, 8)
        bn = _tile(r, 128, 128)
        xp = _pad_to(x2, (_round_up(m, bm), _round_up(i, bk)))
        ut = _pad_to(u.T, (_round_up(i, bk), _round_up(r, bn)))
        y = matmul(xp, ut, bm=bm, bn=bn, bk=bk, interpret=interpret)
        y = y[:m, :r]
    else:
        x3 = _as3(x, mode)
        a, _, b = x3.shape
        br = _tile(r, 128, 8)
        bi = _tile(i, 128, 8)
        bb = _tile(b, 256, 128)
        up = _pad_to(u, (_round_up(r, br), _round_up(i, bi)))
        xp = _pad_to(x3, (a, _round_up(i, bi), _round_up(b, bb)))
        y = ttm_interior(up, xp, br=br, bb=bb, bi=bi, interpret=interpret)
        y = y[:, :r, :b]
    return y.reshape(out_shape)


@partial(jax.jit, static_argnames=("mode", "interpret"))
def ttt(x: jax.Array, y: jax.Array, mode: int, *, interpret: bool | None = None) -> jax.Array:
    """z (I_mode, R_mode) = contraction of x, y over all modes but ``mode``."""
    interpret = _default_interpret() if interpret is None else interpret
    x3 = _as3(x, mode)
    y3 = _as3(y, mode)
    a, i, b = x3.shape
    _, r, _ = y3.shape
    bi = _tile(i, 128, 8)
    br = _tile(r, 128, 128)   # r is the lane dim of the output
    bb = _tile(b, 256, 128)
    xp = _pad_to(x3, (a, _round_up(i, bi), _round_up(b, bb)))
    yp = _pad_to(y3, (a, _round_up(r, br), _round_up(b, bb)))
    z = ttt_pallas3(xp, yp, bi=bi, br=br, bb=bb, interpret=interpret)
    return z[:i, :r]


@partial(jax.jit, static_argnames=("mode", "interpret"))
def gram(x: jax.Array, mode: int, *, interpret: bool | None = None) -> jax.Array:
    """S (I_mode, I_mode) = Y_(n) Y_(n)ᵀ without unfolding."""
    interpret = _default_interpret() if interpret is None else interpret
    x3 = _as3(x, mode)
    a, i, b = x3.shape
    # one tile size for both output axes (the padded I must tile both ways)
    bi = br = _tile(i, 128, 128)
    bb = _tile(b, 256, 128)
    xp = _pad_to(x3, (a, _round_up(i, bi), _round_up(b, bb)))
    z = ttt_pallas3(xp, xp, bi=bi, br=br, bb=bb, interpret=interpret)
    return z[:i, :i]
