"""Fused S6 (Mamba-1 selective scan) forward Pallas kernel.

The TPU adaptation of the CUDA selective-scan kernel: the (d_inner, N)
recurrent state lives in a VMEM scratch buffer that persists across the
sequential T-chunk grid dimension, so HBM traffic is O(T·d_inner) for
inputs/outputs — the (T, d_inner, N) state expansion that the pure-jnp
chunked scan materializes (ssm.py `_s6_scan`) never leaves VMEM.  That
expansion is N× the payload (N = 16): this kernel removes the dominant
memory-roofline term of the falcon-mamba cells (EXPERIMENTS.md §Perf C).

Grid: (B, d_inner/bd, T/bt) — T innermost (TPU grids run sequentially, so
the scratch state carries); the state resets when the chunk index hits 0.

Forward-only: serving (prefill/decode) needs no backward; training falls
back to the chunked jnp scan (a custom_vjp reverse-scan kernel is the
natural extension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _s6_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scratch):
    @pl.when(pl.program_id(2) == 0)
    def _reset():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[...]                                    # (bd, N)
    bt = x_ref.shape[1]

    def step(tau, h):
        dt_t = dt_ref[0, tau, :]                      # (bd,)
        x_t = x_ref[0, tau, :]
        b_t = b_ref[0, tau, :]                        # (N,)
        c_t = c_ref[0, tau, :]
        da = jnp.exp(dt_t[:, None] * a)               # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, tau, :] = (h * c_t[None, :]).sum(axis=1)
        return h

    h_scratch[...] = jax.lax.fori_loop(0, bt, step, h_scratch[...])


@functools.partial(jax.jit, static_argnames=("bd", "bt", "interpret"))
def s6_scan_fwd(x, dt, bmat, cmat, a, *, bd: int = 512, bt: int = 64,
                interpret: bool | None = None) -> jax.Array:
    """y (B,T,Di) = selective scan.  x/dt: (B,T,Di); bmat/cmat: (B,T,N);
    a: (Di,N) negative.  Di % bd == 0 and T % bt == 0 (ops-level pad)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, di = x.shape
    n = bmat.shape[-1]
    assert di % bd == 0 and t % bt == 0, (x.shape, bd, bt)
    grid = (b, di // bd, t // bt)
    return pl.pallas_call(
        _s6_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, bt, bd), lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, bt, n), lambda bb, dd, tt: (bb, tt, 0)),
            pl.BlockSpec((1, bt, n), lambda bb, dd, tt: (bb, tt, 0)),
            pl.BlockSpec((bd, n), lambda bb, dd, tt: (dd, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda bb, dd, tt: (bb, tt, dd)),
        out_shape=jax.ShapeDtypeStruct((b, t, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, bmat, cmat, a)
