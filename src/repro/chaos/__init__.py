"""Deterministic fault injection for the a-Tucker stack.

The execution layers call :func:`fire` / :func:`poison` at well-known
**seams** — e.g. ``"sweep"`` (core fused dispatch), ``"sweep_out"`` /
``"solve_out"`` (result poisoning points), ``"sketch"`` (adaptive range
finder), ``"wave"`` / ``"wave_job"`` / ``"wave_job_data"`` (serve wave
assembly), ``"worker"`` (serve pump loop).  With no rules installed both
calls are a single list check, so the clean path pays nothing.

A :class:`Rule` is deterministic and seed-addressable: it matches one
seam (plus optional context-field equality via ``match=``), fires on the
``at``-th hit / every ``every``-th hit / with seeded pseudo-probability
``p``, and stops after ``times`` firings.  Actions:

  * ``"raise"`` — raise :class:`ChaosFault` (a ``RuntimeError``; set
    ``message=`` to shape how the taxonomy classifies it),
  * ``"oom"``   — raise :class:`SyntheticOOM`, whose message carries the
    real XLA ``RESOURCE_EXHAUSTED`` marker so the production
    classification + fallback machinery is exercised end to end,
  * ``"nan"``   — make the matching :func:`poison` call return True (the
    seam site corrupts its own data; this module never imports jax),
  * ``"slow"`` / ``"wedge"`` — sleep ``delay_s`` (wedge defaults long,
    for exercising ``TuckerService.stop`` timeout handling).

Install programmatically (:func:`install`, :func:`reset`) or via the
``ATUCKER_CHAOS=`` env var naming a profile from :data:`PROFILES`
(``numerical`` | ``oom`` | ``serve-poison``), which CI's resilience job
uses to rerun ``tests/test_resilience.py`` under each fault family.
"""

from __future__ import annotations

import os
import random
import threading
import time
from dataclasses import dataclass, field

__all__ = [
    "ChaosFault", "PROFILES", "Rule", "SyntheticOOM", "active", "fire",
    "fired", "install", "install_profile", "poison", "reset",
]


class ChaosFault(RuntimeError):
    """A synthetic fault raised by an injector rule."""


class SyntheticOOM(ChaosFault):
    """A synthetic allocation failure whose message mimics XLA's, so the
    taxonomy classifies it exactly like a real device OOM."""

    def __init__(self, seam: str):
        super().__init__(
            f"RESOURCE_EXHAUSTED: Out of memory (synthetic fault injected "
            f"at seam {seam!r})")


@dataclass
class Rule:
    """One injector: *where* (seam + context match), *when* (at/every/p),
    *what* (action), *how often* (times)."""

    seam: str
    action: str                       # raise | oom | nan | slow | wedge
    at: int | None = None             # fire on the at-th hit (0-based)
    every: int | None = None          # fire on every N-th hit
    p: float | None = None            # seeded per-hit probability
    times: int | None = 1             # max firings (None = unlimited)
    seed: int = 0
    match: dict = field(default_factory=dict)   # ctx equality filters
    message: str | None = None        # override for action="raise"
    delay_s: float | None = None      # for slow/wedge
    fired_count: int = 0              # mutated under the registry lock

    def _due(self, hit: int) -> bool:
        if self.times is not None and self.fired_count >= self.times:
            return False
        due = self.at is None and self.every is None and self.p is None
        if self.at is not None and hit == self.at:
            due = True
        if self.every is not None and self.every > 0 and \
                hit % self.every == 0:
            due = True
        if self.p is not None:
            roll = random.Random(f"{self.seed}:{self.seam}:{hit}").random()
            due = due or roll < self.p
        return due

    def _matches(self, ctx: dict) -> bool:
        return all(ctx.get(k) == v for k, v in self.match.items())


_lock = threading.Lock()
_rules: list[Rule] = []
_hits: dict[str, int] = {}
_fired: dict[str, int] = {}


def active() -> bool:
    """Whether any injector rules are installed."""
    return bool(_rules)


def install(rule: "Rule | list[Rule] | tuple[Rule, ...]"):
    """Register an injector rule, or an iterable of them; returns what was
    passed (for later inspection of ``fired_count``)."""
    with _lock:
        if isinstance(rule, Rule):
            _rules.append(rule)
        else:
            _rules.extend(rule)
    return rule


def reset() -> None:
    """Remove every rule and zero the hit/fired accounting."""
    with _lock:
        _rules.clear()
        _hits.clear()
        _fired.clear()


def fired() -> dict[str, int]:
    """``{"seam:action": count}`` of faults actually injected so far."""
    with _lock:
        return dict(_fired)


def _consume(seam: str, ctx: dict, want_nan: bool) -> Rule | None:
    """Advance the seam's hit counter and return the first due rule of the
    requested family (data-poisoning vs. control-flow), marking it fired."""
    with _lock:
        if not _rules:
            return None
        hit = _hits.get(seam, 0)
        _hits[seam] = hit + 1
        for r in _rules:
            if r.seam != seam or (r.action == "nan") != want_nan:
                continue
            if r._matches(ctx) and r._due(hit):
                r.fired_count += 1
                key = f"{seam}:{r.action}"
                _fired[key] = _fired.get(key, 0) + 1
                return r
        return None


def fire(seam: str, **ctx) -> None:
    """Injection point for control-flow faults (raise/oom/slow/wedge).
    A no-op unless a due rule matches this seam + context."""
    if not _rules:
        return
    r = _consume(seam, ctx, want_nan=False)
    if r is None:
        return
    if r.action == "oom":
        raise SyntheticOOM(seam)
    if r.action == "raise":
        raise ChaosFault(
            r.message or f"synthetic fault injected at seam {seam!r}")
    if r.action in ("slow", "wedge"):
        time.sleep(r.delay_s if r.delay_s is not None
                   else (30.0 if r.action == "wedge" else 0.05))
        return
    raise ValueError(f"unknown chaos action {r.action!r}")


def poison(seam: str, **ctx) -> bool:
    """Injection point for data corruption: returns True when the seam
    site should replace its data with NaNs (the caller does the actual
    poisoning — this module stays jax-free)."""
    if not _rules:
        return False
    return _consume(seam, ctx, want_nan=True) is not None


#: env-selectable fault families for CI (``ATUCKER_CHAOS=<name>``); each
#: fault either gets recovered by a fallback-ladder hop / wave isolation
#: or surfaces as a classified TuckerError — asserted by
#: tests/test_resilience.py's profile scenario.
PROFILES: dict[str, list[Rule]] = {
    # poison one fused sweep's outputs → NumericalError → als→eig hop
    "numerical": [Rule(seam="sweep_out", action="nan", at=0, times=1)],
    # synthetic device OOM on one dispatch → ResourceError → donate-off /
    # replan-under-tighter-cap hops
    "oom": [Rule(seam="sweep", action="oom", at=0, times=1)],
    # one serve request poisons every fused wave containing it → wave
    # bisection quarantines it alone, the rest of the wave completes
    "serve-poison": [Rule(seam="wave_job", action="raise", times=None,
                          match={"rid": 2},
                          message="synthetic poisoned request")],
}


def install_profile(name: str) -> list[Rule]:
    """Install the named :data:`PROFILES` entry (fresh Rule copies, so a
    profile can be installed repeatedly)."""
    try:
        rules = PROFILES[name]
    except KeyError:
        raise ValueError(
            f"unknown chaos profile {name!r}; "
            f"known: {sorted(PROFILES)}") from None
    out = []
    for r in rules:
        out.append(install(Rule(
            seam=r.seam, action=r.action, at=r.at, every=r.every, p=r.p,
            times=r.times, seed=r.seed, match=dict(r.match),
            message=r.message, delay_s=r.delay_s)))
    return out


_env = os.environ.get("ATUCKER_CHAOS")
if _env:
    # opt-in only ever via the env var; a bad name should fail loudly at
    # import so CI misconfiguration can't silently run a clean suite
    install_profile(_env)
