"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend stubbed (input_specs supplies patch
embeddings), InternLM2 backbone.  [arXiv:2404.16821]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92553,
    n_patches=1024,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_patches=16, dtype="float32")
