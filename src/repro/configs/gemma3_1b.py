"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144, 5:1 local:global (window 512), qk-norm, 128k rope.
[hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    sliding_window=512, local_global_pattern=(5, 1),
    rope_theta=10_000.0, rope_theta_global=1e6,
    qk_norm=True, post_norm=True, embed_scale=True,
    act="gelu", tie_embeddings=True, dtype="bfloat16",
)

SMOKE = CONFIG.with_(
    n_layers=6, d_model=48, n_heads=2, n_kv_heads=1, head_dim=24,
    d_ff=96, vocab=256, sliding_window=8, dtype="float32")
