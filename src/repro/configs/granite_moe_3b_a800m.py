"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512, vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab=256, n_experts=8, top_k=2, capacity_factor=8.0, dtype="float32")

# §Perf-tuned recipe (EXPERIMENTS.md): context-parallel attention (head
# counts 24/8 don't divide model=16) + tight MoE capacity.
TUNED = CONFIG.with_(seq_shard_attn=True, capacity_factor=1.0)
