"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU.  [arXiv:2404.14219]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064,
    act="silu", tie_embeddings=False, dtype="bfloat16",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, dtype="float32")
