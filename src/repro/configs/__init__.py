"""Assigned-architecture configs.  ``get(name)`` → full ModelConfig;
``get_smoke(name)`` → reduced same-family config for CPU smoke tests.

Every module defines CONFIG and SMOKE.  LONG_CONTEXT_OK marks the archs that
run the ``long_500k`` shape (sub-quadratic / bounded-cache; DESIGN.md §5).
"""

from __future__ import annotations

import importlib

ARCHS = (
    "mixtral_8x22b",
    "granite_moe_3b_a800m",
    "gemma3_1b",
    "gemma2_9b",
    "minitron_4b",
    "phi3_mini_3p8b",
    "falcon_mamba_7b",
    "zamba2_1p2b",
    "seamless_m4t_medium",
    "internvl2_2b",
)

# canonical ids (assignment spelling) → module names
ALIASES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "gemma3-1b": "gemma3_1b",
    "gemma2-9b": "gemma2_9b",
    "minitron-4b": "minitron_4b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-2b": "internvl2_2b",
}

LONG_CONTEXT_OK = {
    "mixtral_8x22b",      # SWA window caps the cache
    "gemma3_1b",          # 5:1 local:global
    "gemma2_9b",          # 1:1 local:global
    "falcon_mamba_7b",    # O(1) state
    "zamba2_1p2b",        # hybrid
}

# archs with no decode step for a given shape kind (none here are
# encoder-only; seamless is enc-dec and decodes its decoder)
NO_DECODE: set[str] = set()


def _mod(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    return _mod(name).SMOKE


def canonical(name: str) -> str:
    return ALIASES.get(name, name)


def supports_shape(name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return canonical(name) in LONG_CONTEXT_OK
    return True
