"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free, ssm_state=16
vocab=65024 — mamba1 architecture.  [arXiv:2410.05355]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    ssm_version=1, ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=False, dtype="bfloat16", fsdp=True,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, vocab=256, ssm_chunk=16,
    dtype="float32", fsdp=False)
