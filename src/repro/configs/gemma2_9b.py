"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, local(4096):global alternating, logit softcaps.
[arXiv:2408.00118]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000,
    sliding_window=4096, local_global_pattern=(1, 1),
    attn_softcap=50.0, final_softcap=30.0,
    post_norm=True, embed_scale=True,
    act="gelu", tie_embeddings=True, dtype="bfloat16", fsdp=True,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, sliding_window=8, dtype="float32", fsdp=False)
