"""zamba2-1.2b [hybrid]: 38L d_model=2048 Mamba2 backbone, shared attn
block (32H kv=32, d_ff=8192) every 6 layers, ssm_state=64, vocab=32000.
[arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000,
    ssm_version=2, ssm_state=64, ssm_conv=4, ssm_expand=2,
    ssm_head_dim=64, ssm_chunk=256,
    shared_attn_every=6,
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = CONFIG.with_(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, ssm_state=8, ssm_head_dim=16, ssm_chunk=16,
    shared_attn_every=2, dtype="float32")
