"""seamless-m4t-medium [audio]: 12L(enc)+12L(dec) d_model=1024 16H
d_ff=4096 vocab=256206 — enc-dec backbone; modality frontend stubbed
(input_specs supplies frame embeddings).  [arXiv:2308.11596]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256206,
    norm="layernorm", act="gelu",
    tie_embeddings=True, dtype="bfloat16",
)

SMOKE = CONFIG.with_(
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, dtype="float32")
