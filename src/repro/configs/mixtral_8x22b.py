"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA.  [arXiv:2401.04088]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    n_experts=8, top_k=2,
    sliding_window=4096, local_global_pattern=(1, 0),   # pure SWA
    rope_theta=1e6, tie_embeddings=False,
    dtype="bfloat16", fsdp=True,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, n_experts=4, top_k=2, sliding_window=16,
    capacity_factor=8.0, dtype="float32", fsdp=False)

# §Perf-tuned recipe (EXPERIMENTS.md): tight MoE capacity; pair with
# microbatch=16 (launch-level) to fit 16 GB/chip.  seq-shard variants
# REGRESSED collectives for this arch (48 heads shard cleanly) — B2/B7.
TUNED = CONFIG.with_(capacity_factor=1.0)
