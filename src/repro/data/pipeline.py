"""Deterministic, shardable, resumable data pipeline.

Every batch is a pure function of ``(seed, step)`` via counter-based RNG
(threefry fold_in) — resumability and elasticity fall out for free:
  * restart at step k ⇒ identical batch k (bit-exact resume),
  * a different mesh re-derives its per-shard slice from the same global
    batch (elastic re-sharding after degraded restart).

Two sources: synthetic token streams (default; zipf-ish marginals so losses
move) and a memory-mapped token file (``TokenFileSource``) for real corpora.
Modality stubs (vlm patches / audio frames) are generated to the same
deterministic rule, matching the brief's frontend-stub contract.
"""

from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    source: str = "synthetic"        # synthetic | file
    path: str | None = None          # token file (np.uint16/np.int32 binary)


class SyntheticLM:
    """Deterministic synthetic LM batches for a (cfg, shape) cell."""

    def __init__(self, dc: DataConfig, cfg: ModelConfig, seq_len: int,
                 global_batch: int):
        self.dc, self.cfg = dc, cfg
        self.seq_len, self.global_batch = seq_len, global_batch

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.dc.seed), step)
        b, t, v = self.global_batch, self.seq_len, self.cfg.vocab
        kt, kp = jax.random.split(key)
        # zipf-ish marginal: squash uniform^3 toward low ids, plus a copy
        # motif (periodic repetition) so models can actually reduce loss
        u = jax.random.uniform(kt, (b, t + 1))
        toks = (u ** 3 * (v - 1)).astype(jnp.int32)
        period = 7
        base = toks[:, :period]
        reps = jnp.tile(base, (1, (t + 1) // period + 1))[:, : t + 1]
        mix = jax.random.bernoulli(kp, 0.5, (b, 1))
        toks = jnp.where(mix, reps, toks)
        out = {"tokens": toks}
        if self.cfg.family == "vlm":
            out["patches"] = jax.random.normal(
                kp, (b, self.cfg.n_patches, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "encdec":
            out = {"frames": jax.random.normal(kp, (b, t, self.cfg.d_model), jnp.float32),
                   "tokens": toks}
        return out


class TokenFileSource:
    """Memory-mapped token corpus; window sampling is (seed, step)-pure."""

    def __init__(self, dc: DataConfig, cfg: ModelConfig, seq_len: int,
                 global_batch: int):
        assert dc.path, "TokenFileSource needs DataConfig.path"
        self.tokens = np.memmap(dc.path, dtype=np.int32, mode="r")
        self.dc, self.cfg = dc, cfg
        self.seq_len, self.global_batch = seq_len, global_batch

    def batch_at(self, step: int) -> dict:
        n = len(self.tokens) - (self.seq_len + 1)
        rng = np.random.default_rng((self.dc.seed, step))
        starts = rng.integers(0, n, size=self.global_batch)
        toks = np.stack([self.tokens[s: s + self.seq_len + 1] for s in starts])
        return {"tokens": jnp.asarray(toks % self.cfg.vocab, jnp.int32)}


def make_source(dc: DataConfig, cfg: ModelConfig, shape: ShapeConfig):
    cls = TokenFileSource if dc.source == "file" else SyntheticLM
    return cls(dc, cfg, shape.seq_len, shape.global_batch)
