"""data substrate."""
