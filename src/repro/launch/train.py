"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --shape train_4k --steps 1000 [--compress] [--tuned]

On a real fleet this runs under one process per host with
jax.distributed.initialize(); on this box it drives the same code path on
the local device(s).  Checkpoints + (seed, step)-pure data give exact
resume; `--compress` enables the Tucker cross-pod gradient codec when a
'pod' axis exists.
"""

import argparse

import jax

from .. import configs
from ..data.pipeline import DataConfig, make_source
from ..models import build
from ..models.config import SHAPES, ShapeConfig
from ..optim.adamw import AdamW, cosine_schedule
from ..optim.grad_compress import CompressionConfig
from ..train.train_step import (init_state, make_compressed_train_step,
                                make_train_step)
from ..train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="assigned shape id (default: CPU-sized tiny shape)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--tuned", action="store_true",
                    help="use the §Perf-tuned recipe where defined")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    if args.smoke:
        cfg = configs.get_smoke(args.arch)
    elif args.tuned:
        import importlib
        mod = importlib.import_module(
            f"repro.configs.{configs.canonical(args.arch)}")
        cfg = getattr(mod, "TUNED", mod.CONFIG)
    else:
        cfg = configs.get(args.arch)

    shape = SHAPES[args.shape] if args.shape else \
        ShapeConfig("cpu_tiny", 128, 8, "train")
    print(f"arch={cfg.name} params≈{cfg.param_count():,} "
          f"shape={shape.name} devices={len(jax.devices())}")

    bundle = build(cfg)
    src = make_source(DataConfig(seed=0), cfg, shape)
    opt = AdamW(lr=cosine_schedule(args.lr, args.steps // 10, args.steps))

    mesh = None
    comp = CompressionConfig() if args.compress else None
    n_pods = 1
    if args.compress:
        import numpy as np
        devs = len(jax.devices())
        n_pods = 2 if devs % 2 == 0 and devs > 1 else 1
        mesh = jax.make_mesh((n_pods, devs // n_pods), ("pod", "data"))

    state = init_state(bundle, opt, jax.random.PRNGKey(0),
                       compression=comp, n_pods=n_pods)
    if args.compress and mesh is not None:
        step = make_compressed_train_step(bundle, opt, comp, mesh,
                                          n_micro=args.microbatch)
    else:
        step = make_train_step(bundle, opt, n_micro=args.microbatch)

    tc = TrainerConfig(total_steps=args.steps,
                       ckpt_every=max(20, args.steps // 4),
                       log_every=10, ckpt_dir=args.ckpt_dir)
    hist = Trainer(tc, step, state, src,
                   log_path=f"{args.ckpt_dir}/metrics.jsonl").run()
    print(f"done: loss {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
