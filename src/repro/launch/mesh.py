"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state.  Single pod = 16×16 (256 chips, TPU v5e pod), multi-pod = 2 pods.
``pod`` and ``data`` are both batch-parallel axes; ``model`` carries
TP/EP/SP.  Hardware constants for the roofline live here too.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over however many (fake) devices the test process has."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0
        return jax.make_mesh((2, n // 4, 2), ("pod", "data", "model"))
    return jax.make_mesh((n // 2, 2), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
