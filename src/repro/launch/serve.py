"""Serving driver: batched requests through the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --smoke \
        --requests 6 --max-new 16 [--ckpt <dir>]
"""

import argparse

import jax

from .. import configs
from ..models import build
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir to restore")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    if args.ckpt:
        from ..checkpoint.checkpointer import Checkpointer
        restored = Checkpointer(args.ckpt).restore(params)
        if restored:
            params, step = restored
            print(f"restored params at step {step}")

    eng = ServeEngine(bundle, params, batch_slots=args.slots,
                      max_len=args.max_len)
    reqs = [Request(prompt=[1 + i, 2, 3, 4 + i], max_new_tokens=args.max_new,
                    rid=i) for i in range(args.requests)]
    import time
    t0 = time.perf_counter()
    outs = eng.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.output) for r in outs)
    print(f"{tok} tokens in {dt:.2f}s ({tok/dt:.1f} tok/s across "
          f"{args.slots} slots)")
    for r in outs:
        print(f"  req {r.rid}: {r.prompt} → {r.output}")


if __name__ == "__main__":
    main()
