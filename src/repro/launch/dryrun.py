import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------------
"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, record memory/cost analysis + collective schedule.

  PYTHONPATH=src python -m repro.launch.dryrun                  # full sweep
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
      --shape train_4k --multi-pod both --artifacts artifacts/dryrun

Artifacts land in <artifacts>/<mesh>/<arch>__<shape>.json and feed
``repro.roofline.analysis`` / EXPERIMENTS.md.  Already-present cells are
skipped (resumable sweep); --force recomputes.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .. import configs
from ..models import build, input_specs
from ..models.config import SHAPES
from ..models.registry import cache_specs
from ..models.shardings import (batch_specs, cache_specs_tree, param_specs,
                                to_shardings)
from ..models import shardings as shardings_mod
from ..optim.adamw import AdamW
from ..roofline import hlo_walk
from ..roofline.analysis import Roofline, model_flops_for
from .mesh import data_axes, make_production_mesh

# grad-accumulation chunks per (shape kind); keeps live activations bounded
N_MICRO = {"train": 8, "prefill": 1, "decode": 1}


def _opt_state_specs(pspecs, optimizer):
    """AdamW m/v mirror the param sharding; count is replicated."""
    from ..train.train_step import TrainState  # noqa: F401
    return pspecs


def build_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False,
               shape_override=None, cfg_override=None):
    """Returns (jitted fn, abstract_args, cfg, shape) for one cell.

    ``smoke=True`` swaps in the reduced config (dryrun-lite CI path);
    ``shape_override``/``cfg_override`` let tests shrink the cell further.
    """
    cfg = cfg_override or (configs.get_smoke(arch) if smoke else configs.get(arch))
    shape = shape_override or SHAPES[shape_name]
    bundle = build(cfg)
    optimizer = AdamW(lr=1e-4)

    daxes = data_axes(mesh)
    fsdp = daxes if cfg.fsdp else None
    params_abs = bundle.abstract_params()
    repl = (("w_gate", "w_up", "w_down") if cfg.moe_capacity_sharding else ())
    pspecs = param_specs(params_abs, mesh, fsdp_axes=fsdp, replicate_names=repl)
    pshard = to_shardings(pspecs, mesh)

    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        from ..train.train_step import TrainState, _accumulate_grads

        n_micro = shape.microbatch or N_MICRO["train"]
        if shape.global_batch % n_micro:
            n_micro = 1

        def step(params, opt_state, batch):
            grads, metrics = _accumulate_grads(
                lambda p, b: bundle.loss(p, b), params, batch, n_micro)
            new_params, new_opt, om = optimizer.update(grads, opt_state, params)
            return new_params, new_opt, {**metrics, **om}

        opt_abs = jax.eval_shape(optimizer.init, params_abs)
        oshard = jax.tree.map(
            lambda _: None, opt_abs)  # placeholder; real spec below
        from ..optim.adamw import AdamWState
        oshard = AdamWState(
            count=NamedSharding(mesh, P()),
            m=to_shardings(pspecs, mesh),
            v=to_shardings(pspecs, mesh))
        bshard = to_shardings(batch_specs(specs["batch"], mesh), mesh)
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, specs["batch"]), cfg, shape

    if shape.kind == "prefill":
        cshard = to_shardings(cache_specs_tree(specs["cache"], mesh), mesh)
        bshard = to_shardings(batch_specs(specs["batch"], mesh), mesh)

        def pre(params, batch, cache):
            return bundle.prefill(params, batch, cache)

        fn = jax.jit(pre, in_shardings=(pshard, bshard, cshard),
                     donate_argnums=(2,))
        return fn, (params_abs, specs["batch"], specs["cache"]), cfg, shape

    # decode
    total = shape.seq_len
    cshard = to_shardings(cache_specs_tree(specs["cache"], mesh), mesh)
    tshard = to_shardings(batch_specs(specs["token"], mesh), mesh)

    def dec(params, token, cache, pos):
        return bundle.decode(params, token, cache, pos, total)

    fn = jax.jit(dec,
                 in_shardings=(pshard, tshard, cshard, NamedSharding(mesh, P())),
                 donate_argnums=(2,))
    return fn, (params_abs, specs["token"], specs["cache"], specs["pos"]), cfg, shape


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             art_dir: Path, *, force: bool = False, verbose: bool = True):
    out_path = art_dir / mesh_name / f"{configs.canonical(arch)}__{shape_name}.json"
    if out_path.exists() and not force:
        if verbose:
            print(f"[dryrun] skip (cached): {arch} × {shape_name} × {mesh_name}")
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    shardings_mod.set_activation_mesh(mesh)
    fn, abs_args, cfg, shape = build_cell(arch, shape_name, mesh)
    with mesh:
        lowered = fn.lower(*abs_args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    cost = compiled.cost_analysis() or {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, f):
                mem[f] = int(getattr(ma, f))
    except Exception as e:                                    # pragma: no cover
        mem["error"] = str(e)

    hlo = compiled.as_text()
    try:
        import zstandard
        (out_path.parent / (out_path.stem + ".hlo.zst")).write_bytes(
            zstandard.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception:
        pass
    # trip-count-aware walker: XLA cost_analysis visits while bodies once,
    # so scanned programs under-count by the trip factor (see hlo_walk.py)
    walked = hlo_walk.analyze(hlo)
    coll = {k: walked[k] for k in hlo_walk.COLLECTIVES}
    coll["count"] = walked["coll_count"]
    coll["total"] = walked["coll_total"]
    chips = mesh.size
    mf = model_flops_for(cfg, shape)
    # memory term uses ESSENTIAL traffic (what must cross HBM under TPU-level
    # fusion); the upper bound (all top-level op I/O) is recorded alongside
    roof = Roofline.from_cell(
        arch=configs.canonical(arch), shape=shape_name, mesh_name=mesh_name,
        chips=chips,
        cost={"flops": walked["flops"], "bytes accessed": walked["traffic_ess"]},
        collectives=coll, model_flops=mf,
        peak_bytes=float(mem.get("temp_size_in_bytes", 0)
                         + mem.get("argument_size_in_bytes", 0)))

    rec = {
        "arch": configs.canonical(arch), "shape": shape_name,
        "mesh": mesh_name, "chips": chips,
        "lower_s": t_lower, "compile_s": t_compile,
        "walked": {k: float(v) for k, v in walked.items()},
        "cost_analysis_xla": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "memory_analysis": mem,
        "collectives": coll,
        "model_flops": mf,
        "roofline": {
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s, "bottleneck": roof.bottleneck,
            "useful_ratio": roof.useful_ratio,
        },
        "hlo_lines": hlo.count("\n"),
    }
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        m = rec["roofline"]
        print(f"[dryrun] OK {arch} × {shape_name} × {mesh_name}: "
              f"compile {t_compile:.1f}s, bottleneck={m['bottleneck']}, "
              f"compute={m['compute_s']:.3e}s mem={m['memory_s']:.3e}s "
              f"coll={m['collective_s']:.3e}s useful={m['useful_ratio']:.2f} "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB/dev")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(configs.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    art_dir = Path(args.artifacts)
    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                if not configs.supports_shape(arch, shape_name):
                    print(f"[dryrun] SKIP {arch} × {shape_name} "
                          f"(full-attention arch; see DESIGN.md §5)")
                    continue
                try:
                    run_cell(arch, shape_name, mesh, mesh_name, art_dir,
                             force=args.force)
                except Exception:
                    failures.append((arch, shape_name, mesh_name))
                    print(f"[dryrun] FAIL {arch} × {shape_name} × {mesh_name}")
                    traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells compiled")


if __name__ == "__main__":
    main()
