"""checkpoint substrate."""
