"""Sharded, atomic, async checkpointing with an optional Tucker-compressed
tier (a-Tucker as the checkpoint codec — DESIGN.md §4.2).

Layout (one directory per step, atomic rename commit):

  <dir>/step_000123.tmp/ … → <dir>/step_000123/
      meta.json            {step, format, leaf index}
      arr_<i>.npy          one file per pytree leaf (np.save)
      tucker_<i>.npz       compressed leaves: core + factors (+ shape)

Async: ``save`` snapshots to host memory synchronously (cheap) and writes
on a background thread; ``wait`` joins.  ``restore`` loads the newest valid
step; half-written directories (no committed rename) are ignored — the
crash-recovery path.  On multi-host fleets each host writes its own shard
files (process-local leaves); this box is single-process so the full tree
lands here.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, compress_cfg=None, blocking: bool = False):
        """Snapshot now, write in background.  ``compress_cfg`` — a
        repro.optim.grad_compress.CompressionConfig — switches eligible ≥3-D
        leaves to the Tucker codec (cheap frequent safety tier)."""
        flat, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in flat]
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, str(treedef), compress_cfg),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, leaves: list[np.ndarray], treedef: str,
               compress_cfg):
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        index = []
        for i, leaf in enumerate(leaves):
            if compress_cfg is not None and _tucker_eligible(compress_cfg, leaf):
                _save_tucker(tmp / f"tucker_{i}.npz", leaf, compress_cfg)
                index.append({"kind": "tucker", "file": f"tucker_{i}.npz",
                              "dtype": str(leaf.dtype), "shape": list(leaf.shape)})
            else:
                to_save = leaf
                if leaf.dtype.kind == "V" or "bfloat16" in str(leaf.dtype) or \
                        "float8" in str(leaf.dtype):
                    # numpy can't round-trip ml_dtypes through .npy —
                    # store a same-width uint view, re-view on restore
                    to_save = leaf.view({1: np.uint8, 2: np.uint16,
                                         4: np.uint32}[leaf.dtype.itemsize])
                np.save(tmp / f"arr_{i}.npy", to_save)
                index.append({"kind": "raw", "file": f"arr_{i}.npy",
                              "dtype": str(leaf.dtype), "shape": list(leaf.shape)})
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "time": time.time(), "treedef": treedef,
             "leaves": index}))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)             # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "meta.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: int | None = None) -> tuple[Any, int] | None:
        """Restore into the structure of ``tree_like``.  None → nothing valid."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        d = self.dir / f"step_{step:09d}"
        meta = json.loads((d / "meta.json").read_text())
        flat, treedef = jax.tree_util.tree_flatten(tree_like)
        assert len(flat) == len(meta["leaves"]), \
            f"checkpoint has {len(meta['leaves'])} leaves, tree has {len(flat)}"
        leaves = []
        for i, info in enumerate(meta["leaves"]):
            if info["kind"] == "tucker":
                arr = _load_tucker(d / info["file"])
            else:
                arr = np.load(d / info["file"])
            # jnp.dtype resolves extended types (bfloat16) that plain numpy
            # dtype strings don't; uint-stored views are re-viewed first
            import jax.numpy as jnp
            want = jnp.dtype(info["dtype"])
            if arr.dtype != want and arr.dtype.kind == "u" and \
                    arr.dtype.itemsize == want.itemsize:
                arr = arr.view(want)
            leaves.append(jnp.asarray(arr).astype(want))
        return jax.tree_util.tree_unflatten(treedef, leaves), step


# ---------------------------------------------------------------------------
# Tucker codec
# ---------------------------------------------------------------------------

def _tucker_eligible(cfg, leaf: np.ndarray) -> bool:
    return cfg.ranks_for(tuple(leaf.shape)) is not None and \
        np.issubdtype(leaf.dtype, np.floating)


def _save_tucker(path: Path, leaf: np.ndarray, cfg):
    import jax.numpy as jnp
    from ..core import sthosvd
    ranks = cfg.ranks_for(tuple(leaf.shape))
    res = sthosvd(jnp.asarray(leaf, jnp.float32), ranks, methods="auto")
    tt = res.tucker
    np.savez(path, core=np.asarray(tt.core),
             n_factors=len(tt.factors),
             **{f"factor_{i}": np.asarray(u) for i, u in enumerate(tt.factors)})


def _load_tucker(path: Path) -> np.ndarray:
    from ..core import tensor_ops as T
    import jax.numpy as jnp
    z = np.load(path)
    factors = [jnp.asarray(z[f"factor_{i}"]) for i in range(int(z["n_factors"]))]
    return np.asarray(T.reconstruct(jnp.asarray(z["core"]), factors))
