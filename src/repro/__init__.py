"""repro: a-Tucker (input-adaptive, matricization-free Tucker decomposition)
as a first-class feature of a multi-pod JAX LM training/serving framework.

Subpackages: core (the paper), kernels (Pallas TPU), models (10-arch zoo),
optim / train / serve / checkpoint / data (substrate), configs (assigned
architectures), launch (mesh + dry-run + drivers), roofline (HLO analysis).
"""

from . import _jax_compat  # noqa: F401  (side effect: old-JAX shard_map shim)

__version__ = "1.0.0"
