"""AdamW with global-norm clipping and schedules (built here; no optax in
this environment).  Pure pytree transforms, shard-friendly (element-wise)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float | None = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def _lr(self, count):
        return self.lr(count) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads: Any, state: AdamWState, params: Any):
        """Returns (new_params, new_state, metrics)."""
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        count = state.count + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            mh = m_new / bc1
            vh = v_new / bc2
            step = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(count, new_m, new_v), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(1, warmup)
        frac = jnp.clip((c - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(c < warmup, warm, cos)
    return lr
