"""Tucker gradient compression for cross-pod data parallelism.

PowerSGD-style generalization of a-Tucker to distributed training: keep
*shared* Tucker factors ``U^(n)`` per eligible gradient tensor and exchange
only the small core

    core_i = g_i ×_1 U^(1)ᵀ ··· ×_N U^(N)ᵀ        (linear in g_i!)

so ``psum(core_i) == core(psum(g_i))`` and the cross-pod all-reduce moves
``∏R_n / ∏I_n`` of the dense bytes.  Per-device error feedback keeps the
update unbiased over time; factors are refreshed every ``refresh_every``
steps by the *distributed st-HOSVD* — per-mode Gram partials + psum, i.e.
the paper's EIG solver run mode-wise with sequential shrinking.  Because the
psum'd Gram is identical on every pod and ``eigh`` is deterministic, all
pods hold bit-identical factors without ever communicating them; only the
small Grams travel, amortized over the refresh interval.

Eligibility: tensors with ndim ≥ 3 and size ≥ min_size (a-Tucker targets
dense tensors; scalars/matrices pass through dense).  With scan-over-layers
every big LM gradient is naturally ≥ 3-D: (L, d, f), (L, E, d, f), …

The refresh decision is STATIC: the trainer compiles two step variants
(refresh / no-refresh) and picks per step at Python level — no collectives
under data-dependent control flow.

All functions are pure pytree→pytree transforms usable inside a
``shard_map(axis_names={'pod'})`` manual section of the train step, or with
``axis_name=None`` as a single-process compressor (checkpoint compression,
tests).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..core import tensor_ops as T


@dataclass(frozen=True)
class CompressionConfig:
    rank_fraction: float = 0.25    # R_n = ceil(rank_fraction * I_n) on compressed modes
    max_rank: int = 64
    min_size: int = 65536          # below this, grads go dense
    min_ndim: int = 3
    refresh_every: int = 20        # factor refresh cadence (steps)
    skip_first_mode: bool = True   # (L, d, f): layer/scan mode stays full rank
    enabled: bool = True

    def ranks_for(self, shape: tuple[int, ...]) -> tuple[int, ...] | None:
        if not self.enabled or len(shape) < self.min_ndim:
            return None
        if math.prod(shape) < self.min_size:
            return None
        ranks = []
        for m, d in enumerate(shape):
            if self.skip_first_mode and m == 0:
                ranks.append(d)   # identity mode (scan/layer axis)
            else:
                ranks.append(max(1, min(self.max_rank,
                                        int(math.ceil(self.rank_fraction * d)))))
        if math.prod(ranks) >= math.prod(shape):
            return None           # no win — stay dense
        return tuple(ranks)


def init_state(cfg: CompressionConfig, grads_like: Any) -> Any:
    """Per-leaf state: {'factors': [U^(n) | None per mode], 'error': 0s}.

    Factor entries start as zeros; the trainer must run its FIRST step with
    ``refresh=True`` so they are populated before use.
    """
    def leaf_state(g):
        ranks = cfg.ranks_for(tuple(g.shape))
        if ranks is None:
            return EMPTY
        factors = [
            None if r == d else jnp.zeros((d, r), dtype=jnp.float32)
            for d, r in zip(g.shape, ranks)
        ]
        return {"factors": factors, "error": jnp.zeros(g.shape, jnp.float32)}

    return jax.tree.map(leaf_state, grads_like)


class _Empty:
    """Sentinel pytree leaf: 'this gradient is not compressed'."""
    def __repr__(self):
        return "EMPTY"


EMPTY = _Empty()
jax.tree_util.register_pytree_node(
    _Empty, lambda e: ((), None), lambda aux, ch: EMPTY)


def _project(g, factors):
    """core = g ×_n U^(n)ᵀ over compressed modes."""
    y = g
    for mode, u in enumerate(factors):
        if u is not None:
            y = T.ttm(y, u.T.astype(y.dtype), mode)
    return y


def _expand(core, factors):
    y = core
    for mode, u in enumerate(factors):
        if u is not None:
            y = T.ttm(y, u.astype(y.dtype), mode)
    return y


def _refresh_factors(g_fb, factors, axis_name: str | None):
    """Distributed st-HOSVD-EIG refresh with sequential shrinking."""
    y = g_fb
    new_factors = []
    for mode, u in enumerate(factors):
        if u is None:
            new_factors.append(None)
            continue
        r = u.shape[1]
        s = T.gram(y, mode)
        if axis_name is not None:
            s = jax.lax.psum(s, axis_name)
        _, vecs = jnp.linalg.eigh(s)
        un = vecs[:, -r:][:, ::-1]
        new_factors.append(un)
        y = T.ttm(y, un.T, mode)     # sequential shrink (st-HOSVD semantics)
    return new_factors


def compressed_bytes(cfg: CompressionConfig, shape: tuple[int, ...]) -> tuple[int, int]:
    """(dense, compressed) all-reduce bytes per step for a grad of ``shape``
    (fp32 wire format; Gram psums amortized over the refresh interval)."""
    dense = 4 * math.prod(shape)
    ranks = cfg.ranks_for(shape)
    if ranks is None:
        return dense, dense
    core = 4 * math.prod(ranks)
    gram_amort = sum(4 * d * d for d, r in zip(shape, ranks) if r != d)
    return dense, core + gram_amort // max(1, cfg.refresh_every)


def compress_psum(
    cfg: CompressionConfig,
    grads: Any,
    state: Any,
    *,
    refresh: bool,
    axis_name: str | None = "pod",
) -> tuple[Any, Any, dict]:
    """Compressed cross-``axis_name`` gradient mean with error feedback.

    Returns ``(reduced_grads, new_state, stats)``.  ``refresh`` is static:
    True recomputes the shared factors from this step's (feedback-corrected)
    gradients via psum'd mode-wise Grams before projecting.
    """
    n_peers = jax.lax.psum(1, axis_name) if axis_name is not None else 1

    acc = {"dense": 0, "compressed": 0}

    def one(g, st):
        if isinstance(st, _Empty) or st is None:
            b = g.size * g.dtype.itemsize
            acc["dense"] += b
            acc["compressed"] += b
            out = jax.lax.psum(g, axis_name) / n_peers if axis_name is not None else g
            return out, EMPTY

        g_fb = g.astype(jnp.float32) + st["error"]
        factors = (_refresh_factors(g_fb, st["factors"], axis_name)
                   if refresh else st["factors"])

        core = _project(g_fb, factors)
        if axis_name is not None:
            core = jax.lax.psum(core, axis_name) / n_peers
        g_hat = _expand(core, factors)
        err = g_fb - _expand(_project(g_fb, factors), factors)

        d, c = compressed_bytes(cfg, tuple(g.shape))
        acc["dense"] += d
        acc["compressed"] += c
        return g_hat.astype(g.dtype), {"factors": factors, "error": err}

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    outs = [one(g, s) for g, s in zip(flat_g, flat_s)]
    new_grads = treedef.unflatten([o[0] for o in outs])
    new_state = treedef.unflatten([o[1] for o in outs])
    stats = {"bytes_dense": acc["dense"], "bytes_compressed": acc["compressed"],
             "ratio": acc["dense"] / max(1, acc["compressed"])}
    return new_grads, new_state, stats


# ---------------------------------------------------------------------------
# shard_map plumbing: error buffers are PER-POD state (sharded on a stacked
# leading axis); factors are replicated (they come out of psum'd Grams, so
# vma inference proves replication).
# ---------------------------------------------------------------------------

def _is_state_leaf(x):
    return isinstance(x, _Empty) or (isinstance(x, dict) and "error" in x)


def state_specs(state: Any, pod_axis: str = "pod") -> Any:
    """PartitionSpec pytree for the compressor state under shard_map."""
    from jax.sharding import PartitionSpec as P

    def leaf(st):
        if isinstance(st, _Empty):
            return EMPTY
        return {"factors": [None if u is None else P() for u in st["factors"]],
                "error": P(pod_axis)}

    return jax.tree.map(leaf, state, is_leaf=_is_state_leaf)


def stack_for_pods(state: Any, n_pods: int) -> Any:
    """Give every error buffer a leading (stacked) pod axis."""
    def leaf(st):
        if isinstance(st, _Empty):
            return EMPTY
        e = st["error"]
        return {"factors": st["factors"],
                "error": jnp.broadcast_to(e[None], (n_pods,) + e.shape)}

    return jax.tree.map(leaf, state, is_leaf=_is_state_leaf)


def localize(state: Any) -> Any:
    """Inside shard_map: strip the (local, size-1) stacked pod axis."""
    def leaf(st):
        if isinstance(st, _Empty):
            return EMPTY
        return {"factors": st["factors"], "error": st["error"][0]}

    return jax.tree.map(leaf, state, is_leaf=_is_state_leaf)


def delocalize(state: Any) -> Any:
    """Inside shard_map: re-add the stacked pod axis before returning."""
    def leaf(st):
        if isinstance(st, _Empty):
            return EMPTY
        return {"factors": st["factors"], "error": st["error"][None]}

    return jax.tree.map(leaf, state, is_leaf=_is_state_leaf)
