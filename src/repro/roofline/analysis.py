"""Three-term roofline analysis from compiled dry-run artifacts.

  compute    = HLO_FLOPs / (chips × 197 TFLOP/s)
  memory     = HLO_bytes / (chips × 819 GB/s)
  collective = collective_bytes / (chips × 50 GB/s)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device
program → multiply by chips for the global numerator, or equivalently use
the per-device number over per-chip peak — we do the latter).
collective_bytes is parsed from the compiled HLO text: the summed operand
sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops.
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import asdict, dataclass
from pathlib import Path

from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# dtype[1,2,3]{layout} — layout part optional
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_INSTR = re.compile(
    r"=\s*\(?((?:" + "|".join(_DTYPE_BYTES) + r")\[[0-9,]*\][^ ]*"
    r"(?:,\s*(?:" + "|".join(_DTYPE_BYTES) + r")\[[0-9,]*\][^ )]*)*)\)?\s+"
    r"([a-z][a-z0-9\-]*)\(")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND = re.compile(r"condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _operand_bytes(ret_types: str, op: str, line: str) -> int:
    """Operand bytes inferred from the RESULT type(s) + collective semantics
    (compiled HLO prints operands without their types)."""
    shapes = _SHAPE_RE.findall(ret_types)
    total = sum(_shape_bytes(d, s) for d, s in shapes)
    gs = 1
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        gs = int(m.group(2))
    else:
        m = re.search(r"replica_groups=\{(\{[^}]*\})", line)
        if m:
            gs = m.group(1).count(",") + 1
    if op == "all-gather" and gs:
        return total // gs          # operand = result / group
    if op == "reduce-scatter" and gs:
        return total * gs           # operand = result x group
    return total                    # all-reduce / all-to-all / permute


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum EXECUTED operand bytes per collective kind.

    Compiled HLO wraps the layer scan / microbatch loop in ``while`` ops, so
    a static line count undercounts by the trip factor.  We build the
    computation call graph (while bodies, fusions, calls, conditionals),
    read each while's trip count from the integer bound in its condition
    computation, and multiply bytes accordingly.
    """
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMP_HDR.match(s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)

    def trip_count(cond_name: str) -> int:
        ints = [int(x) for x in
                _CONST_INT.findall("\n".join(comps.get(cond_name, [])))]
        return max(ints) if ints else 1

    memo: dict[str, dict[str, float]] = {}

    def walk(name: str) -> dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {k: 0.0 for k in COLLECTIVE_OPS} | {"count": 0.0}
        acc = {k: 0.0 for k in COLLECTIVE_OPS} | {"count": 0.0}
        for line in comps.get(name, []):
            m = _INSTR.search(line)
            if m:
                op = m.group(2)
                base = op[:-6] if op.endswith("-start") else op
                if base in COLLECTIVE_OPS and not op.endswith("-done"):
                    acc[base] += _operand_bytes(m.group(1), base, line)
                    acc["count"] += 1
            if " while(" in line:
                mb = _WHILE_BODY.search(line)
                mc = _WHILE_COND.search(line)
                if mb:
                    sub = walk(mb.group(1))
                    t = trip_count(mc.group(1)) if mc else 1
                    for k in acc:
                        acc[k] += sub[k] * t
            elif " conditional(" in line:
                mb = _BRANCHES.search(line)
                if mb:
                    branches = [b.strip().lstrip("%") for b in mb.group(1).split(",")]
                    subs = [walk(b) for b in branches if b in comps]
                    if subs:   # worst-case branch
                        worst = max(subs,
                                    key=lambda s_: sum(s_[k] for k in COLLECTIVE_OPS))
                        for k in acc:
                            acc[k] += worst[k]
            else:
                mcall = _CALLS.search(line)
                if mcall and (" fusion(" in line or " call(" in line):
                    sub = walk(mcall.group(1))
                    for k in acc:
                        acc[k] += sub[k]
        memo[name] = acc
        return acc

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
            break
    out_f = (walk(entry) if entry
             else {k: 0.0 for k in COLLECTIVE_OPS} | {"count": 0.0})
    out = {k: int(v) for k, v in out_f.items()}
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    collective_bytes: float     # per device
    model_flops: float          # 6·N·D (global, useful)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float         # model_flops / (hlo_flops × chips)
    peak_bytes_per_device: float = 0.0

    @classmethod
    def from_cell(cls, *, arch, shape, mesh_name, chips, cost, collectives,
                  model_flops, peak_bytes=0.0):
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        coll = float(collectives.get("total", 0))
        compute_s = flops / PEAK_FLOPS_BF16
        memory_s = byts / HBM_BW
        collective_s = coll / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        bott = max(terms, key=terms.get)
        useful = model_flops / max(1.0, flops * chips)
        return cls(arch, shape, mesh_name, chips, flops, byts, coll,
                   model_flops, compute_s, memory_s, collective_s, bott,
                   useful, peak_bytes)


def model_flops_for(cfg, shape_cfg) -> float:
    """6·N·D for train (N = active params, D = tokens); decode: 2·N_active
    per generated token + KV-cache read bytes are in the memory term."""
    n = cfg.param_count()
    if cfg.n_experts:
        gated = 3 if cfg.act == "silu" else 2
        dense_moe = cfg.n_layers * cfg.n_experts * gated * cfg.d_model * cfg.d_ff
        active_moe = dense_moe * cfg.top_k / cfg.n_experts
        n = n - dense_moe + active_moe
    tokens = shape_cfg.global_batch * shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * n * tokens
    if shape_cfg.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape_cfg.global_batch      # decode: one token per seq


def fmt_seconds(s: float) -> str:
    if s < 1e-3:
        return f"{s*1e6:.1f}µs"
    if s < 1:
        return f"{s*1e3:.2f}ms"
    return f"{s:.2f}s"


def load_artifacts(art_dir: str | Path) -> list[dict]:
    out = []
    for p in sorted(Path(art_dir).glob("**/*.json")):
        out.append(json.loads(p.read_text()))
    return out


def to_markdown_table(rows: list[Roofline]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "bottleneck | useful |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {fmt_seconds(r.compute_s)} "
            f"| {fmt_seconds(r.memory_s)} | {fmt_seconds(r.collective_s)} "
            f"| {r.bottleneck} | {r.useful_ratio:.2f} |")
    return hdr + "\n".join(lines)


def main():  # pragma: no cover
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    args = ap.parse_args()
    print("| arch | shape | mesh | compute | memory | collective | "
          "bottleneck | roofline-frac | useful | GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in load_artifacts(args.artifacts):
        if "roofline" not in d:
            continue
        r = d["roofline"]
        tot = max(r["compute_s"], r["memory_s"], r["collective_s"]) or 1.0
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {fmt_seconds(r['compute_s'])} | {fmt_seconds(r['memory_s'])} "
              f"| {fmt_seconds(r['collective_s'])} | {r['bottleneck']} "
              f"| {r['compute_s']/tot:.3f} | {r['useful_ratio']:.2f} "
              f"| {d['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f} |")


if __name__ == "__main__":  # pragma: no cover
    main()
