"""Trip-count-aware analyzer for compiled HLO text.

XLA's ``cost_analysis()`` visits each ``while`` body ONCE, so scanned
programs (layer stacks, microbatch loops, blockwise attention) under-count
flops/bytes by the trip factor.  This walker rebuilds the numbers from the
compiled module:

  * per-computation symbol table (params + instruction defs) so operand
    shapes resolve even though compiled HLO prints operands untyped,
  * dot flops = 2 · |result| · K  (K from lhs contracting dims),
  * memory traffic = Σ (operand + result bytes) over *top-level* ops —
    fusion internals excluded, which models fused execution,
  * collective bytes per kind (operand-sized, group-size-corrected),
  * every term multiplied by the enclosing while trip counts (parsed from
    the integer bound in the loop condition).

Used by repro.roofline.analysis for the three roofline terms.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^,)]*))")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CONST_INT = re.compile(r"s32\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes_elems(type_str: str) -> tuple[int, list[tuple[str, list[int]]]]:
    """Total bytes + parsed (dtype, dims) list for a (possibly tuple) type."""
    total = 0
    shapes = []
    for dt, dims in _TYPE_RE.findall(type_str):
        ds = [int(x) for x in dims.split(",")] if dims else []
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, ds))
    return total, shapes


@dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list[str]
    line: str


@dataclass
class Computation:
    name: str
    types: dict[str, str] = field(default_factory=dict)   # symbol → type str
    instrs: list[Instr] = field(default_factory=list)


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None or (line.endswith("{") and _COMP_HDR.match(line)):
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if raw.startswith("ENTRY"):
                    entry = cur.name
                # params carry types in the header
                for pname, ptype in _PARAM_RE.findall(line):
                    cur.types[pname] = ptype
            continue
        if line == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # result type = everything before the first `op(` call.  (The type
        # prefix — including tuple /*index=N*/ comments and layout braces —
        # never contains a `word(` token, so the first one is the op.)
        om = re.search(r"(?:^|\s)([a-z][a-z0-9\-]*)\(", rest)
        if not om:
            continue
        op = om.group(1)
        rtype = rest[:om.start()].strip()
        inside = rest[rest.index("(", om.start(1)) + 1:]
        depth = 1
        args = []
        for i, ch in enumerate(inside):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = _OPND_RE.findall(inside[:i])
                    break
        cur.types[name] = rtype
        cur.instrs.append(Instr(name, rtype, op, args, line))
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_bytes, out_shapes = _type_bytes_elems(ins.result_type)
    if not out_shapes:
        return 0.0
    n_out = 1
    for d in out_shapes[0][1]:
        n_out *= d
    # K = product of lhs contracting dim sizes
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    k = 1
    if mc and ins.operands:
        lhs_t = comp.types.get(ins.operands[0])
        if lhs_t:
            _, lshapes = _type_bytes_elems(lhs_t)
            if lshapes:
                dims = lshapes[0][1]
                for ci in mc.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        k *= dims[int(ci)]
    return 2.0 * n_out * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    _, out_shapes = _type_bytes_elems(ins.result_type)
    if not out_shapes or len(ins.operands) < 2:
        return 0.0
    n_out = 1
    for d in out_shapes[0][1]:
        n_out *= d
    rhs_t = comp.types.get(ins.operands[1])
    k = 1
    if rhs_t:
        _, rshapes = _type_bytes_elems(rhs_t)
        if rshapes:
            for d in rshapes[0][1]:
                k *= d
    return 2.0 * n_out * k


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{(\{[^}]*\})", line)
    if m:
        return m.group(1).count(",") + 1
    return 1


_ZERO = {"flops": 0.0, "traffic": 0.0, "traffic_ess": 0.0, "coll_count": 0.0,
         **{k: 0.0 for k in COLLECTIVES}}

# ops whose operands/results we count as HBM traffic at top level — the
# UPPER BOUND metric (XLA:CPU fuses less than XLA:TPU, so this includes
# elementwise chains a TPU build would fuse away)
_TRAFFIC_OPS = ("fusion", "dot", "convolution", "copy", "convert", "bitcast",
                "transpose", "reduce", "broadcast", "reshape", "scatter",
                "gather", "dynamic-slice", "dynamic-update-slice", "sort",
                "select-and-scatter", "pad", "concatenate", "slice",
                "iota", "compare", "add", "multiply", "subtract", "divide",
                "exponential", "tanh", "rsqrt", "maximum", "minimum") + \
    COLLECTIVES + tuple(c + "-start" for c in COLLECTIVES)

# ESSENTIAL traffic: operands/results that must cross HBM even under perfect
# elementwise fusion (TPU target) — matmul I/O, cache/dispatch data movement,
# collectives, sorts.  This is the memory-roofline numerator.
_ESSENTIAL_OPS = ("dot", "convolution", "scatter", "gather", "dynamic-slice",
                  "dynamic-update-slice", "sort", "select-and-scatter",
                  "concatenate") + COLLECTIVES + \
    tuple(c + "-start" for c in COLLECTIVES)


def analyze(text: str) -> dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        return dict(_ZERO, coll_total=0.0)

    memo: dict[str, dict[str, float]] = {}

    def trip_count(cond: str) -> int:
        c = comps.get(cond)
        if not c:
            return 1
        ints = [int(x) for i in c.instrs
                for x in _CONST_INT.findall(i.line)]
        return max(ints) if ints else 1

    def walk(name: str) -> dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = dict(_ZERO)          # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        acc = dict(_ZERO)

        for ins in comp.instrs:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            coll_b = 0
            if base in COLLECTIVES and not op.endswith("-done"):
                coll_b, _ = _type_bytes_elems(ins.result_type)
                gs = _group_size(ins.line)
                if base == "all-gather":
                    coll_b = coll_b // max(1, gs)
                elif base == "reduce-scatter":
                    coll_b = coll_b * gs
                acc[base] += coll_b
                acc["coll_count"] += 1

            if op == "dot":
                acc["flops"] += _dot_flops(ins, comp)
            elif op == "convolution":
                acc["flops"] += _conv_flops(ins, comp)

            def io_bytes():
                b, _ = _type_bytes_elems(ins.result_type)
                for o in ins.operands:
                    t = comp.types.get(o)
                    if t:
                        b += _type_bytes_elems(t)[0]
                return b

            if op in _TRAFFIC_OPS and op != "bitcast":
                acc["traffic"] += io_bytes()

            if base in _ESSENTIAL_OPS and not op.endswith("-done"):
                if base in COLLECTIVES:
                    acc["traffic_ess"] += coll_b
                elif base in ("gather", "dynamic-slice"):
                    # reads only the gathered bytes, not the whole operand
                    acc["traffic_ess"] += _type_bytes_elems(ins.result_type)[0]
                elif base in ("scatter", "dynamic-update-slice"):
                    # writes only the update slice (result aliases the buffer)
                    upd = (comp.types.get(ins.operands[-1])
                           if ins.operands else None)
                    acc["traffic_ess"] += (_type_bytes_elems(upd)[0] if upd
                                           else _type_bytes_elems(ins.result_type)[0])
                else:
                    acc["traffic_ess"] += io_bytes()

            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if mb:
                    sub = walk(mb.group(1))
                    t = trip_count(mc.group(1)) if mc else 1
                    for k in acc:
                        acc[k] += sub[k] * t
            elif op == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if mbr:
                    branches = [b.strip().lstrip("%") for b in mbr.group(1).split(",")]
                    subs = [walk(b) for b in branches if b in comps]
                    if subs:
                        worst = max(subs, key=lambda s: s["flops"] + s["traffic"])
                        for k in acc:
                            acc[k] += worst[k]
            elif op in ("fusion", "call", "async-start"):
                mcall = re.search(r"(?:calls|to_apply|called_computation)=%?([\w.\-]+)",
                                  ins.line)
                if mcall:
                    sub = walk(mcall.group(1))
                    # fusion internals: count FLOPs + essential traffic (dots
                    # and scatters can live in fused computations on CPU) but
                    # NOT upper-bound traffic (fused = no HBM for elementwise)
                    acc["flops"] += sub["flops"]
                    acc["traffic_ess"] += sub["traffic_ess"]
                    for k in COLLECTIVES + ("coll_count",):
                        acc[k] += sub[k]

        memo[name] = acc
        return acc

    out = walk(entry)
    out["coll_total"] = sum(out[k] for k in COLLECTIVES)
    return out
