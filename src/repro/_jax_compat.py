"""Compatibility patches for older JAX releases.

The codebase targets the current ``jax.shard_map`` API (top-level export,
``axis_names=`` to scope manual axes, ``check_vma=``).  On releases where
shard_map still lives in ``jax.experimental.shard_map`` (≤ 0.4.x) this module
installs an adapter under ``jax.shard_map``:

  * ``axis_names={...}``  → ``auto = mesh.axis_names - axis_names`` (the old
    complement parameter)
  * ``check_vma=``        → ``check_rep=``

Imported for its side effect from ``repro/__init__``; a no-op on new JAX.
"""

from __future__ import annotations

import jax

if not hasattr(jax, "shard_map"):  # pragma: no branch - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, check_rep=None, **kwargs):
        if axis_names is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep, **kwargs)

    jax.shard_map = shard_map
