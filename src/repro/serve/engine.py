"""Batched serving engines.

``ServeEngine`` — slot-based continuous batching over the LM decode step.
Requests are admitted into fixed batch slots; each slot tracks its own
position; finished slots (EOS or max_len) are refilled from the queue
without stopping the batch — the decode step is one compiled program
regardless of slot occupancy (inactive slots decode garbage that is masked
out, the standard static-shape trick).  Prefill runs per-request
(right-padded to the slot's prompt bucket) and writes the slot's stripe of
the batched KV cache.

``TuckerBatchEngine`` — the decomposition-serving counterpart, built on the
plan/execute front door (:mod:`repro.core.api`): requests carrying small
dense tensors are grouped by (shape, dtype, config), each group reuses one
cached ``TuckerPlan`` (selector + compilation amortized across the fleet),
and same-shaped groups execute as a single vmapped program.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.api import TuckerConfig, TuckerPlan
from ..core.sthosvd import SthosvdResult
from ..models.registry import ModelBundle


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0
    output: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None, seed: int = 0):
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.cache = bundle.init_cache(batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots

        self._decode = jax.jit(
            lambda p, tok, c, pos: bundle.decode(p, tok, c, pos, max_len))
        cfg = bundle.cfg

        def prefill_one(p, tokens, cache_slice):
            return bundle.prefill(p, {"tokens": tokens}, cache_slice)

        self._prefill = jax.jit(prefill_one)

    # -- slot management -----------------------------------------------------
    def _admit(self, req: Request, slot: int):
        toks = jnp.asarray([req.prompt], jnp.int32)
        slot_cache = jax.tree.map(lambda x: x[:, slot:slot + 1], self.cache)
        logits, slot_cache = self._prefill(self.params, toks, slot_cache)
        self.cache = jax.tree.map(
            lambda full, s: full.at[:, slot:slot + 1].set(s), self.cache, slot_cache)
        self.pos[slot] = len(req.prompt)
        self.slot_req[slot] = req
        first = self._sample(logits[:, -1], np.array([req.temperature]))
        req.output.append(int(first[0]))

    def _sample(self, logits, temps):
        """Next token per row: greedy at temperature 0, categorical above.

        ``temps`` is one temperature per logits row (slots run mixed
        temperatures in one batched step).  The PRNG key is only consumed
        when some row actually samples — an all-greedy batch is fully
        deterministic and key-free.
        """
        temps = np.asarray(temps, np.float32)
        greedy = np.asarray(jnp.argmax(logits, -1))
        if not (temps > 0).any():
            return greedy
        self.key, k = jax.random.split(self.key)
        scaled = logits / jnp.maximum(jnp.asarray(temps)[:, None], 1e-6)
        sampled = np.asarray(jax.random.categorical(k, scaled, axis=-1))
        return np.where(temps > 0, sampled, greedy)

    # -- main loop ---------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        active = lambda: any(r is not None for r in self.slot_req)
        while queue or active():
            # fill empty slots
            for s in range(self.b):
                if self.slot_req[s] is None and queue:
                    self._admit(queue.pop(0), s)
            # one batched decode step: feed each slot its last token at its
            # OWN position (per-slot position vector)
            last = np.zeros((self.b, 1), np.int32)
            temps = np.zeros(self.b, np.float32)
            for s, r in enumerate(self.slot_req):
                if r is not None and r.output:
                    last[s, 0] = r.output[-1]
                    temps[s] = r.temperature
            logits, self.cache = self._decode(
                self.params, jnp.asarray(last), self.cache,
                jnp.asarray(self.pos, jnp.int32))
            nxt = self._sample(logits[:, 0], temps)
            for s, r in enumerate(self.slot_req):
                if r is None:
                    continue
                tok = int(nxt[s])
                r.output.append(tok)
                self.pos[s] += 1
                if (self.eos is not None and tok == self.eos) or \
                        len(r.output) >= r.max_new_tokens or \
                        self.pos[s] >= self.max_len - 1:
                    r.done = True
                    self.slot_req[s] = None
        return requests


# ---------------------------------------------------------------------------
# Tucker decomposition serving (plan/execute front door)
# ---------------------------------------------------------------------------

@dataclass
class TuckerRequest:
    """One decomposition job: a small dense tensor plus its TuckerConfig."""
    x: jax.Array
    config: TuckerConfig
    rid: int = 0
    result: SthosvdResult | None = None


class TuckerBatchEngine:
    """Serves fleets of small Tucker decompositions with amortized planning.

    A thin synchronous wrapper over :class:`repro.serve.service.TuckerService`
    running the identity bucket policy (``BucketPolicy.exact()``: every
    (shape, dtype, pinned config) is its own bucket, waves are unbounded, no
    request is ever padded) with an unbounded admission queue — exactly the
    pre-service ``run()`` semantics: per group the service plans ONCE (the
    adaptive selector and XLA compilation run on the first request only),
    singleton groups share the unbatched compiled sweep via
    ``TuckerPlan.execute``, and larger groups execute as one vmapped
    program via ``execute_batch`` with the service-built stack donated into
    the sweep (no caller array is ever invalidated).

    ``impl`` pins every plan the engine builds to one ops backend (overriding
    each request config's ``impl``) — the serving-side backend axis; the
    default ``None`` honours per-request configs (typically ``"auto"``,
    resolved per platform at plan time).  ``stats["backends"]`` counts
    requests per resolved backend.

    ``mesh`` (plus optional ``shard_axis``) attaches a device mesh to every
    plan the engine builds, so grouped requests execute through the
    ``sharded`` backend — a mesh with no explicit ``impl`` pins
    ``impl="sharded"``.  Requests that already carry their own mesh keep
    it.  A mesh is only ever attached to (or kept on) configs whose
    resolved backend can use one (``"auto"`` or a mesh-requiring backend);
    pinning a single-device ``impl`` drops it, since ``TuckerConfig``
    rejects the contradictory combination.  Sharded groups still batch
    planning and compilation — ``execute_batch`` runs them item by item
    over one cached compiled sweep.

    ``memory_cap_bytes`` pins a per-device modeled-peak ceiling onto every
    plan the engine builds (requests carrying their own cap keep the
    tighter of the two) — the fleet-operator knob for the paper's OOM
    regime; pair it with per-request ``mode_order="opt"`` configs to let
    the DP search schedules under it.

    ``record=True`` (optionally with a ``record_store``) runs requests
    through the eager timed path so engine traffic feeds the autotune
    flywheel — see :class:`~repro.serve.service.TuckerService`.  For
    streaming traffic (async submit/poll, shape buckets, backpressure,
    latency metrics) use the service directly.
    """

    def __init__(self, selector=None, *, impl: str | None = None,
                 mesh=None, shard_axis: str | None = None,
                 memory_cap_bytes: int | None = None,
                 record: bool = False, record_store=None):
        from .buckets import BucketPolicy
        from .service import TuckerService
        self.service = TuckerService(
            selector, policy=BucketPolicy.exact(), impl=impl, mesh=mesh,
            shard_axis=shard_axis, memory_cap_bytes=memory_cap_bytes,
            max_queue=None, record=record, record_store=record_store)

    @property
    def _plans(self) -> dict[tuple, TuckerPlan]:
        return self.service._plans

    @property
    def stats(self) -> dict:
        return self.service.stats()

    def _pinned(self, config: TuckerConfig) -> TuckerConfig:
        return self.service._pinned(config)

    def plan_for(self, shape, dtype, config: TuckerConfig) -> TuckerPlan:
        return self.service.plan_for(shape, dtype, config)

    def run(self, requests: list[TuckerRequest]) -> list[TuckerRequest]:
        tickets = [self.service.submit(r.x, r.config, rid=r.rid)
                   for r in requests]
        self.service.drain()
        first_err: Exception | None = None
        for r, t in zip(requests, tickets):
            try:
                r.result = self.service.poll(t)
            except Exception as e:  # noqa: BLE001 - surfaced after the sweep
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err
        return requests
