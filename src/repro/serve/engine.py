"""Batched serving engine: slot-based continuous batching over the decode
step.

Requests are admitted into fixed batch slots; each slot tracks its own
position; finished slots (EOS or max_len) are refilled from the queue
without stopping the batch — the decode step is one compiled program
regardless of slot occupancy (inactive slots decode garbage that is masked
out, the standard static-shape trick).

Prefill runs per-request (right-padded to the slot's prompt bucket) and
writes the slot's stripe of the batched KV cache.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.registry import ModelBundle


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0
    output: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None, seed: int = 0):
        self.bundle = bundle
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.cache = bundle.init_cache(batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots

        self._decode = jax.jit(
            lambda p, tok, c, pos: bundle.decode(p, tok, c, pos, max_len))
        cfg = bundle.cfg

        def prefill_one(p, tokens, cache_slice):
            return bundle.prefill(p, {"tokens": tokens}, cache_slice)

        self._prefill = jax.jit(prefill_one)

    # -- slot management -----------------------------------------------------
    def _admit(self, req: Request, slot: int):
        toks = jnp.asarray([req.prompt], jnp.int32)
        slot_cache = jax.tree.map(lambda x: x[:, slot:slot + 1], self.cache)
        logits, slot_cache = self._prefill(self.params, toks, slot_cache)
        self.cache = jax.tree.map(
            lambda full, s: full.at[:, slot:slot + 1].set(s), self.cache, slot_cache)
        self.pos[slot] = len(req.prompt)
        self.slot_req[slot] = req
        first = self._sample(logits[:, -1])
        req.output.append(int(first[0]))

    def _sample(self, logits):
        self.key, k = jax.random.split(self.key)
        return np.asarray(jnp.argmax(logits, -1))

    # -- main loop ---------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        active = lambda: any(r is not None for r in self.slot_req)
        while queue or active():
            # fill empty slots
            for s in range(self.b):
                if self.slot_req[s] is None and queue:
                    self._admit(queue.pop(0), s)
            # one batched decode step: feed each slot its last token at its
            # OWN position (per-slot position vector)
            last = np.zeros((self.b, 1), np.int32)
            for s, r in enumerate(self.slot_req):
                if r is not None and r.output:
                    last[s, 0] = r.output[-1]
            logits, self.cache = self._decode(
                self.params, jnp.asarray(last), self.cache,
                jnp.asarray(self.pos, jnp.int32))
            nxt = self._sample(logits[:, 0])
            for s, r in enumerate(self.slot_req):
                if r is None:
                    continue
                tok = int(nxt[s])
                r.output.append(tok)
                self.pos[s] += 1
                if (self.eos is not None and tok == self.eos) or \
                        len(r.output) >= r.max_new_tokens or \
                        self.pos[s] >= self.max_len - 1:
                    r.done = True
                    self.slot_req[s] = None
        return requests
