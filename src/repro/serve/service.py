"""Streaming Tucker serving: async submit/poll over shape buckets.

``TuckerBatchEngine.run()`` is a synchronous one-shot over a pre-collected
request list; production decomposition traffic is a *stream*.  This module
is the admission pipeline in front of the plan/execute machinery:

  * ``submit(x, config) -> Ticket`` routes the request into a shape bucket
    (:mod:`repro.serve.buckets` — odd shapes are zero-padded up to the
    nearest bucket; the slack is masked out of every Gram/TTM contribution
    so exact-mode results are bitwise-equal to unpadded execution) and
    enqueues it under a bounded-queue backpressure policy (``"reject"``
    raises :class:`RejectedError`, ``"block"`` waits for space).
  * Waves of up to ``policy.wave_slots`` lanes are formed per bucket and
    executed through the bucket's warm :class:`~repro.core.api.TuckerPlan`
    + one vmapped compiled sweep (the process-wide ``_SWEEP_CACHE``), with
    power-of-two lane fill bounding compiled batch sizes.  Dispatch is
    pipelined: while wave *i* runs on the device, the service completes
    wave *i−1* and stacks wave *i+1* from the queue — slots refill without
    stopping the stream, mirroring ``ServeEngine``'s slot loop.
  * ``poll(ticket)`` / ``wait(ticket)`` retrieve results; ``drain()`` runs
    or awaits everything queued.  ``start()`` spawns a background worker so
    ``submit`` returns immediately (async mode); without it the service is
    a synchronous pump (``drain`` executes inline).
  * ``stats()`` exposes per-bucket p50/p95/p99 latency, queue depth,
    pad-waste and lane-occupancy ratios, and backend/solver counters;
    ``trace_path=`` appends a JSONL event per submit/wave/completion.
  * ``record=True`` (or an ambient :func:`repro.tune.recording` context)
    runs waves through the eager timed path so served traffic feeds the
    autotune flywheel — optionally straight into a ``record_store``.

Every engine-level pin (``impl`` / ``mesh`` / ``memory_cap_bytes`` /
donation) flows through unchanged; ``TuckerBatchEngine`` is now a thin
synchronous wrapper over this service (identity bucket policy, unbounded
waves).
"""

from __future__ import annotations

import math
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from ..core.api import CACHE_STATS, TuckerConfig, TuckerPlan, plan as make_plan
from ..core.plan import validate_ranks
from ..obs import drift as _drift
from ..obs import trace as _obs
from ..core.sthosvd import SthosvdResult
from .buckets import BucketPolicy, pad_block, pad_waste, slice_valid, trim_result
from .metrics import BucketMetrics, LatencyWindow, TraceWriter

BACKPRESSURE_MODES = ("reject", "block")


class RejectedError(RuntimeError):
    """submit() refused a request: the admission queue is full (policy
    ``"reject"``) or could not make progress (``"block"`` with no runnable
    wave)."""


class ServiceClosed(RuntimeError):
    """submit() after close(): the service no longer admits requests."""


@dataclass
class Ticket:
    """Handle returned by :meth:`TuckerService.submit`; pass to ``poll`` /
    ``wait``.  ``padded`` says the request did not fit its bucket exactly
    (``bucket`` is the slot shape it was padded into)."""
    rid: int
    shape: tuple[int, ...]
    bucket: tuple[int, ...]
    padded: bool
    submitted_at: float
    _job: "_Job" = field(repr=False, default=None)


class _Job:
    """Internal per-request state (Ticket keeps the only reference once the
    job leaves the queue, so completed work is garbage-collected with its
    ticket)."""
    __slots__ = ("rid", "x", "config", "shape", "key", "t_submit",
                 "result", "error", "event")

    def __init__(self, rid, x, config, shape, key):
        self.rid = rid
        self.x = x
        self.config = config
        self.shape = shape
        self.key = key
        self.t_submit = time.perf_counter()
        self.result: SthosvdResult | None = None
        self.error: Exception | None = None
        self.event = threading.Event()


class _BucketState:
    __slots__ = ("key", "queue", "metrics")

    def __init__(self, key):
        self.key = key
        self.queue: deque[_Job] = deque()
        self.metrics = BucketMetrics(bucket=key[0])


class TuckerService:
    """Continuous-batching decomposition service (see module docstring).

    ``impl`` / ``mesh`` / ``shard_axis`` / ``memory_cap_bytes`` pin every
    plan the service builds, with exactly the semantics the batch engine
    documented (request configs keep the tighter memory cap; a mesh is
    dropped under a single-device impl pin).  ``policy`` is the
    :class:`~repro.serve.buckets.BucketPolicy`; ``max_queue`` bounds total
    queued requests (None = unbounded, backpressure off).

    ``max_inflight_waves`` bounds CROSS-WAVE PIPELINING: how many dispatched
    waves may be awaiting results while the pump stacks the next one.  JAX
    dispatch is async, so each in-flight wave overlaps device execution with
    host-side padding/stacking of its successors — mode-group k of wave i+1
    is being built (and dispatched) while wave i still computes.  ``1`` is
    fully serial (dispatch → block → next), ``2`` (default) the classic
    one-ahead pipeline the service always did, higher values deepen the
    window for streams of small waves.  Per-bucket ``pipeline_occupancy``
    in :meth:`stats` reports how often the window was actually used.

    Synchronous use (the engine wrapper, offline batches)::

        svc = TuckerService()
        t = svc.submit(x, cfg)
        svc.drain()
        res = svc.poll(t)

    Streaming use::

        with TuckerService(max_queue=256, backpressure="block") as svc:
            svc.start()
            tickets = [svc.submit(x, cfg) for x in stream]
            results = [svc.wait(t) for t in tickets]
    """

    def __init__(self, selector=None, *, policy: BucketPolicy | None = None,
                 impl: str | None = None, mesh=None,
                 shard_axis: str | None = None,
                 memory_cap_bytes: int | None = None,
                 max_queue: int | None = 1024,
                 backpressure: str = "reject",
                 max_inflight_waves: int = 2,
                 record: bool = False, record_store=None,
                 trace_path=None):
        if backpressure not in BACKPRESSURE_MODES:
            raise ValueError(f"backpressure {backpressure!r} not in "
                             f"{BACKPRESSURE_MODES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 or None (unbounded)")
        if max_inflight_waves < 1:
            raise ValueError("max_inflight_waves must be >= 1 (1 = serial "
                             "dispatch, 2 = classic one-ahead pipelining)")
        self._selector = selector
        self._policy = policy if policy is not None else BucketPolicy()
        self._impl = "sharded" if impl is None and mesh is not None else impl
        self._mesh = mesh
        self._shard_axis = shard_axis
        self._cap = memory_cap_bytes
        self._max_queue = max_queue
        self._backpressure = backpressure
        self._max_inflight = int(max_inflight_waves)
        self._record = record
        self._record_store = record_store
        self._trace = TraceWriter(trace_path) if trace_path else None

        self._plans: dict[tuple, TuckerPlan] = {}
        self._buckets: dict[tuple, _BucketState] = {}
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._pending = 0          # queued + in-flight, not yet completed
        self._next_rid = 0
        self._counters = {"submitted": 0, "requests": 0, "rejected": 0,
                          "failed": 0, "batches": 0, "plans_built": 0}
        self._latency = LatencyWindow()
        self._t0 = time.perf_counter()
        self._thread: threading.Thread | None = None
        self._running = False
        self._closed = False

    # -- tracing -------------------------------------------------------------
    def _emit(self, kind: str, **fields) -> None:
        """One serve event, to BOTH sinks: the service's own JSONL
        TraceWriter (when ``trace_path`` was given — schema unchanged) and
        the process-wide :mod:`repro.obs` event bus (no-op unless tracing
        is enabled), so a bus capture ties serve lifecycle events to the
        plan/execute/compile spans underneath them."""
        if self._trace:
            self._trace.event(kind, **fields)
        _obs.event(kind, **fields)

    # -- config pinning (the engine's fleet-operator knobs) ------------------
    def _pinned(self, config: TuckerConfig) -> TuckerConfig:
        from ..core.backend import get_backend

        impl = self._impl if self._impl is not None else config.impl
        mesh, axis = config.mesh, config.shard_axis
        if mesh is None and self._mesh is not None:
            mesh, axis = self._mesh, self._shard_axis or config.shard_axis
        if impl != "auto" and not get_backend(impl).requires_mesh:
            mesh = None   # pinned single-device backend: a mesh is moot
        cap = config.memory_cap_bytes
        if self._cap is not None:
            cap = self._cap if cap is None else min(cap, self._cap)
        if (impl, mesh, axis, cap) != (config.impl, config.mesh,
                                       config.shard_axis,
                                       config.memory_cap_bytes):
            config = replace(config, impl=impl, mesh=mesh, shard_axis=axis,
                             memory_cap_bytes=cap)
        return config

    # -- plan cache ----------------------------------------------------------
    def plan_for(self, shape, dtype, config: TuckerConfig) -> TuckerPlan:
        """The (cached) plan a request of this (shape, dtype, config) runs
        under the service's pins — built on first use, reused forever."""
        return self._plan_cached(tuple(int(s) for s in shape),
                                 str(jnp.dtype(dtype)), self._pinned(config))

    def _plan_cached(self, shape: tuple, dtype: str, pinned: TuckerConfig,
                     *, base: TuckerPlan | None = None) -> TuckerPlan:
        key = (shape, dtype, pinned)
        p = self._plans.get(key)
        if p is None:
            if base is not None:
                # derive from the bucket's warm plan (same config/dtype):
                # the api-level reuse hook for padded member shapes
                p = base.for_shape(shape, selector=self._selector)
            else:
                p = make_plan(shape, dtype, pinned, selector=self._selector)
            # plan building happens outside the lock (it can be slow); two
            # threads may race here, in which case the first insert wins
            with self._lock:
                if key in self._plans:
                    return self._plans[key]
                self._plans[key] = p
                self._counters["plans_built"] += 1
        return p

    # -- admission -----------------------------------------------------------
    def submit(self, x, config: TuckerConfig, *, rid: int | None = None) -> Ticket:
        """Admit one decomposition request; returns a :class:`Ticket`.

        Validation (ranks vs the TRUE shape) happens here so a bad request
        fails its caller, not the wave that picks it up.  When the queue is
        at ``max_queue``: ``backpressure="reject"`` raises
        :class:`RejectedError` immediately; ``"block"`` waits for space —
        against the background worker when running, otherwise by pumping a
        wave inline (synchronous callers backpressure themselves by doing
        the work).
        """
        if self._closed:
            raise ServiceClosed("service is closed to new submissions")
        if not hasattr(x, "shape"):
            x = jnp.asarray(x)
        shape = tuple(int(s) for s in x.shape)
        if config.ranks is not None:
            validate_ranks(shape, config.ranks)
        # rank-adaptive configs (error_target, ranks=None) have no ranks to
        # validate here: per-mode ranks resolve per input at execute time,
        # and the config's own __post_init__ already validated the target
        pinned = self._pinned(config)
        dtype = str(jnp.dtype(x.dtype))
        bshape = self._policy.bucket_shape(shape)
        key = (bshape, dtype, pinned)
        while True:
            with self._lock:
                if self._closed:
                    raise ServiceClosed("service is closed to new submissions")
                bs = self._buckets.get(key)
                if bs is None:
                    bs = self._buckets[key] = _BucketState(key)
                if self._max_queue is None or self._pending < self._max_queue:
                    if rid is None:
                        rid = self._next_rid
                    self._next_rid = max(self._next_rid, rid) + 1
                    job = _Job(rid, x, pinned, shape, key)
                    bs.queue.append(job)
                    bs.metrics.submitted += 1
                    self._pending += 1
                    self._counters["submitted"] += 1
                    self._work.notify_all()
                    break
                if self._backpressure == "reject":
                    bs.metrics.rejected += 1
                    self._counters["rejected"] += 1
                    self._emit("reject", rid=rid, shape=list(shape),
                               bucket=list(bshape))
                    raise RejectedError(
                        f"admission queue full ({self._max_queue} pending); "
                        "retry later or use backpressure='block'")
                if self._running:
                    self._space.wait(timeout=0.1)
                    continue
            # block policy, no worker: free space by running a wave here
            if not self._pump_once():
                raise RejectedError(
                    "queue full under backpressure='block' with no worker "
                    "running and no runnable wave")
        self._emit("submit", rid=job.rid, shape=list(shape),
                   bucket=list(bshape), padded=shape != bshape)
        return Ticket(rid=job.rid, shape=shape, bucket=bshape,
                      padded=shape != bshape, submitted_at=time.time(),
                      _job=job)

    # -- retrieval -----------------------------------------------------------
    def poll(self, ticket: Ticket) -> SthosvdResult | None:
        """Non-blocking: the request's result, or None while it is queued or
        in flight.  Re-raises the request's failure, if it failed."""
        job = ticket._job
        if job.error is not None:
            raise job.error
        return job.result

    def wait(self, ticket: Ticket, timeout: float | None = None) -> SthosvdResult:
        """Block until the request completes (driving the queue inline when
        no worker thread is running), then return its result."""
        job = ticket._job
        if not job.event.is_set() and not self._running:
            self.drain()
        if not job.event.wait(timeout):
            raise TimeoutError(f"request {ticket.rid} still pending after "
                               f"{timeout}s")
        return self.poll(ticket)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in flight)."""
        with self._lock:
            return self._pending

    # -- wave formation ------------------------------------------------------
    def _take_wave(self) -> tuple[_BucketState, list[_Job]] | None:
        """Pop the next wave: up to ``wave_slots`` requests from the bucket
        whose head request has waited longest (FIFO across buckets)."""
        with self._lock:
            ready = [bs for bs in self._buckets.values() if bs.queue]
            if not ready:
                return None
            bs = min(ready, key=lambda b: b.queue[0].t_submit)
            k = len(bs.queue) if self._policy.wave_slots is None \
                else min(len(bs.queue), self._policy.wave_slots)
            return bs, [bs.queue.popleft() for _ in range(k)]

    def _dispatch_wave(self, bs: _BucketState, jobs: list[_Job],
                       inflight: int = 0):
        """Execute one wave (dispatch only — JAX returns futures) and hand
        back a ``finish()`` closure that blocks on the results, completes
        the tickets, and updates metrics.  The pump keeps up to
        ``max_inflight_waves`` dispatched-but-unfinished waves, so host-side
        stacking and padding overlap device execution; ``inflight`` is how
        many earlier waves were still in flight at this dispatch (recorded
        as pipeline occupancy)."""
        bshape, dtype, cfg = bs.key
        t_start = time.perf_counter()
        done: list[tuple[_Job, SthosvdResult | None, TuckerPlan | None,
                         Exception | None]] = []
        lanes = len(jobs)
        tune = sys.modules.get("repro.tune")
        record = self._record or (
            tune is not None and tune.active_sink() is not None)
        try:
            if record:
                for j in jobs:
                    done.append(self._run_recorded(j, bshape, dtype, cfg))
            elif self._policy.pad_mode == "mask" and \
                    any(j.shape != bshape for j in jobs):
                # mask mode: mixed true shapes fuse into ONE vmapped wave at
                # the bucket shape; zero slack is arithmetically inert and
                # the factors' slack rows come back exactly zero, so each
                # lane trims to its true shape afterwards
                p = self._plan_cached(bshape, dtype, cfg)
                stack = jnp.stack([pad_block(jnp.asarray(j.x), bshape)
                                   for j in jobs])
                stack, lanes = self._lane_fill(stack, len(jobs), p)
                results = p.execute_batch(stack, donate=True)[:len(jobs)]
                for j, r in zip(jobs, results):
                    r = trim_result(r, j.shape) if j.shape != bshape else r
                    done.append((j, r, p, None))
            else:
                exact = [j for j in jobs if j.shape == bshape]
                padded = [j for j in jobs if j.shape != bshape]
                if exact:
                    p = self._plan_cached(bshape, dtype, cfg)
                    if len(exact) == 1 and self._policy.lanes_for(1) == 1:
                        # singleton: share the unbatched compiled sweep
                        res = p.execute(jnp.asarray(exact[0].x))
                        done.append((exact[0], res, p, None))
                    else:
                        stack = jnp.stack([jnp.asarray(j.x) for j in exact])
                        stack, lanes_e = self._lane_fill(stack, len(exact), p)
                        lanes = lanes_e + len(padded)
                        results = p.execute_batch(stack, donate=True)
                        for j, r in zip(exact, results):
                            done.append((j, r, p, None))
                if padded:
                    # the admission slot buffer: every padded member lands in
                    # a bucket-shaped slot; exact mode then slices the valid
                    # block back out (bitwise-lossless) and runs it through
                    # the plan its TRUE shape resolves to — the identical
                    # cached program a direct decompose() would run, which
                    # is what makes padded results bitwise-equal to
                    # unpadded execution
                    base = self._plans.get((bshape, dtype, cfg))
                    slots = jnp.stack([pad_block(jnp.asarray(j.x), bshape)
                                       for j in padded])
                    for i, j in enumerate(padded):
                        tp = self._plan_cached(j.shape, dtype, cfg, base=base)
                        res = tp.execute(slice_valid(slots[i], j.shape),
                                         donate=True)
                        done.append((j, res, tp, None))
        except Exception as e:  # noqa: BLE001 - fail the wave's jobs, not the pump
            finished = {id(j) for j, *_ in done}
            for j in jobs:
                if id(j) not in finished:
                    done.append((j, None, None, e))

        def finish():
            for _, res, _, _ in done:
                if res is not None:
                    jax.block_until_ready(res.tucker.core)
            t_done = time.perf_counter()
            events = []
            with self._lock:
                m = bs.metrics
                m.waves += 1
                m.pipelined_waves += inflight > 0
                m.inflight_sum += inflight
                m.lanes += lanes
                m.lanes_filled += len(jobs)
                self._counters["batches"] += 1
                for j, res, p, err in done:
                    j.result, j.error = res, err
                    if err is not None:
                        m.failed += 1
                        self._counters["failed"] += 1
                        events.append(("error", {"rid": j.rid,
                                                 "error": repr(err)}))
                    else:
                        lat = t_done - j.t_submit
                        m.completed += 1
                        m.padded += j.shape != bshape
                        m.true_elems += math.prod(j.shape)
                        m.slot_elems += math.prod(bshape)
                        m.latency.add(lat)
                        m.queue_wait.add(t_start - j.t_submit)
                        m.backends[p.backend] = m.backends.get(p.backend, 0) + 1
                        for meth in p.methods:
                            m.solvers[meth] = m.solvers.get(meth, 0) + 1
                        self._counters["requests"] += 1
                        self._latency.add(lat)
                        events.append(("done", {
                            "rid": j.rid, "bucket": list(bshape),
                            "latency_s": round(lat, 6),
                            "backend": p.backend,
                            "pad_waste": round(pad_waste(j.shape, bshape), 6)}))
                    self._pending -= 1
                    j.event.set()
                self._space.notify_all()
                self._idle.notify_all()
            self._emit("wave", bucket=list(bshape),
                       lanes=lanes, filled=len(jobs),
                       pad_mode=self._policy.pad_mode,
                       wall_s=round(t_done - t_start, 6))
            for kind, fields in events:
                self._emit(kind, **fields)
            if not record:
                # recorded waves fed drift per step (source="execute")
                # inside plan.execute already; here the only measurement
                # is the wave wall-clock, so amortize it across the wave's
                # completed jobs and attribute each job's share across its
                # plan's steps proportionally to their predictions — the
                # serve-traffic view of predicted-vs-actual calibration
                self._observe_wave_drift(done, t_done - t_start)

        return finish

    @staticmethod
    def _observe_wave_drift(done, wall_s: float) -> None:
        ok = [(j, p) for j, res, p, err in done
              if err is None and p is not None]
        if not ok or wall_s <= 0.0:
            return
        per_job = wall_s / len(ok)
        platform = jax.default_backend()
        for _, p in ok:
            total_pred = p.total_predicted_s
            if total_pred <= 0.0:
                continue
            for s in p.schedule:
                _drift.MONITOR.observe(
                    platform=platform, backend=s.backend, solver=s.method,
                    predicted_s=s.predicted_s,
                    actual_s=per_job * (s.predicted_s / total_pred),
                    source="serve")

    def _lane_fill(self, stack, n: int, p: TuckerPlan):
        """Round the wave's batch up to the policy's lane count with
        zero-filled lanes (bounding compiled batch sizes); sharded plans
        execute item-by-item, so filler lanes would be pure waste there."""
        lanes = self._policy.lanes_for(n)
        if lanes > n and p.backend != "sharded":
            fill = jnp.zeros((lanes - n, *stack.shape[1:]), stack.dtype)
            return jnp.concatenate([stack, fill]), lanes
        return stack, n

    def _run_recorded(self, j: _Job, bshape, dtype, cfg):
        """Eager timed execution for one request: per-step wall-clock feeds
        the autotune flywheel (the ambient recording() sink sees the traces
        via plan.execute itself; ``record_store`` harvests them here)."""
        try:
            if self._policy.pad_mode == "mask" and j.shape != bshape:
                p = self._plan_cached(bshape, dtype, cfg)
                res = p.execute(pad_block(jnp.asarray(j.x), bshape),
                                record=True)
                out = trim_result(res, j.shape)
            else:
                base = self._plans.get((bshape, dtype, cfg))
                p = self._plan_cached(j.shape, dtype, cfg, base=base)
                res = out = p.execute(jnp.asarray(j.x), record=True)
            if self._record_store is not None:
                from .. import tune
                tune.harvest_result(
                    res, self._record_store,
                    dtype=cfg.compute_dtype or dtype,
                    als_iters=cfg.als_iters)
            return (j, out, p, None)
        except Exception as e:  # noqa: BLE001 - per-job failure isolation
            return (j, None, None, e)

    # -- pumping -------------------------------------------------------------
    def _pump_once(self) -> bool:
        """Run one wave to completion inline; False when nothing is queued."""
        wave = self._take_wave()
        if wave is None:
            return False
        self._dispatch_wave(*wave)()
        return True

    def drain(self) -> None:
        """Complete everything admitted so far.  With a worker running this
        waits; otherwise it pumps waves inline, keeping up to
        ``max_inflight_waves`` in flight while successors are stacked (the
        same pipelining the worker does)."""
        if self._running:
            with self._lock:
                while self._pending > 0 and self._running:
                    self._idle.wait(timeout=0.1)
            return
        inflight: deque = deque()
        while True:
            wave = self._take_wave()
            if wave is None:
                break
            inflight.append(self._dispatch_wave(*wave,
                                                inflight=len(inflight)))
            while len(inflight) >= self._max_inflight:
                inflight.popleft()()
        while inflight:
            inflight.popleft()()

    # -- background worker (async mode) --------------------------------------
    def start(self) -> "TuckerService":
        """Spawn the background wave pump; ``submit`` becomes fire-and-
        forget and ``poll``/``wait`` observe completions as they land."""
        with self._lock:
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name="tucker-service")
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the worker (optionally draining the queue first)."""
        if self._running and drain:
            self.drain()
        with self._lock:
            self._running = False
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def close(self) -> None:
        """Refuse new submissions, drain what's queued, stop the worker,
        and close the trace file."""
        with self._lock:
            self._closed = True
        if self._running:
            self.stop(drain=True)
        else:
            self.drain()
        if self._trace:
            self._trace.close()

    def __enter__(self) -> "TuckerService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _pump(self) -> None:
        inflight: deque = deque()
        try:
            while True:
                wave = self._take_wave()
                if wave is None:
                    if inflight:
                        inflight.popleft()()
                        continue   # completions may have unblocked submits
                    with self._lock:
                        if not self._running:
                            break
                        if not any(b.queue for b in self._buckets.values()):
                            self._work.wait(timeout=0.05)
                    continue
                inflight.append(self._dispatch_wave(*wave,
                                                    inflight=len(inflight)))
                while len(inflight) >= self._max_inflight:
                    inflight.popleft()()
        finally:
            while inflight:
                inflight.popleft()()
            # a dying pump must not strand waiters: fail whatever remains
            with self._lock:
                if self._running:   # left the loop on an unexpected error
                    self._running = False
                    err = RuntimeError("service worker died; request was "
                                       "never executed")
                    for bs in self._buckets.values():
                        while bs.queue:
                            j = bs.queue.popleft()
                            j.error = err
                            self._pending -= 1
                            self._counters["failed"] += 1
                            bs.metrics.failed += 1
                            j.event.set()
                self._idle.notify_all()
                self._space.notify_all()

    # -- observability -------------------------------------------------------
    def _bucket_label(self, key, taken: set) -> str:
        bshape, dtype, cfg = key
        policy = (f"e{cfg.error_target:g}" if cfg.ranks is None
                  else "x".join(str(r) for r in cfg.ranks))
        label = "x".join(str(s) for s in bshape) + f"/{dtype}/r{policy}"
        if cfg.variant != "sthosvd":
            label += f"/{cfg.variant}"
        base, k = label, 2
        while label in taken:
            label, k = f"{base}#{k}", k + 1
        taken.add(label)
        return label

    def stats(self) -> dict:
        """Operator snapshot: global counters + per-bucket observability
        (p50/p95/p99 latency ms, queue depth, pad-waste, occupancy,
        backend/solver counts).  ``requests``/``batches``/``plans_built``/
        ``backends`` keep the batch engine's historical meanings."""
        with self._lock:
            taken: set = set()
            buckets = {}
            backends: dict = {}
            solvers: dict = {}
            true_elems = slot_elems = 0
            for key, bs in self._buckets.items():
                buckets[self._bucket_label(key, taken)] = \
                    bs.metrics.snapshot(queue_depth=len(bs.queue))
                for k, v in bs.metrics.backends.items():
                    backends[k] = backends.get(k, 0) + v
                for k, v in bs.metrics.solvers.items():
                    solvers[k] = solvers.get(k, 0) + v
                true_elems += bs.metrics.true_elems
                slot_elems += bs.metrics.slot_elems
            elapsed = time.perf_counter() - self._t0
            return {
                **self._counters,
                "pending": self._pending,
                "max_inflight_waves": self._max_inflight,
                "n_buckets": len(self._buckets),
                "backends": backends,
                "solvers": solvers,
                "pad_waste": round(1.0 - true_elems / slot_elems, 6)
                             if slot_elems else 0.0,
                "throughput_rps": self._counters["requests"] / elapsed
                                  if elapsed > 0 else 0.0,
                "latency": self._latency.snapshot_ms(),
                "buckets": buckets,
                # process-wide observability riding the operator snapshot:
                # compile-cache behaviour and predicted-vs-actual drift
                # (stale cells name the repro.tune rerun that repairs them)
                "sweep_cache": dict(CACHE_STATS),
                "drift": _drift.MONITOR.summary(),
            }
