"""Streaming Tucker serving: async submit/poll over shape buckets.

``TuckerBatchEngine.run()`` is a synchronous one-shot over a pre-collected
request list; production decomposition traffic is a *stream*.  This module
is the admission pipeline in front of the plan/execute machinery:

  * ``submit(x, config) -> Ticket`` routes the request into a shape bucket
    (:mod:`repro.serve.buckets` — odd shapes are zero-padded up to the
    nearest bucket; the slack is masked out of every Gram/TTM contribution
    so exact-mode results are bitwise-equal to unpadded execution) and
    enqueues it under a bounded-queue backpressure policy (``"reject"``
    raises :class:`RejectedError`, ``"block"`` waits for space).
  * Waves of up to ``policy.wave_slots`` lanes are formed per bucket and
    executed through the bucket's warm :class:`~repro.core.api.TuckerPlan`
    + one vmapped compiled sweep (the process-wide ``_SWEEP_CACHE``), with
    power-of-two lane fill bounding compiled batch sizes.  Dispatch is
    pipelined: while wave *i* runs on the device, the service completes
    wave *i−1* and stacks wave *i+1* from the queue — slots refill without
    stopping the stream, mirroring ``ServeEngine``'s slot loop.
  * ``poll(ticket)`` / ``wait(ticket)`` retrieve results; ``drain()`` runs
    or awaits everything queued.  ``start()`` spawns a background worker so
    ``submit`` returns immediately (async mode); without it the service is
    a synchronous pump (``drain`` executes inline).
  * ``stats()`` exposes per-bucket p50/p95/p99 latency, queue depth,
    pad-waste and lane-occupancy ratios, and backend/solver counters;
    ``trace_path=`` appends a JSONL event per submit/wave/completion.
  * ``record=True`` (or an ambient :func:`repro.tune.recording` context)
    runs waves through the eager timed path so served traffic feeds the
    autotune flywheel — optionally straight into a ``record_store``.

Every engine-level pin (``impl`` / ``mesh`` / ``memory_cap_bytes`` /
donation) flows through unchanged; ``TuckerBatchEngine`` is now a thin
synchronous wrapper over this service (identity bucket policy, unbounded
waves).

Failure isolation (see the repo README's "Resilience" section and
``docs/ARCHITECTURE.md``):

  * ``submit(..., validate="finite")`` (the default) rejects NaN/Inf
    inputs at admission with :class:`~repro.core.errors.InputError`
    naming the worst offending mode; ``deadline_s=`` bounds how long a
    request may wait — expired requests fail with
    :class:`~repro.core.errors.DeadlineError` at admission or pre-wave,
    without ever occupying a lane.
  * A failed fused wave is **bisected**: the wave re-runs in halves (at
    the original wave's lane count, so every sub-wave reuses the same
    compiled program and non-poisoned lanes stay bitwise-identical to a
    clean wave) until the poisoned request is quarantined alone; a lane
    that comes back non-finite is quarantined the same way.  The last
    resort for a single request is an exact isolated run, whose failure
    comes back *classified* (:func:`~repro.core.errors.coerce_exception`
    guarantees no unclassified exception ever escapes through ``poll``).
  * A per-bucket **circuit breaker** trips after ``breaker_threshold``
    consecutive wave failures: the bucket degrades to exact item-by-item
    execution, then half-opens after ``breaker_cooldown_s`` with a single
    fused probe wave.  ``stats()["resilience"]`` and :meth:`health`
    surface trips, states, and recovery counters.
  * ``submit(..., retries=n)`` grants a per-request retry budget: wave-
    level failures re-enqueue the job up to *n* times (input, deadline,
    and cancellation failures never retry).
"""

from __future__ import annotations

import math
import sys
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .. import chaos as _chaos
from ..core.api import CACHE_STATS, TuckerConfig, TuckerPlan, plan as make_plan
from ..core.errors import (CancelledError, DeadlineError, InputError,
                           NumericalError, ResourceError, check_finite,
                           coerce_exception)
from ..core.plan import validate_ranks
from ..obs import drift as _drift
from ..obs import trace as _obs
from ..core.sthosvd import SthosvdResult
from .buckets import BucketPolicy, pad_block, pad_waste, slice_valid, trim_result
from .metrics import BucketMetrics, LatencyWindow, TraceWriter

BACKPRESSURE_MODES = ("reject", "block")
VALIDATE_MODES = ("finite", "none")

#: errors that a retry budget never retries: the request itself is the
#: problem (bad input), or the caller already gave up (deadline, cancel)
_NO_RETRY = (InputError, DeadlineError, CancelledError)


class RejectedError(RuntimeError):
    """submit() refused a request: the admission queue is full (policy
    ``"reject"``) or could not make progress (``"block"`` with no runnable
    wave)."""


class ServiceClosed(RuntimeError):
    """submit() after close(): the service no longer admits requests."""


@dataclass
class Ticket:
    """Handle returned by :meth:`TuckerService.submit`; pass to ``poll`` /
    ``wait``.  ``padded`` says the request did not fit its bucket exactly
    (``bucket`` is the slot shape it was padded into); ``deadline_s`` is
    the admission deadline the request carries (None = none)."""
    rid: int
    shape: tuple[int, ...]
    bucket: tuple[int, ...]
    padded: bool
    submitted_at: float
    deadline_s: float | None = None
    _job: "_Job" = field(repr=False, default=None)


class _Job:
    """Internal per-request state (Ticket keeps the only reference once the
    job leaves the queue, so completed work is garbage-collected with its
    ticket)."""
    __slots__ = ("rid", "x", "config", "shape", "key", "t_submit",
                 "deadline", "retries_left", "result", "error", "event")

    def __init__(self, rid, x, config, shape, key, *, deadline=None,
                 retries=0):
        self.rid = rid
        self.x = x
        self.config = config
        self.shape = shape
        self.key = key
        self.t_submit = time.perf_counter()
        self.deadline = deadline       # absolute perf_counter, or None
        self.retries_left = retries
        self.result: SthosvdResult | None = None
        self.error: Exception | None = None
        self.event = threading.Event()


class _Breaker:
    """Per-bucket circuit breaker over FUSED wave execution.

    ``closed`` — waves run fused (the fast path).  After ``threshold``
    consecutive wave failures the breaker opens: the bucket degrades to
    exact item-by-item execution (``"isolated"``), trading throughput for
    blast-radius-one.  After ``cooldown_s`` one wave is dispatched fused
    as a probe (``half_open``); success re-closes the breaker, failure
    re-opens it for another cooldown.

    ``trips`` counts only closed→open transitions, so concurrent failure
    reports cannot double-count a single trip.  Every transition happens
    under the service lock.
    """
    __slots__ = ("threshold", "cooldown_s", "state", "consecutive",
                 "opened_at", "probing", "trips", "reopens")

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self.consecutive = 0
        self.opened_at = 0.0
        self.probing = False
        self.trips = 0
        self.reopens = 0

    def route(self, now: float) -> str:
        """How the next wave should run: ``"fused"`` | ``"isolated"`` |
        ``"probe"`` (fused, but its outcome decides reopen-vs-close).
        Claims the probe slot, so only one probe is in flight at a time."""
        if self.state == "closed":
            return "fused"
        if not self.probing and now - self.opened_at >= self.cooldown_s:
            self.probing = True
            self.state = "half_open"
            return "probe"
        return "isolated"

    def on_result(self, ok: bool, now: float) -> bool:
        """Outcome of a non-probe fused wave; True when this report TRIPPED
        the breaker (closed→open) — the only transition that counts as a
        trip, so a burst of concurrent failures trips exactly once."""
        if ok:
            self.consecutive = 0
            return False
        self.consecutive += 1
        if self.state == "closed" and self.consecutive >= self.threshold:
            self.state = "open"
            self.opened_at = now
            self.trips += 1
            return True
        return False

    def on_probe(self, ok: bool, now: float) -> None:
        """Outcome of the half-open probe wave."""
        self.probing = False
        if ok:
            self.state = "closed"
            self.consecutive = 0
        else:
            self.state = "open"
            self.opened_at = now
            self.reopens += 1

    def snapshot(self) -> dict:
        return {"state": self.state, "trips": self.trips,
                "reopens": self.reopens,
                "consecutive_failures": self.consecutive}


class _BucketState:
    __slots__ = ("key", "queue", "metrics", "breaker")

    def __init__(self, key, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0):
        self.key = key
        self.queue: deque[_Job] = deque()
        self.metrics = BucketMetrics(bucket=key[0])
        self.breaker = _Breaker(breaker_threshold, breaker_cooldown_s)


class TuckerService:
    """Continuous-batching decomposition service (see module docstring).

    ``impl`` / ``mesh`` / ``shard_axis`` / ``memory_cap_bytes`` pin every
    plan the service builds, with exactly the semantics the batch engine
    documented (request configs keep the tighter memory cap; a mesh is
    dropped under a single-device impl pin).  ``policy`` is the
    :class:`~repro.serve.buckets.BucketPolicy`; ``max_queue`` bounds total
    queued requests (None = unbounded, backpressure off).

    ``max_inflight_waves`` bounds CROSS-WAVE PIPELINING: how many dispatched
    waves may be awaiting results while the pump stacks the next one.  JAX
    dispatch is async, so each in-flight wave overlaps device execution with
    host-side padding/stacking of its successors — mode-group k of wave i+1
    is being built (and dispatched) while wave i still computes.  ``1`` is
    fully serial (dispatch → block → next), ``2`` (default) the classic
    one-ahead pipeline the service always did, higher values deepen the
    window for streams of small waves.  Per-bucket ``pipeline_occupancy``
    in :meth:`stats` reports how often the window was actually used.

    ``breaker_threshold`` / ``breaker_cooldown_s`` configure the per-bucket
    circuit breaker (consecutive wave failures before fused execution is
    suspended, and how long before a fused probe is attempted).

    Synchronous use (the engine wrapper, offline batches)::

        svc = TuckerService()
        t = svc.submit(x, cfg)
        svc.drain()
        res = svc.poll(t)

    Streaming use::

        with TuckerService(max_queue=256, backpressure="block") as svc:
            svc.start()
            tickets = [svc.submit(x, cfg) for x in stream]
            results = [svc.wait(t) for t in tickets]
    """

    def __init__(self, selector=None, *, policy: BucketPolicy | None = None,
                 impl: str | None = None, mesh=None,
                 shard_axis: str | None = None,
                 memory_cap_bytes: int | None = None,
                 max_queue: int | None = 1024,
                 backpressure: str = "reject",
                 max_inflight_waves: int = 2,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0,
                 record: bool = False, record_store=None,
                 trace_path=None):
        if backpressure not in BACKPRESSURE_MODES:
            raise ValueError(f"backpressure {backpressure!r} not in "
                             f"{BACKPRESSURE_MODES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 or None (unbounded)")
        if max_inflight_waves < 1:
            raise ValueError("max_inflight_waves must be >= 1 (1 = serial "
                             "dispatch, 2 = classic one-ahead pipelining)")
        if breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        if breaker_cooldown_s <= 0:
            raise ValueError("breaker_cooldown_s must be > 0")
        self._selector = selector
        self._policy = policy if policy is not None else BucketPolicy()
        self._impl = "sharded" if impl is None and mesh is not None else impl
        self._mesh = mesh
        self._shard_axis = shard_axis
        self._cap = memory_cap_bytes
        self._max_queue = max_queue
        self._backpressure = backpressure
        self._max_inflight = int(max_inflight_waves)
        self._breaker_threshold = int(breaker_threshold)
        self._breaker_cooldown = float(breaker_cooldown_s)
        self._record = record
        self._record_store = record_store
        self._trace = TraceWriter(trace_path) if trace_path else None

        self._plans: dict[tuple, TuckerPlan] = {}
        self._buckets: dict[tuple, _BucketState] = {}
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._pending = 0          # queued + in-flight, not yet completed
        self._inflight_jobs: set[_Job] = set()
        self._active_bucket: tuple | None = None
        self._next_rid = 0
        self._counters = {"submitted": 0, "requests": 0, "rejected": 0,
                          "failed": 0, "batches": 0, "plans_built": 0}
        self._res = {"deadline_expired": 0, "cancelled": 0, "retried": 0,
                     "bisections": 0, "quarantined": 0, "recovered": 0,
                     "isolated_waves": 0, "probe_waves": 0}
        self._latency = LatencyWindow()
        self._t0 = time.perf_counter()
        self._thread: threading.Thread | None = None
        self._running = False
        self._worker_failed = False
        self._closed = False

    # -- tracing -------------------------------------------------------------
    def _emit(self, kind: str, **fields) -> None:
        """One serve event, to BOTH sinks: the service's own JSONL
        TraceWriter (when ``trace_path`` was given — schema unchanged) and
        the process-wide :mod:`repro.obs` event bus (no-op unless tracing
        is enabled), so a bus capture ties serve lifecycle events to the
        plan/execute/compile spans underneath them."""
        if self._trace:
            self._trace.event(kind, **fields)
        _obs.event(kind, **fields)

    # -- config pinning (the engine's fleet-operator knobs) ------------------
    def _pinned(self, config: TuckerConfig) -> TuckerConfig:
        from ..core.backend import get_backend

        impl = self._impl if self._impl is not None else config.impl
        mesh, axis = config.mesh, config.shard_axis
        if mesh is None and self._mesh is not None:
            mesh, axis = self._mesh, self._shard_axis or config.shard_axis
        if impl != "auto" and not get_backend(impl).requires_mesh:
            mesh = None   # pinned single-device backend: a mesh is moot
        cap = config.memory_cap_bytes
        if self._cap is not None:
            cap = self._cap if cap is None else min(cap, self._cap)
        if (impl, mesh, axis, cap) != (config.impl, config.mesh,
                                       config.shard_axis,
                                       config.memory_cap_bytes):
            config = replace(config, impl=impl, mesh=mesh, shard_axis=axis,
                             memory_cap_bytes=cap)
        return config

    # -- plan cache ----------------------------------------------------------
    def plan_for(self, shape, dtype, config: TuckerConfig) -> TuckerPlan:
        """The (cached) plan a request of this (shape, dtype, config) runs
        under the service's pins — built on first use, reused forever."""
        return self._plan_cached(tuple(int(s) for s in shape),
                                 str(jnp.dtype(dtype)), self._pinned(config))

    def _plan_cached(self, shape: tuple, dtype: str, pinned: TuckerConfig,
                     *, base: TuckerPlan | None = None) -> TuckerPlan:
        key = (shape, dtype, pinned)
        p = self._plans.get(key)
        if p is None:
            if base is not None:
                # derive from the bucket's warm plan (same config/dtype):
                # the api-level reuse hook for padded member shapes
                p = base.for_shape(shape, selector=self._selector)
            else:
                p = make_plan(shape, dtype, pinned, selector=self._selector)
            # plan building happens outside the lock (it can be slow); two
            # threads may race here, in which case the first insert wins
            with self._lock:
                if key in self._plans:
                    return self._plans[key]
                self._plans[key] = p
                self._counters["plans_built"] += 1
        return p

    # -- admission -----------------------------------------------------------
    def submit(self, x, config: TuckerConfig, *, rid: int | None = None,
               deadline_s: float | None = None, retries: int = 0,
               validate: str | None = "finite") -> Ticket:
        """Admit one decomposition request; returns a :class:`Ticket`.

        Validation (ranks vs the TRUE shape) happens here so a bad request
        fails its caller, not the wave that picks it up.
        ``validate="finite"`` (the default) additionally rejects NaN/Inf
        inputs at admission with :class:`~repro.core.errors.InputError`
        naming the worst offending mode; pass ``validate="none"`` to skip
        the check on trusted traffic.  ``deadline_s`` bounds the request's
        total time in the service: a request still queued when its deadline
        passes fails with :class:`~repro.core.errors.DeadlineError` instead
        of occupying a lane.  ``retries`` is a per-request budget of wave-
        level retry attempts (input/deadline/cancel failures never retry).

        When the queue is at ``max_queue``: ``backpressure="reject"``
        raises :class:`RejectedError` immediately; ``"block"`` waits for
        space — against the background worker when running, otherwise by
        pumping a wave inline (synchronous callers backpressure themselves
        by doing the work).
        """
        if self._closed:
            raise ServiceClosed("service is closed to new submissions")
        if validate is None:
            validate = "none"
        if validate not in VALIDATE_MODES:
            raise ValueError(f"validate {validate!r} not in {VALIDATE_MODES}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")
        if retries < 0:
            raise ValueError("retries must be >= 0")
        t_adm = time.perf_counter()
        if not hasattr(x, "shape"):
            x = jnp.asarray(x)
        shape = tuple(int(s) for s in x.shape)
        if config.ranks is not None:
            validate_ranks(shape, config.ranks)
        # rank-adaptive configs (error_target, ranks=None) have no ranks to
        # validate here: per-mode ranks resolve per input at execute time,
        # and the config's own __post_init__ already validated the target
        if validate == "finite":
            check_finite(x, name="request input")
        pinned = self._pinned(config)
        dtype = str(jnp.dtype(x.dtype))
        bshape = self._policy.bucket_shape(shape)
        key = (bshape, dtype, pinned)
        deadline = t_adm + deadline_s if deadline_s is not None else None
        while True:
            with self._lock:
                if self._closed:
                    raise ServiceClosed("service is closed to new submissions")
                bs = self._buckets.get(key)
                if bs is None:
                    bs = self._buckets[key] = _BucketState(
                        key, self._breaker_threshold, self._breaker_cooldown)
                if self._max_queue is None or self._pending < self._max_queue:
                    if rid is None:
                        rid = self._next_rid
                    self._next_rid = max(self._next_rid, rid) + 1
                    job = _Job(rid, x, pinned, shape, key,
                               deadline=deadline, retries=retries)
                    bs.queue.append(job)
                    bs.metrics.submitted += 1
                    self._pending += 1
                    self._counters["submitted"] += 1
                    self._work.notify_all()
                    break
                if self._backpressure == "reject":
                    bs.metrics.rejected += 1
                    self._counters["rejected"] += 1
                    self._emit("reject", rid=rid, shape=list(shape),
                               bucket=list(bshape))
                    raise RejectedError(
                        f"admission queue full ({self._max_queue} pending); "
                        "retry later or use backpressure='block'")
                if deadline is not None and time.perf_counter() >= deadline:
                    bs.metrics.rejected += 1
                    self._counters["rejected"] += 1
                    raise DeadlineError(
                        f"request missed its {deadline_s}s deadline while "
                        "blocked on admission (queue full)")
                if self._running:
                    self._space.wait(timeout=0.1)
                    continue
            # block policy, no worker: free space by running a wave here
            if not self._pump_once():
                raise RejectedError(
                    "queue full under backpressure='block' with no worker "
                    "running and no runnable wave")
        self._emit("submit", rid=job.rid, shape=list(shape),
                   bucket=list(bshape), padded=shape != bshape)
        return Ticket(rid=job.rid, shape=shape, bucket=bshape,
                      padded=shape != bshape, submitted_at=time.time(),
                      deadline_s=deadline_s, _job=job)

    # -- retrieval -----------------------------------------------------------
    def poll(self, ticket: Ticket) -> SthosvdResult | None:
        """Non-blocking: the request's result, or None while it is queued or
        in flight.  Re-raises the request's failure, if it failed."""
        job = ticket._job
        if job.error is not None:
            raise job.error
        return job.result

    def wait(self, ticket: Ticket, timeout: float | None = None) -> SthosvdResult:
        """Block until the request completes (driving the queue inline when
        no worker thread is running), then return its result."""
        job = ticket._job
        if not job.event.is_set() and not self._running:
            self.drain()
        if not job.event.wait(timeout):
            raise TimeoutError(f"request {ticket.rid} still pending after "
                               f"{timeout}s")
        return self.poll(ticket)

    def cancel(self, ticket: Ticket) -> bool:
        """Cancel a not-yet-dispatched request.  Returns True when the
        request was removed from its queue: its waiters unblock and
        ``poll``/``wait`` raise :class:`~repro.core.errors.CancelledError`.
        Returns False when the request already dispatched or completed —
        in-flight work is never interrupted (lanes are fused; killing one
        would kill its wave-mates)."""
        job = ticket._job
        with self._lock:
            bs = self._buckets.get(job.key)
            if bs is None or job not in bs.queue:
                return False
            bs.queue.remove(job)
            job.result = None
            job.error = CancelledError(
                f"request {job.rid} was cancelled before dispatch")
            self._pending -= 1
            self._counters["failed"] += 1
            bs.metrics.failed += 1
            bs.metrics.cancelled += 1
            self._res["cancelled"] += 1
            job.event.set()
            self._space.notify_all()
            self._idle.notify_all()
        self._emit("cancel", rid=job.rid, bucket=list(job.key[0]))
        return True

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in flight)."""
        with self._lock:
            return self._pending

    # -- wave formation ------------------------------------------------------
    def _take_wave(self) -> tuple[_BucketState, list[_Job]] | None:
        """Pop the next wave: up to ``wave_slots`` requests from the bucket
        whose head request has waited longest (FIFO across buckets)."""
        with self._lock:
            ready = [bs for bs in self._buckets.values() if bs.queue]
            if not ready:
                return None
            bs = min(ready, key=lambda b: b.queue[0].t_submit)
            k = len(bs.queue) if self._policy.wave_slots is None \
                else min(len(bs.queue), self._policy.wave_slots)
            jobs = [bs.queue.popleft() for _ in range(k)]
            self._inflight_jobs.update(jobs)
            return bs, jobs

    def _job_block(self, j: _Job, bshape):
        """One lane's input block (padded up to the bucket when needed),
        with the per-job chaos seams: ``wave_job`` fires (raise/oom/slow)
        and a due ``wave_job_data`` nan-rule poisons this lane's data —
        the synthetic "one bad request inside a fused wave"."""
        _chaos.fire("wave_job", rid=j.rid)
        xb = jnp.asarray(j.x)
        if j.shape != bshape:
            xb = pad_block(xb, bshape)
        if _chaos.active() and _chaos.poison("wave_job_data", rid=j.rid):
            xb = xb * float("nan")
        return xb

    def _dispatch_wave(self, bs: _BucketState, jobs: list[_Job],
                       inflight: int = 0):
        """Execute one wave (dispatch only — JAX returns futures) and hand
        back a ``finish()`` closure that blocks on the results, completes
        the tickets, and updates metrics.  The pump keeps up to
        ``max_inflight_waves`` dispatched-but-unfinished waves, so host-side
        stacking and padding overlap device execution; ``inflight`` is how
        many earlier waves were still in flight at this dispatch (recorded
        as pipeline occupancy).

        ``finish()`` is also where failure isolation lives: jobs whose
        results never materialized (wave exception, async device failure,
        or a non-finite fused lane) are recovered — fused groups by
        bisection at the original lane count, everything else by an exact
        isolated re-run — and whatever still fails comes back as a
        *classified* error."""
        bshape, dtype, cfg = bs.key
        t_start = time.perf_counter()
        done: list[tuple[_Job, SthosvdResult | None, TuckerPlan | None,
                         Exception | None]] = []
        # pre-wave deadline sweep: expired requests fail here, before the
        # wave is stacked, so they never occupy a lane
        live: list[_Job] = []
        for j in jobs:
            if j.deadline is not None and t_start >= j.deadline:
                done.append((j, None, None, DeadlineError(
                    f"request {j.rid} missed its deadline before dispatch "
                    f"(queued {t_start - j.t_submit:.3f}s)")))
            else:
                live.append(j)
        lanes = len(live)
        fused_group: list[_Job] = []   # jobs sharing ONE stacked dispatch
        fused_lanes: int | None = None
        wave_exc: Exception | None = None
        tune = sys.modules.get("repro.tune")
        record = self._record or (
            tune is not None and tune.active_sink() is not None)
        with self._lock:
            self._active_bucket = bs.key
            route = bs.breaker.route(t_start) if (live and not record) \
                else "fused"
            if route == "isolated":
                self._res["isolated_waves"] += 1
            elif route == "probe":
                self._res["probe_waves"] += 1
        try:
            if not live:
                pass
            elif record:
                for j in live:
                    done.append(self._run_recorded(j, bshape, dtype, cfg))
            elif route == "isolated":
                # breaker open: exact item-by-item execution at each
                # request's true shape — no fused wave left to poison
                for j in live:
                    done.append(self._run_isolated(j, bs))
            elif self._policy.pad_mode == "mask" and \
                    any(j.shape != bshape for j in live):
                # mask mode: mixed true shapes fuse into ONE vmapped wave at
                # the bucket shape; zero slack is arithmetically inert and
                # the factors' slack rows come back exactly zero, so each
                # lane trims to its true shape afterwards
                p = self._plan_cached(bshape, dtype, cfg)
                _chaos.fire("wave", bucket=bshape, n=len(live))
                fused_group = list(live)
                stack = jnp.stack([self._job_block(j, bshape) for j in live])
                stack, lanes = self._lane_fill(stack, len(live), p)
                fused_lanes = lanes
                results = p.execute_batch(stack, donate=True)[:len(live)]
                for j, r in zip(live, results):
                    r = trim_result(r, j.shape) if j.shape != bshape else r
                    done.append((j, r, p, None))
            else:
                exact = [j for j in live if j.shape == bshape]
                padded = [j for j in live if j.shape != bshape]
                if exact:
                    p = self._plan_cached(bshape, dtype, cfg)
                    _chaos.fire("wave", bucket=bshape, n=len(exact))
                    if len(exact) == 1 and self._policy.lanes_for(1) == 1:
                        # singleton: share the unbatched compiled sweep
                        _chaos.fire("wave_job", rid=exact[0].rid)
                        res = p.execute(jnp.asarray(exact[0].x))
                        done.append((exact[0], res, p, None))
                    else:
                        fused_group = list(exact)
                        stack = jnp.stack([self._job_block(j, bshape)
                                           for j in exact])
                        stack, lanes_e = self._lane_fill(stack, len(exact), p)
                        fused_lanes = lanes_e
                        lanes = lanes_e + len(padded)
                        results = p.execute_batch(stack, donate=True)
                        for j, r in zip(exact, results):
                            done.append((j, r, p, None))
                if padded:
                    # the admission slot buffer: every padded member lands in
                    # a bucket-shaped slot; exact mode then slices the valid
                    # block back out (bitwise-lossless) and runs it through
                    # the plan its TRUE shape resolves to — the identical
                    # cached program a direct decompose() would run, which
                    # is what makes padded results bitwise-equal to
                    # unpadded execution
                    base = self._plans.get((bshape, dtype, cfg))
                    slots = jnp.stack([pad_block(jnp.asarray(j.x), bshape)
                                       for j in padded])
                    for i, j in enumerate(padded):
                        _chaos.fire("wave_job", rid=j.rid)
                        tp = self._plan_cached(j.shape, dtype, cfg, base=base)
                        res = tp.execute(slice_valid(slots[i], j.shape),
                                         donate=True)
                        done.append((j, res, tp, None))
        except Exception as e:  # noqa: BLE001 - recovered in finish(), not here
            wave_exc = e

        def finish():
            # 1) collect what needs recovery: jobs the wave never produced a
            #    result for, async device failures, and poisoned fused lanes
            fused_ids = {id(j) for j in fused_group}
            recover: list[_Job] = []
            if wave_exc is not None:
                executed = {id(j) for j, *_ in done}
                recover.extend(j for j in live if id(j) not in executed)
            final: list = []
            quarantined = 0
            for j, res, p, err in done:
                if res is None:
                    final.append((j, res, p, err))
                    continue
                try:
                    jax.block_until_ready(res.tucker.core)
                except Exception:  # noqa: BLE001 - async failure -> recovery
                    recover.append(j)
                    continue
                if id(j) in fused_ids and not bool(
                        jnp.all(jnp.isfinite(res.tucker.core))):
                    # poisoned lane quarantine: re-derive THIS lane alone;
                    # every other lane keeps its fused result untouched
                    quarantined += 1
                    recover.append(j)
                    continue
                final.append((j, res, p, err))
            wave_ok = not recover
            if quarantined:
                with self._lock:
                    self._res["quarantined"] += quarantined
            # 2) recover: fused members by bisection at the original lane
            #    count (same compiled program -> clean lanes stay bitwise-
            #    identical), the rest by one exact isolated re-run
            recovered_ids = {id(j) for j in recover}
            if recover:
                fused_rec = [j for j in recover if id(j) in fused_ids]
                other_rec = [j for j in recover if id(j) not in fused_ids]
                if fused_rec:
                    hint = fused_lanes if fused_lanes is not None \
                        else self._policy.lanes_for(len(fused_group))
                    final.extend(self._bisect(bs, fused_rec, hint))
                for j in other_rec:
                    final.append(self._run_isolated(j, bs))
            # 3) breaker bookkeeping (fused waves only; recorded and
            #    already-isolated waves say nothing about the fused path)
            breaker_events = []
            if live and not record:
                with self._lock:
                    if route == "probe":
                        was = bs.breaker.state
                        bs.breaker.on_probe(wave_ok, time.perf_counter())
                        if wave_ok and was != "closed":
                            breaker_events.append(
                                ("breaker_close", {"bucket": list(bshape)}))
                    elif route == "fused":
                        if bs.breaker.on_result(wave_ok,
                                                time.perf_counter()):
                            breaker_events.append(
                                ("breaker_open",
                                 {"bucket": list(bshape),
                                  "after_failures": bs.breaker.consecutive}))
            # 4) retry budget: requeue retryable failures instead of
            #    completing them (bad-input / deadline / cancel never retry)
            requeue: list[_Job] = []
            completed: list = []
            for entry in final:
                j, res, p, err = entry
                if (err is not None and j.retries_left > 0
                        and not isinstance(err, _NO_RETRY)):
                    j.retries_left -= 1
                    requeue.append(j)
                else:
                    completed.append(entry)
            t_done = time.perf_counter()
            events = []
            with self._lock:
                self._inflight_jobs.difference_update(jobs)
                m = bs.metrics
                m.waves += 1
                m.pipelined_waves += inflight > 0
                m.inflight_sum += inflight
                m.lanes += lanes
                m.lanes_filled += len(live)
                m.quarantined += quarantined
                self._counters["batches"] += 1
                for j in requeue:
                    bs.queue.append(j)
                    m.retried += 1
                    self._res["retried"] += 1
                    events.append(("retry", {"rid": j.rid,
                                             "left": j.retries_left}))
                if requeue:
                    self._work.notify_all()
                for j, res, p, err in completed:
                    if j.event.is_set():
                        # already finalized elsewhere (cancelled while
                        # queued for retry, or abandoned by a force-stop)
                        continue
                    j.result, j.error = res, err
                    if err is not None:
                        m.failed += 1
                        self._counters["failed"] += 1
                        if isinstance(err, DeadlineError):
                            m.deadline_expired += 1
                            self._res["deadline_expired"] += 1
                        events.append(("error", {"rid": j.rid,
                                                 "error": repr(err)}))
                    else:
                        lat = t_done - j.t_submit
                        m.completed += 1
                        m.padded += j.shape != bshape
                        m.true_elems += math.prod(j.shape)
                        m.slot_elems += math.prod(bshape)
                        m.latency.add(lat)
                        m.queue_wait.add(t_start - j.t_submit)
                        m.backends[p.backend] = m.backends.get(p.backend, 0) + 1
                        for meth in p.methods:
                            m.solvers[meth] = m.solvers.get(meth, 0) + 1
                        if id(j) in recovered_ids:
                            m.recovered += 1
                            self._res["recovered"] += 1
                        self._counters["requests"] += 1
                        self._latency.add(lat)
                        events.append(("done", {
                            "rid": j.rid, "bucket": list(bshape),
                            "latency_s": round(lat, 6),
                            "backend": p.backend,
                            "pad_waste": round(pad_waste(j.shape, bshape), 6)}))
                    self._pending -= 1
                    j.event.set()
                if self._active_bucket == bs.key:
                    self._active_bucket = None
                self._space.notify_all()
                self._idle.notify_all()
            self._emit("wave", bucket=list(bshape),
                       lanes=lanes, filled=len(live),
                       pad_mode=self._policy.pad_mode, route=route,
                       wall_s=round(t_done - t_start, 6))
            for kind, fields in breaker_events:
                self._emit(kind, **fields)
            for kind, fields in events:
                self._emit(kind, **fields)
            if not record:
                # recorded waves fed drift per step (source="execute")
                # inside plan.execute already; here the only measurement
                # is the wave wall-clock, so amortize it across the wave's
                # completed jobs and attribute each job's share across its
                # plan's steps proportionally to their predictions — the
                # serve-traffic view of predicted-vs-actual calibration
                self._observe_wave_drift(completed, t_done - t_start)

        return finish

    # -- failure recovery ----------------------------------------------------
    def _fused_sync(self, bs: _BucketState, group: list[_Job],
                    lanes_hint: int) -> list:
        """Re-run ``group`` as one fused wave padded to ``lanes_hint`` lanes
        — the ORIGINAL wave's lane count, so the sub-wave reuses the same
        compiled program and every lane's result is bitwise-identical to
        the one a clean wave would have produced.  Blocks on the results
        and raises if any lane fails or comes back non-finite (the bisect
        driver then halves the group)."""
        bshape, dtype, cfg = bs.key
        p = self._plan_cached(bshape, dtype, cfg)
        stack = jnp.stack([self._job_block(j, bshape) for j in group])
        if lanes_hint > len(group) and p.backend != "sharded":
            fill = jnp.zeros((lanes_hint - len(group), *stack.shape[1:]),
                             stack.dtype)
            stack = jnp.concatenate([stack, fill])
        results = p.execute_batch(stack, donate=True)[:len(group)]
        out = []
        for j, r in zip(group, results):
            jax.block_until_ready(r.tucker.core)
            if not bool(jnp.all(jnp.isfinite(r.tucker.core))):
                raise NumericalError(
                    f"request {j.rid}: fused lane produced a non-finite "
                    "core (poisoned wave member)")
            rr = trim_result(r, j.shape) if j.shape != bshape else r
            out.append((j, rr, p, None))
        return out

    def _bisect(self, bs: _BucketState, group: list[_Job],
                lanes_hint: int) -> list:
        """Wave bisection: retry the failed group fused; on failure halve
        it and recurse, so a single poisoned request is quarantined alone
        while its wave-mates complete.  The singleton base case falls back
        to an exact isolated run, whose failure comes back classified."""
        if not group:
            return []
        try:
            return self._fused_sync(bs, group, lanes_hint)
        except Exception:  # noqa: BLE001 - halve and isolate
            if len(group) == 1:
                return [self._run_isolated(group[0], bs)]
            with self._lock:
                self._res["bisections"] += 1
            self._emit("bisect", bucket=list(bs.key[0]), n=len(group))
            mid = len(group) // 2
            return (self._bisect(bs, group[:mid], lanes_hint)
                    + self._bisect(bs, group[mid:], lanes_hint))

    def _run_isolated(self, j: _Job, bs: _BucketState):
        """Exact single-request execution at the request's TRUE shape — the
        breaker-open path and the last resort for a quarantined request.
        Runs under ``validate="finite"`` so a poisoned result is caught
        (and the plan's own fallback ladder gets a chance to recover it);
        failures come back classified, never raw."""
        bshape, dtype, cfg = bs.key
        try:
            _chaos.fire("wave_job", rid=j.rid)
            base = self._plans.get((bshape, dtype, cfg))
            tp = self._plan_cached(j.shape, dtype, cfg, base=base)
            res = tp.execute(jnp.asarray(j.x), validate="finite")
            return (j, res, tp, None)
        except Exception as e:  # noqa: BLE001 - per-job failure isolation
            return (j, None, None, coerce_exception(e))

    @staticmethod
    def _observe_wave_drift(done, wall_s: float) -> None:
        ok = [(j, p) for j, res, p, err in done
              if err is None and p is not None]
        if not ok or wall_s <= 0.0:
            return
        per_job = wall_s / len(ok)
        platform = jax.default_backend()
        for _, p in ok:
            total_pred = p.total_predicted_s
            if total_pred <= 0.0:
                continue
            for s in p.schedule:
                _drift.MONITOR.observe(
                    platform=platform, backend=s.backend, solver=s.method,
                    predicted_s=s.predicted_s,
                    actual_s=per_job * (s.predicted_s / total_pred),
                    source="serve")

    def _lane_fill(self, stack, n: int, p: TuckerPlan):
        """Round the wave's batch up to the policy's lane count with
        zero-filled lanes (bounding compiled batch sizes); sharded plans
        execute item-by-item, so filler lanes would be pure waste there."""
        lanes = self._policy.lanes_for(n)
        if lanes > n and p.backend != "sharded":
            fill = jnp.zeros((lanes - n, *stack.shape[1:]), stack.dtype)
            return jnp.concatenate([stack, fill]), lanes
        return stack, n

    def _run_recorded(self, j: _Job, bshape, dtype, cfg):
        """Eager timed execution for one request: per-step wall-clock feeds
        the autotune flywheel (the ambient recording() sink sees the traces
        via plan.execute itself; ``record_store`` harvests them here)."""
        try:
            if self._policy.pad_mode == "mask" and j.shape != bshape:
                p = self._plan_cached(bshape, dtype, cfg)
                res = p.execute(pad_block(jnp.asarray(j.x), bshape),
                                record=True)
                out = trim_result(res, j.shape)
            else:
                base = self._plans.get((bshape, dtype, cfg))
                p = self._plan_cached(j.shape, dtype, cfg, base=base)
                res = out = p.execute(jnp.asarray(j.x), record=True)
            if self._record_store is not None:
                from .. import tune
                tune.harvest_result(
                    res, self._record_store,
                    dtype=cfg.compute_dtype or dtype,
                    als_iters=cfg.als_iters)
            return (j, out, p, None)
        except Exception as e:  # noqa: BLE001 - per-job failure isolation
            return (j, None, None, coerce_exception(e))

    # -- pumping -------------------------------------------------------------
    def _pump_once(self) -> bool:
        """Run one wave to completion inline; False when nothing is queued."""
        wave = self._take_wave()
        if wave is None:
            return False
        self._dispatch_wave(*wave)()
        return True

    def drain(self) -> None:
        """Complete everything admitted so far.  With a worker running this
        waits; otherwise it pumps waves inline, keeping up to
        ``max_inflight_waves`` in flight while successors are stacked (the
        same pipelining the worker does)."""
        if self._running:
            with self._lock:
                while self._pending > 0 and self._running:
                    self._idle.wait(timeout=0.1)
            return
        inflight: deque = deque()
        while True:
            wave = self._take_wave()
            if wave is None:
                if inflight:
                    # retried jobs may have re-entered the queue from a
                    # finish(); complete in-flight waves, then re-check
                    inflight.popleft()()
                    continue
                break
            inflight.append(self._dispatch_wave(*wave,
                                                inflight=len(inflight)))
            while len(inflight) >= self._max_inflight:
                inflight.popleft()()
        while inflight:
            inflight.popleft()()

    # -- background worker (async mode) --------------------------------------
    def start(self) -> "TuckerService":
        """Spawn the background wave pump; ``submit`` becomes fire-and-
        forget and ``poll``/``wait`` observe completions as they land."""
        with self._lock:
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name="tucker-service")
        self._thread.start()
        return self

    def stop(self, drain: bool = True, *, force: bool = False,
             join_timeout: float = 30.0) -> None:
        """Stop the worker.  ``drain=True`` (default) completes the queue
        first; ``force=True`` abandons queued AND in-flight work instead —
        every unfinished job fails with a classified
        :class:`~repro.core.errors.ResourceError` and its waiters unblock
        immediately.  If the worker thread does not join within
        ``join_timeout`` seconds (a wedged wave), a ``RuntimeWarning``
        names the bucket it was last dispatching instead of returning
        silently; the daemonic thread is then abandoned."""
        if self._running and drain and not force:
            self.drain()
        with self._lock:
            self._running = False
            if force:
                self._abandon_unfinished_locked(
                    "service stopped with force=True; request was "
                    "abandoned before completing")
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=join_timeout)
            if self._thread.is_alive():
                with self._lock:
                    stuck = self._active_bucket
                where = ("bucket " + "x".join(str(s) for s in stuck[0])
                         if stuck else "an unknown bucket")
                warnings.warn(
                    f"service worker did not stop within {join_timeout}s; "
                    f"it was last dispatching {where} — abandoning the "
                    "daemonic worker thread (use stop(force=True) to fail "
                    "its jobs immediately)", RuntimeWarning, stacklevel=2)
            self._thread = None

    def _abandon_unfinished_locked(self, reason: str) -> None:
        """Fail every queued and in-flight job with a ResourceError (caller
        holds the lock).  The finish() of a still-running wave skips jobs
        whose event is already set, so nothing is completed twice."""
        err = ResourceError(reason)
        stranded: list[_Job] = []
        for bs in self._buckets.values():
            while bs.queue:
                stranded.append(bs.queue.popleft())
        stranded.extend(j for j in self._inflight_jobs
                        if not j.event.is_set())
        self._inflight_jobs.clear()
        for j in stranded:
            if j.event.is_set():
                continue
            j.result, j.error = None, err
            self._pending -= 1
            self._counters["failed"] += 1
            self._buckets[j.key].metrics.failed += 1
            j.event.set()
        self._idle.notify_all()
        self._space.notify_all()

    def close(self) -> None:
        """Refuse new submissions, drain what's queued, stop the worker,
        and close the trace file."""
        with self._lock:
            self._closed = True
        if self._running:
            self.stop(drain=True)
        else:
            self.drain()
        if self._trace:
            self._trace.close()

    def __enter__(self) -> "TuckerService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _pump(self) -> None:
        inflight: deque = deque()
        died: Exception | None = None
        try:
            while True:
                if _chaos.active():
                    _chaos.fire("worker")
                wave = self._take_wave()
                if wave is None:
                    if inflight:
                        inflight.popleft()()
                        continue   # completions may have unblocked submits
                    with self._lock:
                        if not self._running:
                            break
                        if not any(b.queue for b in self._buckets.values()):
                            self._work.wait(timeout=0.05)
                    continue
                inflight.append(self._dispatch_wave(*wave,
                                                    inflight=len(inflight)))
                while len(inflight) >= self._max_inflight:
                    inflight.popleft()()
        except Exception as e:  # noqa: BLE001 - a dying pump must fail its jobs
            died = e
        finally:
            while inflight:
                inflight.popleft()()
            # a dying pump must not strand waiters: fail whatever remains
            with self._lock:
                if self._running:   # left the loop on an unexpected error
                    self._running = False
                    self._worker_failed = True
                    reason = "service worker died; request was never executed"
                    if died is not None:
                        reason += f" (worker failure: {died!r})"
                    self._abandon_unfinished_locked(reason)
                self._idle.notify_all()
                self._space.notify_all()

    # -- observability -------------------------------------------------------
    def _bucket_label(self, key, taken: set) -> str:
        bshape, dtype, cfg = key
        policy = (f"e{cfg.error_target:g}" if cfg.ranks is None
                  else "x".join(str(r) for r in cfg.ranks))
        label = "x".join(str(s) for s in bshape) + f"/{dtype}/r{policy}"
        if cfg.variant != "sthosvd":
            label += f"/{cfg.variant}"
        base, k = label, 2
        while label in taken:
            label, k = f"{base}#{k}", k + 1
        taken.add(label)
        return label

    def health(self) -> dict:
        """Liveness/readiness probe: ``"ok"`` | ``"degraded"`` (some
        bucket's breaker is not closed — fused serving suspended there) |
        ``"unhealthy"`` (the worker died unexpectedly).  Cheap: counters
        only, never touches the device."""
        with self._lock:
            taken: set = set()
            open_buckets = [self._bucket_label(bs.key, taken)
                            for bs in self._buckets.values()
                            if bs.breaker.state != "closed"]
            if self._worker_failed:
                status = "unhealthy"
            elif open_buckets:
                status = "degraded"
            else:
                status = "ok"
            return {
                "status": status,
                "worker": ("failed" if self._worker_failed else
                           "running" if self._running else "stopped"),
                "pending": self._pending,
                "breakers_open": open_buckets,
            }

    def stats(self) -> dict:
        """Operator snapshot: global counters + per-bucket observability
        (p50/p95/p99 latency ms, queue depth, pad-waste, occupancy,
        backend/solver counts).  ``requests``/``batches``/``plans_built``/
        ``backends`` keep the batch engine's historical meanings;
        ``resilience`` aggregates the failure-isolation machinery
        (deadlines, cancels, retries, bisections, quarantines, breaker
        trips) and each bucket snapshot carries its breaker state."""
        with self._lock:
            taken: set = set()
            buckets = {}
            backends: dict = {}
            solvers: dict = {}
            true_elems = slot_elems = 0
            trips = reopens = open_count = 0
            for key, bs in self._buckets.items():
                snap = bs.metrics.snapshot(queue_depth=len(bs.queue))
                snap["breaker"] = bs.breaker.snapshot()
                buckets[self._bucket_label(key, taken)] = snap
                trips += bs.breaker.trips
                reopens += bs.breaker.reopens
                open_count += bs.breaker.state != "closed"
                for k, v in bs.metrics.backends.items():
                    backends[k] = backends.get(k, 0) + v
                for k, v in bs.metrics.solvers.items():
                    solvers[k] = solvers.get(k, 0) + v
                true_elems += bs.metrics.true_elems
                slot_elems += bs.metrics.slot_elems
            elapsed = time.perf_counter() - self._t0
            return {
                **self._counters,
                "pending": self._pending,
                "max_inflight_waves": self._max_inflight,
                "n_buckets": len(self._buckets),
                "backends": backends,
                "solvers": solvers,
                "pad_waste": round(1.0 - true_elems / slot_elems, 6)
                             if slot_elems else 0.0,
                "throughput_rps": self._counters["requests"] / elapsed
                                  if elapsed > 0 else 0.0,
                "latency": self._latency.snapshot_ms(),
                "buckets": buckets,
                "resilience": {
                    **self._res,
                    "breaker_trips": trips,
                    "breaker_reopens": reopens,
                    "breakers_open": open_count,
                },
                # process-wide observability riding the operator snapshot:
                # compile-cache behaviour and predicted-vs-actual drift
                # (stale cells name the repro.tune rerun that repairs them)
                "sweep_cache": dict(CACHE_STATS),
                "drift": _drift.MONITOR.summary(),
            }
