"""serve substrate: LM continuous batching + Tucker decomposition serving."""

from .engine import Request, ServeEngine, TuckerBatchEngine, TuckerRequest

__all__ = ["Request", "ServeEngine", "TuckerBatchEngine", "TuckerRequest"]
