"""serve substrate: LM continuous batching + streaming Tucker serving.

``TuckerService`` is the streaming front door (async submit/poll, shape
buckets, backpressure, per-bucket metrics); ``TuckerBatchEngine`` is its
synchronous one-shot wrapper.
"""

from .buckets import BucketPolicy, pad_block, pad_waste, slice_valid, trim_result
from .engine import Request, ServeEngine, TuckerBatchEngine, TuckerRequest
from .metrics import BucketMetrics, LatencyWindow, TraceWriter
from .service import RejectedError, ServiceClosed, Ticket, TuckerService

__all__ = [
    "BucketMetrics", "BucketPolicy", "LatencyWindow", "RejectedError",
    "Request", "ServeEngine", "ServiceClosed", "Ticket", "TraceWriter",
    "TuckerBatchEngine", "TuckerRequest", "TuckerService",
    "pad_block", "pad_waste", "slice_valid", "trim_result",
]
