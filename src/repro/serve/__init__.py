"""serve substrate."""
