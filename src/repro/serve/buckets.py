"""Shape buckets for the streaming Tucker service.

Production decomposition traffic is a stream of tensors whose shapes
cluster but rarely repeat exactly.  Planning (selector + schedule) and XLA
compilation are per-shape costs, so a service that treats every odd shape
as its own group pays them on the tail of the shape distribution forever.
Buckets quantize that tail: each incoming shape is rounded up to a bucket
(every dim to the next multiple of ``grid``), the request's tensor is
zero-padded into the bucket's slot buffer, and the bucket holds one warm
:class:`~repro.core.api.TuckerPlan` plus one vmapped compiled sweep.

Padding correctness — the two pad modes
---------------------------------------

Zero slack contributes *exact zeros* to every Gram and TTM reduction (the
mode-n Gram of a zero-padded tensor is the unpadded Gram with zero rows and
columns appended; a TTM against it only ever multiplies the slack by zero),
so masking is free arithmetically.  What is NOT free is running the
*eigendecomposition* at the padded size: LAPACK on a (B, B) matrix is a
different computation than on the embedded (I, I) block, so factors come
out equal-in-exact-arithmetic but not bit-identical.  Hence two modes:

``pad_mode="exact"`` (default)
    The slot buffer stays bucket-shaped, but each lane's valid block is
    sliced back out before the solve (a zero-pad → slice roundtrip is
    bitwise lossless) and runs through the plan the request's TRUE shape
    resolves to — the *same* cached compiled sweep a direct
    ``decompose(x, cfg)`` would run, so results are **bitwise-equal to
    unpadded execution** (asserted in ``tests/test_service.py``).  Shape-
    exact lanes still batch as one vmapped wave; padded lanes trade wave
    fusion for exactness.

``pad_mode="mask"``
    The whole wave — mixed true shapes included — runs the bucket plan's
    single vmapped sweep at the bucket shape; the zero slack is masked out
    of every Gram/TTM contribution by construction, factors come back with
    exactly-zero slack rows (zero rows propagate exactly through the EIG
    eigenvector deflation, the ALS normal equations, and Householder QR —
    verified empirically in the tests), and :func:`trim_result` crops them
    to the true shape.  Results are approximately (not bitwise) equal to
    unpadded execution — the throughput mode for latency-tolerant traffic.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.sthosvd import SthosvdResult, TuckerTensor

PAD_MODES = ("exact", "mask")


@dataclass(frozen=True)
class BucketPolicy:
    """How the service quantizes shapes and forms waves.

    ``grid`` rounds every dim up to its next multiple (an int applies to
    all modes; a tuple gives a per-mode grid).  ``grid=1`` is the identity
    policy: every shape is its own bucket and no request is ever padded —
    the compatibility mode :class:`~repro.serve.engine.TuckerBatchEngine`
    runs under.

    ``max_pad_ratio`` caps the padding overhead: a shape whose bucket
    would hold more than ``max_pad_ratio``× its true element count gets an
    exact (unpadded) bucket of its own instead — pathological slivers
    never burn 8× their size in slack.

    ``pad_mode`` picks the padded-execution strategy (see module
    docstring): ``"exact"`` for bitwise parity with unpadded execution,
    ``"mask"`` for single-program-per-bucket wave fusion.

    ``wave_slots`` bounds the lanes one wave takes from the queue
    (``None`` = take everything queued — the offline/batch setting);
    ``lane_pow2`` rounds each wave's batch up to the next power of two
    with zero-filled lanes, so a bucket compiles at most
    ``log2(wave_slots)+1`` batched programs ever instead of one per
    observed batch size (the standard static-slot trick; inactive lanes
    decompose zeros that are dropped).
    """
    grid: int | tuple[int, ...] = 8
    max_pad_ratio: float = 2.0
    pad_mode: str = "exact"
    wave_slots: int | None = 8
    lane_pow2: bool = True

    def __post_init__(self):
        if isinstance(self.grid, Sequence):
            object.__setattr__(self, "grid",
                               tuple(int(g) for g in self.grid))
            grids = self.grid
        else:
            object.__setattr__(self, "grid", int(self.grid))
            grids = (self.grid,)
        if any(g < 1 for g in grids):
            raise ValueError(f"grid must be >= 1, got {self.grid}")
        if self.pad_mode not in PAD_MODES:
            raise ValueError(f"pad_mode {self.pad_mode!r} not in {PAD_MODES}")
        if self.max_pad_ratio < 1.0:
            raise ValueError("max_pad_ratio < 1 would forbid the identity "
                             f"bucket, got {self.max_pad_ratio}")
        if self.wave_slots is not None and self.wave_slots < 1:
            raise ValueError("wave_slots must be >= 1 or None (unbounded)")

    @classmethod
    def exact(cls) -> "BucketPolicy":
        """Identity policy: per-shape buckets, unbounded waves, no lane
        padding — reproduces the pre-service ``TuckerBatchEngine.run()``
        grouping exactly (one vmapped batch per (shape, dtype, config))."""
        return cls(grid=1, wave_slots=None, lane_pow2=False)

    def _grid_for(self, mode: int) -> int:
        if isinstance(self.grid, tuple):
            if mode >= len(self.grid):
                raise ValueError(f"per-mode grid {self.grid} has no entry "
                                 f"for mode {mode}")
            return self.grid[mode]
        return self.grid

    def bucket_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        """The bucket ``shape`` routes to: every dim rounded up to its
        grid, unless the padding overhead breaches ``max_pad_ratio`` (then
        the shape is its own exact bucket)."""
        shape = tuple(int(s) for s in shape)
        up = tuple(-(-s // self._grid_for(m)) * self._grid_for(m)
                   for m, s in enumerate(shape))
        if math.prod(up) > self.max_pad_ratio * math.prod(shape):
            return shape
        return up

    def lanes_for(self, n: int) -> int:
        """Lane count a wave of ``n`` requests occupies: ``n`` itself, or
        the next power of two capped at ``wave_slots`` when ``lane_pow2``
        batch-size bucketing is on."""
        if not self.lane_pow2:
            return n
        lanes = 1 << max(0, (n - 1).bit_length())
        return min(lanes, self.wave_slots) if self.wave_slots else lanes


def pad_waste(true_shape: Sequence[int], bucket: Sequence[int]) -> float:
    """Fraction of the bucket's elements that are slack for this member
    (0.0 for an exact fit)."""
    return 1.0 - math.prod(true_shape) / math.prod(bucket)


def pad_block(x: jax.Array, bucket: Sequence[int]) -> jax.Array:
    """Zero-pad ``x`` up to the bucket shape (trailing slack per mode)."""
    widths = [(0, b - s) for s, b in zip(x.shape, bucket)]
    if any(w < 0 for _, w in widths):
        raise ValueError(f"shape {x.shape} does not fit bucket {tuple(bucket)}")
    if not any(w for _, w in widths):
        return x
    return jnp.pad(x, widths)


def slice_valid(x: jax.Array, true_shape: Sequence[int]) -> jax.Array:
    """The valid block of a padded tensor — bitwise the original values
    (zero-pad then slice is a lossless roundtrip)."""
    if tuple(x.shape) == tuple(true_shape):
        return x
    return x[tuple(slice(0, s) for s in true_shape)]


def trim_result(res: SthosvdResult, true_shape: Sequence[int]) -> SthosvdResult:
    """Crop a mask-mode result (factors at bucket size) to the true shape.

    The core is already (R_0, ..., R_{N-1}) — rank-shaped, bucket-blind —
    so only the factors' slack rows are dropped.  Those rows are exactly
    zero (see module docstring), so the trimmed factors keep orthonormal
    columns and ``core ×_n U_n`` reconstructs the unpadded tensor.
    """
    tt = res.tucker
    trimmed = [u[:s] for u, s in zip(tt.factors, true_shape)]
    return SthosvdResult(
        tucker=TuckerTensor(core=tt.core, factors=trimmed),
        trace=res.trace, select_overhead_s=res.select_overhead_s)
