"""Per-bucket observability for the streaming Tucker service.

Counters + latency windows per bucket, a thread-safe JSONL trace writer,
and snapshot helpers that :meth:`repro.serve.service.TuckerService.stats`
assembles into one operator-facing dict.  Everything here is plain Python
(no jax) so metric reads never touch the device.
"""

from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path

#: latency percentiles every snapshot reports, as (label, q) pairs
PERCENTILES = (("p50_ms", 50.0), ("p95_ms", 95.0), ("p99_ms", 99.0))


class LatencyWindow:
    """Sliding window of the last ``maxlen`` latency samples (seconds).

    Percentiles are computed on demand over the window by linear
    interpolation — recent-traffic figures, not lifetime averages, which is
    what an SLO dashboard wants.  ``count``/``total_s`` keep lifetime sums
    for mean/throughput math.
    """

    def __init__(self, maxlen: int = 2048):
        self._window: deque[float] = deque(maxlen=maxlen)
        self.count = 0
        self.total_s = 0.0

    def add(self, seconds: float) -> None:
        self._window.append(float(seconds))
        self.count += 1
        self.total_s += float(seconds)

    @staticmethod
    def _interp(xs: list, q: float) -> float:
        """q-th percentile of an already-sorted sample list."""
        if not xs:
            return 0.0
        rank = (len(xs) - 1) * q / 100.0
        lo = math.floor(rank)
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * (rank - lo)

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100) of the window in SECONDS; 0.0 empty."""
        return self._interp(sorted(self._window), q)

    def snapshot_ms(self) -> dict:
        # one sort for all percentiles (snapshot_ms used to re-sort the
        # window per percentile — 3x per snapshot)
        xs = sorted(self._window)
        out = {label: self._interp(xs, q) * 1e3 for label, q in PERCENTILES}
        out["mean_ms"] = (self.total_s / self.count * 1e3) if self.count else 0.0
        # windowed mean, over the same samples the percentiles saw — the
        # lifetime mean_ms can sit far from p50 after a traffic shift
        out["window_mean_ms"] = (sum(xs) / len(xs) * 1e3) if xs else 0.0
        return out


@dataclass
class BucketMetrics:
    """Counters for one shape bucket.  Mutated under the service lock."""
    bucket: tuple[int, ...]
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    padded: int = 0              # completed requests that carried slack
    waves: int = 0
    pipelined_waves: int = 0     # waves dispatched while another was in flight
    inflight_sum: int = 0        # Σ waves already in flight at each dispatch
    lanes: int = 0               # total lanes dispatched (incl. zero-filled)
    lanes_filled: int = 0        # lanes carrying a real request
    true_elems: int = 0          # sum of completed requests' true sizes
    slot_elems: int = 0          # sum of the slots they occupied
    cancelled: int = 0           # removed from the queue before dispatch
    deadline_expired: int = 0    # failed with DeadlineError (never ran)
    retried: int = 0             # wave failures re-enqueued under a budget
    quarantined: int = 0         # poisoned fused lanes re-derived alone
    recovered: int = 0           # completed only after bisection/isolation
    backends: dict = field(default_factory=dict)
    solvers: dict = field(default_factory=dict)
    latency: LatencyWindow = field(default_factory=LatencyWindow)
    queue_wait: LatencyWindow = field(default_factory=LatencyWindow)

    @property
    def pad_waste(self) -> float:
        """Fraction of slot elements that were slack across completed
        requests (0.0 = every request fit its bucket exactly)."""
        return 1.0 - self.true_elems / self.slot_elems if self.slot_elems \
            else 0.0

    @property
    def occupancy(self) -> float:
        """Filled fraction of dispatched lanes (1.0 = no zero-fill)."""
        return self.lanes_filled / self.lanes if self.lanes else 0.0

    @property
    def pipeline_occupancy(self) -> float:
        """Fraction of this bucket's waves dispatched while at least one
        earlier wave was still in flight (0.0 = fully serial dispatch,
        → 1.0 = the device never waited for host-side wave stacking)."""
        return self.pipelined_waves / self.waves if self.waves else 0.0

    @property
    def avg_inflight(self) -> float:
        """Mean number of waves already in flight at each dispatch (bounded
        by the service's ``max_inflight_waves`` − 1)."""
        return self.inflight_sum / self.waves if self.waves else 0.0

    def snapshot(self, queue_depth: int = 0) -> dict:
        return {
            "bucket": list(self.bucket),
            "submitted": self.submitted, "completed": self.completed,
            "rejected": self.rejected, "failed": self.failed,
            "padded": self.padded, "waves": self.waves,
            "pipelined_waves": self.pipelined_waves,
            "pipeline_occupancy": round(self.pipeline_occupancy, 6),
            "avg_inflight": round(self.avg_inflight, 6),
            "queue_depth": queue_depth,
            "pad_waste": round(self.pad_waste, 6),
            "occupancy": round(self.occupancy, 6),
            "backends": dict(self.backends), "solvers": dict(self.solvers),
            "latency": self.latency.snapshot_ms(),
            "queue_wait": self.queue_wait.snapshot_ms(),
            "resilience": {
                "cancelled": self.cancelled,
                "deadline_expired": self.deadline_expired,
                "retried": self.retried,
                "quarantined": self.quarantined,
                "recovered": self.recovered,
            },
        }


class TraceWriter:
    """Append-only JSONL event log (one object per line), thread-safe.

    Events carry a wall-clock ``t`` and a ``kind`` (``submit`` | ``wave``
    | ``done`` | ``reject`` | ``error``); everything else is free-form.
    The file handle opens lazily and every event is flushed — a crashed
    service leaves a readable trace (the same interrupted-append tolerance
    the tune store practices).

    A writer also works as a :mod:`repro.obs` event-bus sink
    (``obs.add_sink(writer.handle)``): bus events are plain dicts in the
    same schema, so span and cache events land in the same JSONL stream
    the serve events always used.

    ``event()`` after :meth:`close` raises ``ValueError`` — it used to
    silently reopen the file, so a "closed" trace kept growing.
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._lock = threading.Lock()
        self._fh = None
        self._closed = False

    def _write(self, obj: dict) -> None:
        line = json.dumps(obj, default=repr)
        with self._lock:
            if self._closed:
                raise ValueError(
                    f"TraceWriter for {self.path} is closed; events after "
                    "close() are a bug in the caller (the writer used to "
                    "silently reopen the file here)")
            if self._fh is None:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                self._fh = self.path.open("a")
            self._fh.write(line + "\n")
            self._fh.flush()

    def event(self, kind: str, **fields) -> None:
        self._write({"t": time.time(), "kind": kind, **fields})

    def handle(self, evt: dict) -> None:
        """Event-bus sink adapter: append one already-shaped event dict
        (``{"t": ..., "kind": ..., ...}``) as a JSONL line."""
        self._write(evt)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            if self._fh is not None:
                self._fh.close()
                self._fh = None
