"""CLI for the observability layer.

Usage::

    # Convert a bus capture / serve TraceWriter JSONL into a Perfetto-
    # loadable Chrome trace:
    python -m repro.obs export EVENTS.jsonl --to trace.json

    # Drift report: rebuild predicted-vs-actual cells from the "solve"
    # spans of an events file, or (with no file) run a small in-process
    # probe workload and report on the live monitor:
    python -m repro.obs report [EVENTS.jsonl] [--json]
"""

from __future__ import annotations

import argparse
import json
import sys

from .drift import DriftMonitor, MONITOR
from .export import read_jsonl, write_chrome


def _feed_from_events(events, monitor: DriftMonitor) -> int:
    """Rebuild drift observations from solve spans (which carry
    platform/backend/solver/predicted_s attrs and measured dur_s)."""
    n = 0
    for e in events:
        if e.get("kind") != "span" or e.get("name") != "solve":
            continue
        pred = e.get("predicted_s") or 0.0
        dur = e.get("dur_s") or 0.0
        if pred > 0.0 and dur > 0.0:
            monitor.observe(platform=e.get("platform", "?"),
                            backend=e.get("backend", "?"),
                            solver=e.get("solver", e.get("method", "?")),
                            predicted_s=pred, actual_s=dur,
                            source="events")
            n += 1
    return n


def _probe(monitor: DriftMonitor) -> None:
    """Run a tiny recorded execute so a bare ``report`` has data."""
    import numpy as np

    from repro.core.api import TuckerConfig, plan

    rng = np.random.default_rng(0)
    x = rng.standard_normal((16, 18, 20)).astype(np.float32)
    p = plan(x.shape, x.dtype, TuckerConfig(ranks=(4, 4, 4)))
    for _ in range(max(monitor.min_samples, 5)):
        p.execute(x, record=True)


def _print_report(rep: dict, as_json: bool) -> None:
    if as_json:
        json.dump(rep, sys.stdout, indent=2, default=str)
        sys.stdout.write("\n")
        return
    cells = rep["cells"]
    if not cells:
        print("no drift observations recorded")
    for c in cells:
        flag = "STALE" if c["stale"] else "ok"
        print(f"[{flag:>5}] ({c['platform']}, {c['backend']}, "
              f"{c['solver']}): actual/predicted x{c['ratio']:.3f} "
              f"n={c['n']} z={c['z']:.1f} sources={c['sources']}")
    for backend, m in rep.get("memory", {}).items():
        print(f"[  mem] backend {backend}: observed "
              f"{m['observed_bytes']:,} B vs modeled "
              f"{m['modeled_bytes']:,} B (x{m['ratio']:.2f})")
    for r in rep["recommendations"]:
        print(f"  -> {r['why']}\n     run: {r['command']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_rep = sub.add_parser("report", help="predicted-vs-actual drift report")
    p_rep.add_argument("events", nargs="?", default=None,
                       help="events JSONL (bus capture or TraceWriter "
                            "output); omit to probe in-process")
    p_rep.add_argument("--json", action="store_true", dest="as_json",
                       help="emit the full report as JSON")

    p_exp = sub.add_parser("export", help="events JSONL -> Chrome trace")
    p_exp.add_argument("events", help="events JSONL file")
    p_exp.add_argument("--to", required=True, help="output trace path")

    args = ap.parse_args(argv)

    if args.cmd == "export":
        events = read_jsonl(args.events)
        doc = write_chrome(events, args.to)
        print(f"wrote {len(doc['traceEvents'])} trace events -> {args.to}")
        return 0

    if args.events:
        monitor = DriftMonitor(min_samples=MONITOR.min_samples,
                               z_threshold=MONITOR.z_threshold,
                               tolerance=MONITOR.tolerance)
        n = _feed_from_events(read_jsonl(args.events), monitor)
        print(f"rebuilt {n} observations from {args.events}")
    else:
        monitor = MONITOR
        if not monitor.cells():
            _probe(monitor)
    _print_report(monitor.report(), args.as_json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
