"""repro.obs — unified observability: span tracing, metrics, drift.

One event bus for the whole a-Tucker stack (:mod:`repro.obs.trace`),
exporters to Chrome-trace/Perfetto and JSONL (:mod:`repro.obs.export`),
a Prometheus-style metrics registry (:mod:`repro.obs.metrics`), and a
predicted-vs-actual drift monitor that recommends ``repro.tune``
reruns when calibrations go stale (:mod:`repro.obs.drift`).

Quick start::

    from repro import obs

    with obs.capture() as buf:          # enables tracing for the block
        p = plan(x.shape, x.dtype, cfg)
        p.execute(x)
    obs.write_chrome(buf.events(), "trace.json")   # open in Perfetto

    print(obs.REGISTRY.render())        # Prometheus text exposition
    print(obs.MONITOR.report())         # predicted-vs-actual drift

Span tracing is OFF by default; enable with ``obs.enable()``, the
``ATUCKER_OBS=1`` env var, or an ``obs.capture()`` block.  The drift
monitor is fed directly by the execution layers and stays on always.
CLI: ``python -m repro.obs report|export``.
"""

from .trace import (EventBuffer, add_sink, capture, disable, enable,
                    enabled, event, iter_spans, remove_sink, span)
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
                      absorb_service_stats)
from .export import read_jsonl, to_chrome, write_chrome, write_jsonl
from .drift import MONITOR, DriftMonitor, MemoryWatch

__all__ = [
    # trace
    "EventBuffer", "add_sink", "capture", "disable", "enable", "enabled",
    "event", "iter_spans", "remove_sink", "span",
    # metrics
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "absorb_service_stats",
    # export
    "read_jsonl", "to_chrome", "write_chrome", "write_jsonl",
    # drift
    "MONITOR", "DriftMonitor", "MemoryWatch",
]
