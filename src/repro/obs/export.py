"""Event-stream exporters: Chrome trace-event JSON (Perfetto), JSONL.

The bus speaks plain dicts (:mod:`repro.obs.trace`); these functions turn
a captured stream into files tools understand:

* :func:`to_chrome` / :func:`write_chrome` — the Chrome trace-event
  format (``{"traceEvents": [...]}``), loadable in Perfetto / ``chrome://
  tracing``.  Spans become complete ``"X"`` events with their attrs as
  ``args``; serve ``wave`` events carry ``wall_s`` so they too render as
  duration slices; everything else is an instant ``"i"``.
* :func:`write_jsonl` / :func:`read_jsonl` — one event dict per line,
  the same schema the serve :class:`~repro.serve.metrics.TraceWriter`
  has always produced, so its files and bus captures round-trip through
  the same readers.
"""

from __future__ import annotations

import json
from typing import Iterable

__all__ = ["to_chrome", "write_chrome", "write_jsonl", "read_jsonl"]

_US = 1e6  # trace-event timestamps are microseconds


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return repr(v)


def to_chrome(events: Iterable[dict]) -> dict:
    """Convert a bus event stream to a Chrome trace-event document."""
    out = []
    for e in events:
        kind = e.get("kind", "event")
        pid = e.get("pid", 1)
        tid = e.get("tid", 1)
        args = {k: _jsonable(v) for k, v in e.items()
                if k not in ("t", "kind", "name", "dur_s", "pid", "tid")}
        if kind == "span":
            out.append({
                "name": e.get("name", "span"),
                "cat": "atucker",
                "ph": "X",
                "ts": e["t"] * _US,
                "dur": max(e.get("dur_s", 0.0), 0.0) * _US,
                "pid": pid, "tid": tid,
                "args": args,
            })
        elif kind == "wave" and "wall_s" in e:
            # TraceWriter logs waves at completion; rewind the start so
            # the slice lands where the work actually ran.
            wall = max(float(e["wall_s"]), 0.0)
            out.append({
                "name": f"wave {e.get('bucket', '')}".strip(),
                "cat": "serve",
                "ph": "X",
                "ts": (e["t"] - wall) * _US,
                "dur": wall * _US,
                "pid": pid, "tid": tid,
                "args": args,
            })
        else:
            out.append({
                "name": kind,
                "cat": "serve" if kind in ("submit", "done", "reject",
                                           "error") else "atucker",
                "ph": "i",
                "s": "t",
                "ts": e.get("t", 0.0) * _US,
                "pid": pid, "tid": tid,
                "args": args,
            })
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome(events: Iterable[dict], path) -> dict:
    """Write :func:`to_chrome` output to ``path``; returns the document."""
    doc = to_chrome(events)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return doc


def write_jsonl(events: Iterable[dict], path) -> int:
    """Write one event dict per line; returns the number written."""
    n = 0
    with open(path, "w") as fh:
        for e in events:
            fh.write(json.dumps({k: _jsonable(v) for k, v in e.items()})
                     + "\n")
            n += 1
    return n


def read_jsonl(path) -> list[dict]:
    """Read a JSONL event file (bus capture or serve TraceWriter output);
    blank and malformed lines are skipped."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out
