"""Counter / gauge / histogram registry with Prometheus text exposition.

The serve layer's per-bucket :class:`~repro.serve.metrics.BucketMetrics`
are rich but private to one :class:`TuckerService`; this registry is the
PROCESS-wide metric surface every layer shares — the compile cache counts
here, drift staleness gauges land here, and
:func:`absorb_service_stats` folds any service's ``stats()`` snapshot in,
so one scrape of :meth:`MetricsRegistry.render` sees the whole stack.

Everything is stdlib + threads; label sets are sorted key/value tuples so
series identity is order-independent, matching Prometheus semantics.
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "absorb_service_stats"]

#: default histogram bucket boundaries (seconds-flavored, widely useful)
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


def _labelset(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(ls: tuple, extra: tuple = ()) -> str:
    items = [*ls, *extra]
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


class _Metric:
    def __init__(self, name: str, help_: str):
        self.name = name
        self.help = help_
        self._lock = threading.Lock()
        self._series: dict[tuple, float] = {}

    def _bump(self, labels: dict, value: float, *, add: bool) -> None:
        ls = _labelset(labels)
        with self._lock:
            self._series[ls] = (self._series.get(ls, 0.0) + value) if add \
                else value

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(_labelset(labels), 0.0)

    def series(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._series)


class Counter(_Metric):
    """Monotone counter; ``inc`` with negative amounts is rejected."""
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({amount}))")
        self._bump(labels, amount, add=True)

    def render(self) -> list[str]:
        return [f"{self.name}{_fmt_labels(ls)} {v:g}"
                for ls, v in sorted(self.series().items())]


class Gauge(_Metric):
    """Set-to-current-value metric."""
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._bump(labels, float(value), add=False)

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._bump(labels, amount, add=True)

    def render(self) -> list[str]:
        return [f"{self.name}{_fmt_labels(ls)} {v:g}"
                for ls, v in sorted(self.series().items())]


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each ``le``
    bucket counts observations ≤ its bound, ``+Inf`` counts all)."""
    kind = "histogram"

    def __init__(self, name: str, help_: str, buckets=DEFAULT_BUCKETS):
        self.name = name
        self.help = help_
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._lock = threading.Lock()
        # per labelset: [per-bucket counts..., +Inf count], sum
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def observe(self, value: float, **labels) -> None:
        ls = _labelset(labels)
        idx = bisect_left(self.buckets, value)
        with self._lock:
            counts = self._counts.setdefault(ls,
                                             [0] * (len(self.buckets) + 1))
            counts[idx] += 1
            self._sums[ls] = self._sums.get(ls, 0.0) + value

    def count(self, **labels) -> int:
        with self._lock:
            return sum(self._counts.get(_labelset(labels), ()))

    def render(self) -> list[str]:
        out = []
        with self._lock:
            items = sorted((ls, list(c), self._sums.get(ls, 0.0))
                           for ls, c in self._counts.items())
        for ls, counts, total in items:
            cum = 0
            for bound, c in zip(self.buckets, counts):
                cum += c
                out.append(f"{self.name}_bucket"
                           f"{_fmt_labels(ls, (('le', f'{bound:g}'),))} "
                           f"{cum}")
            cum += counts[-1]
            out.append(f"{self.name}_bucket"
                       f"{_fmt_labels(ls, (('le', '+Inf'),))} {cum}")
            out.append(f"{self.name}_sum{_fmt_labels(ls)} {total:g}")
            out.append(f"{self.name}_count{_fmt_labels(ls)} {cum}")
        return out


class MetricsRegistry:
    """Named metric registry: ``counter``/``gauge``/``histogram`` return
    the existing metric on repeat calls (idempotent, so module-level
    wiring never double-registers) and :meth:`render` emits the whole
    registry as Prometheus text exposition format."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help_: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help_, **kw)
            elif not isinstance(m, cls):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{type(m).__name__}")
            return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_, buckets=buckets)

    def render(self) -> str:
        lines = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.render())
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


#: the process-wide registry (use your own MetricsRegistry to isolate)
REGISTRY = MetricsRegistry()


def absorb_service_stats(stats: dict,
                         registry: MetricsRegistry = REGISTRY,
                         service: str = "tucker") -> None:
    """Fold one :meth:`TuckerService.stats` snapshot into the registry:
    global counters become labeled counters-as-gauges (a snapshot is a
    level, not an increment), per-bucket latency percentiles / pad-waste /
    occupancy become gauges labeled by bucket."""
    g = registry.gauge
    for key in ("submitted", "requests", "rejected", "failed", "batches",
                "plans_built", "pending"):
        if key in stats:
            g(f"atucker_serve_{key}",
              f"service {key} (lifetime snapshot)").set(
                  stats[key], service=service)
    g("atucker_serve_throughput_rps", "completed requests per second").set(
        stats.get("throughput_rps", 0.0), service=service)
    g("atucker_serve_pad_waste", "slack fraction of slot elements").set(
        stats.get("pad_waste", 0.0), service=service)
    for label, q in (("p50_ms", "p50"), ("p95_ms", "p95"), ("p99_ms", "p99")):
        if label in stats.get("latency", {}):
            g("atucker_serve_latency_ms",
              "windowed request latency percentiles").set(
                  stats["latency"][label], service=service, quantile=q)
    for bucket, b in stats.get("buckets", {}).items():
        for key in ("completed", "waves", "queue_depth"):
            g(f"atucker_bucket_{key}", f"per-bucket {key}").set(
                b[key], service=service, bucket=bucket)
        for key in ("pad_waste", "occupancy", "pipeline_occupancy"):
            g(f"atucker_bucket_{key}", f"per-bucket {key}").set(
                b[key], service=service, bucket=bucket)
        for label, q in (("p50_ms", "p50"), ("p95_ms", "p95"),
                         ("p99_ms", "p99")):
            g("atucker_bucket_latency_ms",
              "per-bucket latency percentiles").set(
                  b["latency"][label], service=service, bucket=bucket,
                  quantile=q)
        for solver, n in b.get("solvers", {}).items():
            g("atucker_bucket_solver_requests",
              "completed requests per solver").set(
                  n, service=service, bucket=bucket, solver=solver)


def quantile_from_histogram(hist: Histogram, q: float, **labels) -> float:
    """Linear-interpolated quantile estimate from a histogram's cumulative
    buckets (the registry-side mirror of LatencyWindow.percentile)."""
    ls = _labelset(labels)
    with hist._lock:
        counts = list(hist._counts.get(ls, ()))
    if not counts or not sum(counts):
        return 0.0
    total = sum(counts)
    target = q / 100.0 * total
    cum = 0
    lo = 0.0
    for bound, c in zip(hist.buckets, counts):
        if cum + c >= target and c:
            return lo + (bound - lo) * (target - cum) / c
        cum += c
        lo = bound
    return hist.buckets[-1] if not math.isinf(hist.buckets[-1]) else lo
