"""Process-wide span tracing + event bus for the a-Tucker stack.

Every layer emits into ONE bus: ``plan()`` and its DP search, each
``TuckerPlan.execute`` (fused dispatch, eager per-mode solves, adaptive
sketch passes), the compiled-sweep cache (hit / miss / compile seconds),
sharded sweeps, and the serve pipeline's submit → wave → done lifecycle.
Sinks subscribe to the bus: the serve layer's :class:`~repro.serve.metrics.
TraceWriter` (same JSONL schema it always wrote), in-memory
:class:`EventBuffer` rings for the exporters (:mod:`repro.obs.export`),
the metrics registry, the drift monitor — anything callable.

Design constraints, in priority order:

1. **Disabled means free.**  Tracing is OFF by default (enable with
   :func:`enable`, the ``ATUCKER_OBS=1`` env var, or a :func:`capture`
   context).  A disabled :func:`span` returns one shared no-op object and
   a disabled :func:`event` is a single boolean test — the hot path never
   pays for observability it didn't ask for.  (The drift monitor is fed
   directly by the execution layers, not through this bus, precisely so
   predicted-vs-actual accounting stays on even when tracing is off.)
2. **Plain dicts, stdlib only.**  An event is ``{"t": unix_seconds,
   "kind": str, ...fields}`` — the exact shape the serve TraceWriter has
   always written — plus, for spans, ``name`` / ``dur_s`` / ``span`` /
   ``parent`` / ``tid`` / ``pid``.  No jax import, no device touch.
3. **Context propagation.**  Span parentage rides a :mod:`contextvars`
   ContextVar, so nesting works across the serve worker thread and any
   executor the caller brings, without threading span objects through
   call signatures.
"""

from __future__ import annotations

import contextvars
import itertools
import os
import threading
import time
import warnings
from collections import deque
from typing import Callable, Iterable

__all__ = [
    "EventBuffer", "add_sink", "capture", "disable", "enable", "enabled",
    "event", "remove_sink", "span",
]

_enabled = bool(os.environ.get("ATUCKER_OBS"))
#: copy-on-write: _publish reads this tuple without taking the lock (one
#: atomic load per event); add/remove rebuild it under the lock
_sinks: tuple[Callable[[dict], None], ...] = ()
_sinks_lock = threading.Lock()
_PID = os.getpid()
_ids = itertools.count(1)
#: the innermost open span's id on this context (None = top level)
_current: contextvars.ContextVar[int | None] = \
    contextvars.ContextVar("atucker_obs_span", default=None)


def enabled() -> bool:
    """Whether span/event emission is on (see :func:`enable`)."""
    return _enabled


def enable() -> None:
    """Turn span/event emission on process-wide."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Turn span/event emission off (sinks stay registered)."""
    global _enabled
    _enabled = False


def add_sink(sink: Callable[[dict], None]) -> Callable[[dict], None]:
    """Register a bus subscriber; every emitted event dict is passed to it.
    Returns ``sink`` so the call can be used inline."""
    global _sinks
    with _sinks_lock:
        if sink not in _sinks:
            _sinks = (*_sinks, sink)
    return sink


def remove_sink(sink: Callable[[dict], None]) -> None:
    global _sinks
    with _sinks_lock:
        if sink in _sinks:
            # equality, not identity: a bound-method sink (writer.handle)
            # is a fresh object on every attribute access
            _sinks = tuple(s for s in _sinks if s != sink)


def _publish(evt: dict) -> None:
    for s in _sinks:
        try:
            s(evt)
        except Exception as e:  # noqa: BLE001 - a broken sink must not
            #                     take down the traced workload
            warnings.warn(f"obs sink {s!r} raised {e!r}; event dropped "
                          "for this sink", RuntimeWarning, stacklevel=2)


def event(kind: str, **fields) -> None:
    """Emit a point event onto the bus (no-op while tracing is disabled).

    The dict shape matches the serve TraceWriter's JSONL lines: ``t`` is
    wall-clock unix seconds, ``kind`` the event type, everything else
    free-form (JSON-serializable values only)."""
    if not _enabled:
        return
    sp = _current.get()
    evt = {"t": time.time(), "kind": kind, "pid": _PID,
           "tid": threading.get_ident(), **fields}
    if sp is not None:
        evt.setdefault("parent", sp)
    _publish(evt)


class _NullSpan:
    """The shared disabled span: enters/exits/sets for free."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Span:
    """One timed region, emitted as a single ``kind="span"`` event at exit
    (so a crashed region simply leaves no event — the JSONL stays whole).
    ``set(**attrs)`` adds attributes any time before exit; an exception
    escaping the region stamps ``error=repr(exc)``."""
    __slots__ = ("name", "attrs", "id", "parent", "_t0", "_wall", "_tok")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.id = next(_ids)
        self.parent: int | None = None
        self._t0 = 0.0
        self._wall = 0.0
        self._tok = None

    def __enter__(self) -> "Span":
        self.parent = _current.get()
        self._tok = _current.set(self.id)
        self._wall = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = time.perf_counter() - self._t0
        _current.reset(self._tok)
        if exc is not None:
            self.attrs["error"] = repr(exc)
        _publish({"t": self._wall, "kind": "span", "name": self.name,
                  "dur_s": dur, "span": self.id, "parent": self.parent,
                  "pid": _PID, "tid": threading.get_ident(),
                  **self.attrs})
        return False

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)


def span(name: str, **attrs):
    """Open a traced region::

        with span("execute", backend="matfree", shape=[48, 224, 128]) as sp:
            ...
            sp.set(ranks=list(chosen))   # attrs may land late

    Returns the shared no-op span while tracing is disabled, so callers
    never branch on :func:`enabled` themselves."""
    if not _enabled:
        return _NULL_SPAN
    return Span(name, attrs)


class EventBuffer:
    """Ring-buffer sink: keeps the last ``maxlen`` events in memory for the
    exporters (and tests).  Thread-safe; register via :func:`add_sink` or
    use :func:`capture`."""

    def __init__(self, maxlen: int = 65536):
        self._events: deque[dict] = deque(maxlen=maxlen)
        self._lock = threading.Lock()

    def __call__(self, evt: dict) -> None:
        with self._lock:
            self._events.append(evt)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


class capture:
    """Context manager that enables tracing into a fresh
    :class:`EventBuffer` and restores the previous enabled-state on exit::

        with capture() as buf:
            plan(...).execute(x)
        export.write_chrome(buf.events(), "trace.json")
    """

    def __init__(self, maxlen: int = 65536):
        self.buffer = EventBuffer(maxlen)
        self._was_enabled = False

    def __enter__(self) -> EventBuffer:
        self._was_enabled = _enabled
        add_sink(self.buffer)
        enable()
        return self.buffer

    def __exit__(self, *exc) -> bool:
        if not self._was_enabled:
            disable()
        remove_sink(self.buffer)
        return False


def iter_spans(events: Iterable[dict]) -> Iterable[dict]:
    """The span events of an event stream (exporter/report helper)."""
    return (e for e in events if e.get("kind") == "span")
