"""Predicted-vs-actual drift monitor for calibrations and selector models.

Every plan stamps ``predicted_s`` on its steps when a calibrated
:class:`~repro.core.cost.CostModel` is available, and every eager
execution produces :class:`~repro.core.sthosvd.ModeTrace` rows with real
wall-clock ``seconds``.  This module closes the loop: execution layers
feed ``(platform, backend, solver, predicted_s, actual_s)`` observations
into the process-wide :data:`MONITOR` (a few dict ops — cheap enough to
stay ON even when span tracing is off), which accumulates log-ratio
statistics per cell and flags cells whose predictions have drifted:

* ratio ``actual / predicted`` is tracked in log-space, so over- and
  under-prediction are symmetric and the geometric mean is the natural
  "how far off" scalar;
* a cell is **stale** when it has ``n >= min_samples`` observations, the
  one-sample-t-style z-score ``mean / (std / sqrt(n))`` clears
  ``z_threshold``, and the geometric-mean ratio sits outside
  ``[1/tolerance, tolerance]`` — all three, so a noisy-but-centred cell
  or a consistently-but-trivially-off cell is left alone;
* stale cells yield recommendations naming the flywheel command that
  repairs them (``python -m repro.tune calibrate`` for cost-model cells,
  ``... train`` when the selector itself chose the solver).

Memory drift is the same idea for space: modeled ``plan.peak_bytes`` vs
the live-array high-water sampled by :class:`MemoryWatch` (opt-in
background thread; the only jax import in this module, done lazily).
"""

from __future__ import annotations

import math
import threading
import time

__all__ = ["DriftCell", "DriftMonitor", "MONITOR", "MemoryWatch",
           "observe", "observe_traces", "reset"]


class DriftCell:
    """Running log-ratio statistics for one (platform, backend, solver)."""
    __slots__ = ("n", "sum_log", "sum_log2", "sum_pred", "sum_actual",
                 "last_t", "sources")

    def __init__(self):
        self.n = 0
        self.sum_log = 0.0
        self.sum_log2 = 0.0
        self.sum_pred = 0.0
        self.sum_actual = 0.0
        self.last_t = 0.0
        self.sources: dict[str, int] = {}

    def add(self, predicted_s: float, actual_s: float, source: str) -> None:
        r = math.log(actual_s / predicted_s)
        self.n += 1
        self.sum_log += r
        self.sum_log2 += r * r
        self.sum_pred += predicted_s
        self.sum_actual += actual_s
        self.last_t = time.time()
        self.sources[source] = self.sources.get(source, 0) + 1

    @property
    def mean_log(self) -> float:
        return self.sum_log / self.n if self.n else 0.0

    @property
    def std_log(self) -> float:
        if self.n < 2:
            return 0.0
        var = (self.sum_log2 - self.sum_log * self.sum_log / self.n) \
            / (self.n - 1)
        return math.sqrt(max(var, 0.0))

    @property
    def ratio(self) -> float:
        """Geometric-mean actual/predicted (1.0 = perfectly calibrated)."""
        return math.exp(self.mean_log)

    def z_score(self) -> float:
        """How many standard errors the mean log-ratio sits from 0."""
        if self.n < 2:
            return 0.0
        se = self.std_log / math.sqrt(self.n)
        if se == 0.0:
            # zero observed variance: any nonzero mean is infinitely
            # significant; cap so reports stay finite
            return 0.0 if self.mean_log == 0.0 else \
                math.copysign(99.0, self.mean_log)
        # near-identical observations (e.g. one wave's amortized shares)
        # make se vanishingly small; clamp so reports stay readable
        return max(-99.0, min(99.0, self.mean_log / se))


class DriftMonitor:
    """Aggregates timing + memory drift observations process-wide."""

    def __init__(self, *, min_samples: int = 5, z_threshold: float = 3.0,
                 tolerance: float = 1.5):
        self.min_samples = min_samples
        self.z_threshold = z_threshold
        self.tolerance = tolerance
        self._lock = threading.Lock()
        self._cells: dict[tuple[str, str, str], DriftCell] = {}
        # memory drift: keyed by backend → (modeled, observed, t) latest
        self._mem: dict[str, dict] = {}

    # ------------------------------------------------------------ feeding
    def observe(self, *, platform: str, backend: str, solver: str,
                predicted_s: float, actual_s: float,
                source: str = "execute") -> None:
        """Record one predicted-vs-actual pair.  Pairs without a positive
        prediction (uncalibrated plans) or measurement are ignored."""
        if not (predicted_s and predicted_s > 0.0 and actual_s
                and actual_s > 0.0):
            return
        key = (str(platform), str(backend), str(solver))
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = self._cells[key] = DriftCell()
            cell.add(predicted_s, actual_s, source)

    def observe_traces(self, traces, *, platform: str, backend: str,
                       source: str = "execute") -> int:
        """Feed a sequence of :class:`ModeTrace`-likes (needs ``method``,
        ``predicted_s``, ``seconds``).  Fused sweeps record ``seconds=0``
        per step and are skipped here — the serve layer attributes wave
        wall-clock instead.  Returns the number of pairs recorded."""
        n = 0
        for t in traces:
            pred = getattr(t, "predicted_s", 0.0) or 0.0
            actual = getattr(t, "seconds", 0.0) or 0.0
            if pred > 0.0 and actual > 0.0:
                self.observe(platform=platform, backend=backend,
                             solver=getattr(t, "method", "?"),
                             predicted_s=pred, actual_s=actual,
                             source=source)
                n += 1
        return n

    def observe_memory(self, *, backend: str, modeled_bytes: int,
                       observed_bytes: int) -> None:
        """Record one modeled-peak vs live-array high-water pair."""
        if modeled_bytes <= 0 or observed_bytes <= 0:
            return
        with self._lock:
            self._mem[str(backend)] = {
                "modeled_bytes": int(modeled_bytes),
                "observed_bytes": int(observed_bytes),
                "ratio": observed_bytes / modeled_bytes,
                "t": time.time(),
            }

    # ---------------------------------------------------------- reporting
    def cells(self) -> dict[tuple[str, str, str], DriftCell]:
        with self._lock:
            return dict(self._cells)

    def _cell_report(self, key, cell: DriftCell) -> dict:
        platform, backend, solver = key
        z = cell.z_score()
        stale = (cell.n >= self.min_samples
                 and abs(z) > self.z_threshold
                 and not (1.0 / self.tolerance <= cell.ratio
                          <= self.tolerance))
        return {
            "platform": platform, "backend": backend, "solver": solver,
            "n": cell.n,
            "ratio": cell.ratio,
            "z": z,
            "stale": stale,
            "predicted_total_s": cell.sum_pred,
            "actual_total_s": cell.sum_actual,
            "sources": dict(cell.sources),
        }

    def report(self) -> dict:
        """Full drift report: per-cell stats, memory drift, and repair
        recommendations (the ``repro.tune`` command that refreshes the
        stale model)."""
        cells = [self._cell_report(k, c)
                 for k, c in sorted(self.cells().items())]
        recs = []
        for c in cells:
            if not c["stale"]:
                continue
            direction = "slower" if c["ratio"] > 1.0 else "faster"
            recs.append({
                "cell": (c["platform"], c["backend"], c["solver"]),
                "why": (f"{c['solver']} on ({c['platform']}, "
                        f"{c['backend']}) runs {c['ratio']:.2f}x "
                        f"{direction} than predicted "
                        f"(n={c['n']}, z={c['z']:.1f})"),
                "command": (f"python -m repro.tune calibrate --platform "
                            f"{c['platform']} --backend {c['backend']}"),
            })
            if c["solver"] in ("eig", "svd", "als", "rand"):
                recs.append({
                    "cell": (c["platform"], c["backend"], c["solver"]),
                    "why": ("selector rankings may be inverted where "
                            "predictions drifted"),
                    "command": (f"python -m repro.tune train --platform "
                                f"{c['platform']} --backend "
                                f"{c['backend']}"),
                })
        with self._lock:
            mem = {k: dict(v) for k, v in self._mem.items()}
        for backend, m in mem.items():
            if m["ratio"] > self.tolerance:
                recs.append({
                    "cell": ("memory", backend, "peak_bytes"),
                    "why": (f"live-array high-water {m['ratio']:.2f}x the "
                            f"modeled peak on backend {backend}"),
                    "command": "review memory_cap_bytes / donation settings",
                })
        return {
            "cells": cells,
            "memory": mem,
            "stale": [c for c in cells if c["stale"]],
            "recommendations": recs,
            "thresholds": {"min_samples": self.min_samples,
                           "z": self.z_threshold,
                           "tolerance": self.tolerance},
        }

    def summary(self) -> dict:
        """Compact summary for :meth:`TuckerService.stats`."""
        cells = self.cells()
        stale = [self._cell_report(k, c) for k, c in sorted(cells.items())]
        stale = [c for c in stale if c["stale"]]
        return {
            "cells": len(cells),
            "observations": sum(c.n for c in cells.values()),
            "stale": [
                {"cell": (c["platform"], c["backend"], c["solver"]),
                 "ratio": round(c["ratio"], 3), "n": c["n"],
                 "z": round(c["z"], 1)}
                for c in stale
            ],
        }

    def reset(self) -> None:
        with self._lock:
            self._cells.clear()
            self._mem.clear()


#: the process-wide monitor (execution layers feed this directly)
MONITOR = DriftMonitor()


def observe(**kw) -> None:
    """Module-level shorthand for :meth:`MONITOR.observe`."""
    MONITOR.observe(**kw)


def observe_traces(traces, **kw) -> int:
    """Module-level shorthand for :meth:`MONITOR.observe_traces`."""
    return MONITOR.observe_traces(traces, **kw)


def reset() -> None:
    """Clear the process-wide monitor (tests)."""
    MONITOR.reset()


class MemoryWatch:
    """Background sampler of the jax live-array high-water mark.

    Opt-in (a thread polling :func:`jax.live_arrays` is not free): wrap
    the region whose footprint you want measured, then feed the result to
    :meth:`DriftMonitor.observe_memory` against the plan's modeled
    ``peak_bytes``::

        with MemoryWatch() as mw:
            plan.execute(x)
        MONITOR.observe_memory(backend=plan.backend,
                               modeled_bytes=plan.peak_bytes,
                               observed_bytes=mw.high_water)
    """

    def __init__(self, interval_s: float = 0.002):
        self.interval_s = interval_s
        self.high_water = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _sample(self) -> int:
        import jax  # lazy: keep repro.obs importable without a device

        try:
            return sum(getattr(a, "nbytes", 0) for a in jax.live_arrays())
        except Exception:  # noqa: BLE001 - sampling must never crash work
            return 0

    def _run(self) -> None:
        while not self._stop.is_set():
            self.high_water = max(self.high_water, self._sample())
            self._stop.wait(self.interval_s)

    def __enter__(self) -> "MemoryWatch":
        self.high_water = self._sample()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="atucker-memwatch")
        self._thread.start()
        return self

    def __exit__(self, *exc) -> bool:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self.high_water = max(self.high_water, self._sample())
        return False
