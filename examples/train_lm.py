"""End-to-end LM training driver with Tucker-compressed gradient exchange.

    PYTHONPATH=src python examples/train_lm.py                    # tiny (CPU)
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch gemma3-1b --compress

Presets: tiny (~2M params, minutes on this CPU), 100m (~100M params — sized
for a real accelerator), or any assigned arch via --arch (full config).
Checkpoints + deterministic data make Ctrl-C + rerun resume exactly.
"""

import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig, make_source
from repro.models import build
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.grad_compress import CompressionConfig
from repro.train.train_step import init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    "tiny": ModelConfig(name="tiny", n_layers=4, d_model=128, n_heads=4,
                        n_kv_heads=2, head_dim=32, d_ff=384, vocab=2048,
                        remat=False),
    "100m": ModelConfig(name="lm-100m", n_layers=12, d_model=768, n_heads=12,
                        n_kv_heads=4, head_dim=64, d_ff=2304, vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--arch", default=None,
                    help="assigned architecture id (overrides --preset)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="Tucker-compressed checkpoints (the paper's codec)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.arch else PRESETS[args.preset]
    bundle = build(cfg)
    print(f"arch={cfg.name}  params≈{cfg.param_count():,}")

    shape = ShapeConfig("train", args.seq, args.batch, "train")
    src = make_source(DataConfig(seed=0), cfg, shape)
    opt = AdamW(lr=cosine_schedule(args.lr, args.steps // 10, args.steps))
    state = init_state(bundle, opt, jax.random.PRNGKey(0))
    step = make_train_step(bundle, opt, n_micro=args.microbatch)

    comp = CompressionConfig(rank_fraction=0.25, min_size=1 << 14) \
        if args.compress else None
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=max(50, args.steps // 4),
                       compressed_ckpt_every=25 if args.compress else 0,
                       log_every=10, ckpt_dir=args.ckpt_dir)
    trainer = Trainer(tc, step, state, src, compressed_ckpt_cfg=comp,
                      log_path=f"{args.ckpt_dir}/metrics.jsonl")
    hist = trainer.run()
    print(f"\nloss: {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f} "
          f"over {args.steps} steps "
          f"({'improved' if hist[-1]['loss'] < hist[0]['loss'] else 'NOT improved'})")


if __name__ == "__main__":
    main()
