"""a-Tucker quickstart: the TuckerConfig → plan → execute front door.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic low-rank tensor, plans a decomposition (the adaptive
selector resolves the per-mode solver schedule ONCE, ahead of time), and
executes the frozen plan — then shows what planning buys: cached compiled
sweeps for repeated executes, one vmapped program for a fleet of tensors,
and the legacy per-call baselines for comparison.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TuckerConfig, plan, sthosvd, sthosvd_als, sthosvd_eig,
                        tensor_ops as T)


def make_tensor(dims, ranks, seed=0, noise=0.02):
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0] for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    return x + noise * float(jnp.std(x)) * jnp.asarray(
        rng.standard_normal(dims), jnp.float32)


def main():
    # a deliberately asymmetric tensor (one long mode — the regime where the
    # solver choice matters; cf. the paper's Air Quality tensor)
    dims, ranks = (600, 80, 40), (10, 10, 8)
    x = make_tensor(dims, ranks)

    # 1. plan: selector + cost model run here, never in the hot path
    # impl="auto" also picks the ops backend for this platform (TPU → the
    # Pallas kernels, CPU/GPU → matfree jnp contractions)
    cfg = TuckerConfig(ranks=ranks, methods="auto", impl="auto")
    p = plan(x.shape, x.dtype, cfg)
    print(f"tensor {dims} → ranks {ranks}")
    print(f"planned schedule: {' | '.join(f'{s.mode}:{s.method}' for s in p.schedule)}"
          f"   ops backend: {p.backend}")
    print(f"modeled cost: {p.total_flops / 1e6:.1f} MFLOP, "
          f"peak working set {p.peak_bytes / 2**20:.1f} MiB\n")

    # 2. execute: first call compiles the whole sweep as ONE program …
    t0 = time.perf_counter()
    res = p.execute(x)
    jax.block_until_ready(res.tucker.core)
    compile_and_run = time.perf_counter() - t0
    # … repeated executes reuse it (zero recompiles, zero selector calls)
    t0 = time.perf_counter()
    for _ in range(5):
        res = p.execute(x)
        jax.block_until_ready(res.tucker.core)
    warm = (time.perf_counter() - t0) / 5
    tt = res.tucker
    print(f"plan.execute        first={compile_and_run * 1e3:8.1f} ms  "
          f"warm={warm * 1e3:8.1f} ms   rel_err={float(tt.rel_error(x)):.4f}"
          f"   compression=x{tt.compression_ratio:.0f}")

    # 3. batched execution: a fleet of same-shaped tensors, one vmapped program
    xs = jnp.stack([make_tensor(dims, ranks, seed=s) for s in range(4)])
    p.execute_batch(xs)                     # warm-up (compile)
    t0 = time.perf_counter()
    batch = p.execute_batch(xs)
    jax.block_until_ready(batch[0].tucker.core)
    dt = time.perf_counter() - t0
    errs = [float(r.tucker.rel_error(xi)) for r, xi in zip(batch, xs)]
    print(f"plan.execute_batch  {len(batch)} tensors in {dt * 1e3:8.1f} ms  "
          f"max_err={max(errs):.4f}")

    # 4. legacy per-call baselines (selector/dispatch inside every call)
    print()
    for name, fn in (("st-HOSVD-EIG", sthosvd_eig),
                     ("st-HOSVD-ALS", sthosvd_als),
                     ("a-Tucker per-call",
                      lambda x_, r_, **kw: sthosvd(x_, r_, methods="auto", **kw))):
        fn(x, ranks)                        # warm-up (compile)
        t0 = time.perf_counter()
        r = fn(x, ranks, block_until_ready=True)
        dt = time.perf_counter() - t0
        print(f"{name:19s} {dt * 1e3:8.1f} ms   "
              f"rel_err={float(r.tucker.rel_error(x)):.4f}   "
              f"modes={'|'.join(f'{t.mode}:{t.method}' for t in sorted(r.trace, key=lambda t: t.mode))}")

    # 5. error-targeted decomposition: no ranks — ask for an accuracy and
    # let the plan's rank policy read per-mode ranks off a randomized
    # sketch of the input (then refine with the usual eig/als sweep)
    eps = 0.05
    acfg = TuckerConfig(error_target=eps)
    ap_ = plan(x.shape, x.dtype, acfg)
    ares = ap_.execute(x)
    aerr = float(ares.tucker.rel_error(x))
    print(f"\nerror_target={eps}   chose ranks {ares.tucker.ranks}   "
          f"rel_err={aerr:.4f}   certified bound={ares.error_bound:.4f}")
    assert aerr <= eps, f"achieved error {aerr} exceeds target {eps}"
    assert ares.error_bound <= eps

    # 6. plans are JSON — ship a schedule tuned on one box to another
    blob = p.to_json()
    print(f"\nplan serializes to {len(blob)} bytes of JSON "
          f"(TuckerPlan.save / TuckerPlan.load)")


if __name__ == "__main__":
    main()
