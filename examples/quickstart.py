"""a-Tucker quickstart: input-adaptive, matricization-free Tucker decomposition.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic low-rank tensor, decomposes it with the three solver
schedules (EIG / ALS / adaptive), and prints per-mode solver choices, errors
and timings — the paper's core loop in ~30 lines of user code.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import sthosvd, sthosvd_als, sthosvd_eig, tensor_ops as T


def main():
    # a deliberately asymmetric tensor (one long mode — the regime where the
    # solver choice matters; cf. the paper's Air Quality tensor)
    dims, ranks = (600, 80, 40), (10, 10, 8)
    rng = np.random.default_rng(0)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0] for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    x = x + 0.02 * float(jnp.std(x)) * jnp.asarray(rng.standard_normal(dims), jnp.float32)

    print(f"tensor {dims} → ranks {ranks}\n")
    for name, fn in (("st-HOSVD-EIG", sthosvd_eig),
                     ("st-HOSVD-ALS", sthosvd_als),
                     ("a-Tucker (adaptive)",
                      lambda x_, r_, **kw: sthosvd(x_, r_, methods="auto", **kw))):
        fn(x, ranks)                       # warm-up (compile)
        t0 = time.perf_counter()
        res = fn(x, ranks, block_until_ready=True)
        dt = time.perf_counter() - t0
        tt = res.tucker
        print(f"{name:22s} {dt*1e3:8.1f} ms   rel_err={float(tt.rel_error(x)):.4f}"
              f"   compression=x{tt.compression_ratio:.0f}"
              f"   modes={'|'.join(f'{t.mode}:{t.method}' for t in sorted(res.trace, key=lambda t: t.mode))}")

    print("\nreconstruction check:")
    res = sthosvd(x, ranks, methods="auto")
    xhat = res.tucker.reconstruct()
    print(f"  ‖X−X̂‖/‖X‖ = {float(T.fro_norm(x - xhat) / T.fro_norm(x)):.4f}"
          f"   (noise floor ≈ 0.02)")


if __name__ == "__main__":
    main()
