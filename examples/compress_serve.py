"""Post-training Tucker weight compression + serving comparison.

    PYTHONPATH=src python examples/compress_serve.py

Trains a tiny LM briefly, Tucker-compresses its stacked MLP weights through
the plan/execute front door (one ``TuckerPlan`` per distinct weight-stack
shape — the adaptive selector and sweep compilation are amortized across
same-shaped stacks), reconstructs, and serves the same prompts from both
models — reporting compression ratio, weight reconstruction error, and
generation agreement.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TuckerConfig, plan
from repro.data.pipeline import DataConfig, make_source
from repro.models import build
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamW
from repro.serve.engine import Request, ServeEngine
from repro.train.train_step import init_state, make_train_step


def tucker_compress_params(params, rank_fraction=0.5, min_size=1 << 12):
    """st-HOSVD on every ≥3-D weight stack; returns (params', report).

    Plans are cached per (shape, ranks): weight stacks sharing a shape (all
    layers' QKV, all layers' MLP, …) reuse one resolved schedule and one
    compiled sweep instead of re-selecting per leaf.
    """
    report = []
    plans = {}

    def one(path, leaf):
        if leaf.ndim < 3 or leaf.size < min_size or \
                not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        ranks = tuple(max(1, int(d * rank_fraction)) if i else d
                      for i, d in enumerate(leaf.shape))   # keep layer mode
        key = (leaf.shape, ranks)
        if key not in plans:
            plans[key] = plan(leaf.shape, jnp.float32,
                              TuckerConfig(ranks=ranks, methods="auto",
                                           compute_dtype="float32"))
        p = plans[key]
        res = p.execute(leaf.astype(jnp.float32))
        tt = res.tucker
        err = float(tt.rel_error(leaf.astype(jnp.float32)))
        report.append((jax.tree_util.keystr(path), leaf.shape, ranks,
                       tt.compression_ratio, err, p.methods))
        return tt.reconstruct().astype(leaf.dtype)

    out = jax.tree_util.tree_map_with_path(one, params)
    return out, report


def main():
    cfg = ModelConfig(name="tiny", n_layers=4, d_model=128, n_heads=4,
                      n_kv_heads=2, head_dim=32, d_ff=384, vocab=2048,
                      remat=False)
    bundle = build(cfg)
    shape = ShapeConfig("t", 128, 8, "train")
    src = make_source(DataConfig(seed=0), cfg, shape)
    opt = AdamW(lr=1e-3)
    state = init_state(bundle, opt, jax.random.PRNGKey(0))
    step = make_train_step(bundle, opt)
    print("training tiny LM (60 steps)…")
    for t in range(60):
        state, m = step(state, src.batch_at(t))
    print(f"  final loss {float(m['loss']):.3f}")

    print("\nTucker-compressing ≥3-D weight stacks (planned adaptive st-HOSVD)…")
    cparams, report = tucker_compress_params(state.params)
    n_shapes = len({(shp, rk) for _, shp, rk, *_ in report})
    print(f"  {len(report)} stacks compressed via {n_shapes} cached plan(s)")
    for path, shp, ranks, ratio, err, methods in report:
        print(f"  {path:40s} {str(shp):>18s} → ranks {ranks} "
              f"x{ratio:.1f} err={err:.3f} solvers={methods}")

    prompts = [[3, 1, 4, 1, 5], [9, 2, 6, 5], [8, 6, 7]]
    agree = total = 0
    for params, tag in ((state.params, "original"), (cparams, "compressed")):
        eng = ServeEngine(bundle, params, batch_slots=2, max_len=64)
        outs = eng.run([Request(prompt=p, max_new_tokens=8, rid=i)
                        for i, p in enumerate(prompts)])
        print(f"\n{tag} generations:")
        for r in outs:
            print(f"  {r.prompt} → {r.output}")
        if tag == "original":
            ref = [tuple(r.output) for r in outs]
        else:
            agree = sum(int(tuple(r.output) == ref[i]) for i, r in enumerate(outs))
            total = len(outs)
    print(f"\ngeneration agreement: {agree}/{total}")


if __name__ == "__main__":
    main()
