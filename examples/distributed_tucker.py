"""Distributed st-HOSVD across a device mesh (TuckerMPI's schedule, JAX-native).

    PYTHONPATH=src python examples/distributed_tucker.py

Runs on 8 simulated devices: the tensor is sharded along its largest mode;
per-mode Gram partials are psum'd over the mesh (explicit shard_map
schedule for EIG; GSPMD-sharded ALS), and the result is verified against
the single-device decomposition.  Shows both front doors onto the same
frozen schedule: the legacy per-call wrapper (real per-mode wall-clock)
and the plan/execute path (``impl="sharded"`` — shard modes, reshard
points, and per-device peak bytes resolved at plan time; one cached
compiled sweep at execute time).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TuckerConfig, plan, sthosvd_eig, tensor_ops as T
from repro.core.distributed import sthosvd_distributed


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = jax.make_mesh((8,), ("data",))

    dims, ranks = (64, 80, 48), (6, 8, 4)
    rng = np.random.default_rng(0)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0] for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    x = x + 0.02 * float(jnp.std(x)) * jnp.asarray(
        rng.standard_normal(dims), jnp.float32)

    ref = sthosvd_eig(x, ranks)
    print(f"single-device EIG   rel_err={float(ref.tucker.rel_error(x)):.4f}")

    for methods in ("eig", "als", "auto"):
        res = sthosvd_distributed(x, ranks, mesh, methods=methods)
        err = float(res.tucker.rel_error(x))
        print(f"distributed {methods:5s}  rel_err={err:.4f}  "
              f"modes={'|'.join(f'{t.mode}:{t.method}' for t in res.trace)}  "
              f"secs={'|'.join(f'{t.seconds * 1e3:.0f}ms' for t in res.trace)}")
        assert abs(err - float(ref.tucker.rel_error(x))) < 1e-3

    # plan/execute front door: the same schedule frozen ahead of time
    cfg = TuckerConfig(ranks=ranks, methods="auto", impl="sharded", mesh=mesh)
    p = plan(x.shape, x.dtype, cfg)
    print("\nsharded plan:")
    for s in p.schedule:
        print(f"  mode {s.mode}: {s.method:3s}  shard_mode={s.shard_mode}  "
              f"n_shards={s.n_shards}  peak={s.peak_bytes / 1e6:.2f} MB/device")
    res = p.execute(x)                      # one compiled shard_map sweep
    res2 = p.execute(x)                     # cache hit: zero recompiles
    err = float(res.tucker.rel_error(x))
    print(f"plan.execute        rel_err={err:.4f}  backend={p.backend}")
    assert abs(err - float(ref.tucker.rel_error(x))) < 1e-3
    assert float(jnp.abs(res.tucker.core - res2.tucker.core).max()) == 0.0

    print("\ndistributed == single-device ✓ "
          "(Gram partials psum'd over the mesh; factors bit-identical per device)")

    # rank-adaptive front end to a sharded job: sketch ranks on one device
    # (adaptive plans run replicated — the sketch has no collective path),
    # then plan the fixed-rank SHARDED sweep at the resolved ranks
    eps = 0.05
    probe = plan(x.shape, x.dtype, TuckerConfig(error_target=eps,
                                                methods="rand"))
    chosen, bound = probe.resolve_ranks(x)
    scfg = TuckerConfig(ranks=chosen, methods="auto", impl="sharded",
                        mesh=mesh)
    sres = plan(x.shape, x.dtype, scfg).execute(x)
    serr = float(sres.tucker.rel_error(x))
    print(f"error_target={eps}: sketch chose ranks {chosen} "
          f"(bound={bound:.4f}); sharded sweep at those ranks "
          f"rel_err={serr:.4f}")
    assert serr <= eps, f"achieved error {serr} exceeds target {eps}"


if __name__ == "__main__":
    main()
