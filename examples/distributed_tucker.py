"""Distributed st-HOSVD across a device mesh (TuckerMPI's schedule, JAX-native).

    PYTHONPATH=src python examples/distributed_tucker.py

Runs on 8 simulated devices: the tensor is sharded along its largest mode;
per-mode Gram partials are psum'd over the mesh (explicit shard_map
schedule for EIG; GSPMD-sharded ALS), and the result is verified against
the single-device decomposition.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sthosvd_eig, tensor_ops as T
from repro.core.distributed import sthosvd_distributed


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = jax.make_mesh((8,), ("data",))

    dims, ranks = (64, 80, 48), (6, 8, 4)
    rng = np.random.default_rng(0)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((d, r)))[0] for d, r in zip(dims, ranks)]
    x = T.reconstruct(jnp.asarray(core, jnp.float32),
                      [jnp.asarray(u, jnp.float32) for u in us])
    x = x + 0.02 * float(jnp.std(x)) * jnp.asarray(
        rng.standard_normal(dims), jnp.float32)

    ref = sthosvd_eig(x, ranks)
    print(f"single-device EIG   rel_err={float(ref.tucker.rel_error(x)):.4f}")

    for methods in ("eig", "als", "auto"):
        res = sthosvd_distributed(x, ranks, mesh, methods=methods)
        err = float(res.tucker.rel_error(x))
        print(f"distributed {methods:5s}  rel_err={err:.4f}  "
              f"modes={'|'.join(f'{t.mode}:{t.method}' for t in res.trace)}")
        assert abs(err - float(ref.tucker.rel_error(x))) < 1e-3

    print("\ndistributed == single-device ✓ "
          "(Gram partials psum'd over the mesh; factors bit-identical per device)")


if __name__ == "__main__":
    main()
