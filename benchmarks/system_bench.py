"""System-level benchmarks beyond the paper's figures: plan-reuse vs per-call
decomposition, Pallas kernel roofline characterization, Tucker
gradient-compression wire savings, and tiny-train throughput (the end-to-end
driver measured)."""

from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.optim.grad_compress import CompressionConfig, compressed_bytes

from .common import emit, lowrank_tensor, time_call


def _bench_backends() -> tuple[str, ...]:
    """Backend axis for the system benches: jnp backends everywhere; the
    ``pallas`` rows only where they mean something (native TPU) or when
    forced via ``ATUCKER_BENCH_PALLAS=1`` (interpret mode — correctness
    trajectory, not perf)."""
    import os
    backends = ["matfree", "explicit"]
    if jax.default_backend() == "tpu" or os.environ.get("ATUCKER_BENCH_PALLAS"):
        backends.append("pallas")
    return tuple(backends)


def plan_bench(n_repeat: int = 8, batch: int = 8):
    """Plan/execute vs legacy per-call API (the tentpole's amortization claim).

    Three regimes per shape:
      * percall  — legacy ``sthosvd(x, ranks, methods="auto")``: selector +
        Python dispatch inside every call.
      * plan     — ``plan()`` once, then repeated ``execute``: frozen schedule,
        one cached compiled sweep — one row per ops backend.
      * batch    — ``execute_batch`` on a fleet of ``batch`` same-shaped
        tensors vs the per-item ``execute`` loop.
    """
    from dataclasses import replace

    from repro.core import TuckerConfig, plan, sthosvd

    cases = [((96, 64, 48), (8, 8, 8)), ((256, 24, 24), (8, 6, 6))]
    for dims, ranks in cases:
        tag = "x".join(map(str, dims))
        x = lowrank_tensor(dims, ranks, noise=0.05)
        cfg = TuckerConfig(ranks=ranks, methods="auto")
        p = plan(x.shape, x.dtype, cfg)

        t_percall = time_call(
            lambda: sthosvd(x, ranks, methods="auto", block_until_ready=True),
            reps=n_repeat)
        t_plan = time_call(
            lambda: jax.block_until_ready(p.execute(x).tucker.core),
            reps=n_repeat)
        emit(f"plan/{tag}/percall", t_percall, f"ranks={ranks}")
        emit(f"plan/{tag}/execute", t_plan,
             f"speedup=x{t_percall / t_plan:.2f};schedule={'|'.join(p.methods)}"
             f";backend={p.backend}")
        for impl in _bench_backends():
            if impl == p.backend:
                continue                      # already timed above
            pb = plan(x.shape, x.dtype, replace(cfg, impl=impl))
            t_b = time_call(
                lambda: jax.block_until_ready(pb.execute(x).tucker.core),
                reps=n_repeat)
            emit(f"plan/{tag}/execute[{impl}]", t_b,
                 f"vs_{p.backend}=x{t_plan / t_b:.2f}")

        xs = jnp.stack([lowrank_tensor(dims, ranks, noise=0.05, seed=s)
                        for s in range(batch)])
        t_loop = time_call(
            lambda: [jax.block_until_ready(p.execute(xs[b]).tucker.core)
                     for b in range(batch)], reps=2)
        t_batch = time_call(
            lambda: jax.block_until_ready(p.execute_batch(xs)[0].tucker.core),
            reps=2)
        emit(f"plan/{tag}/batch{batch}", t_batch,
             f"loop={t_loop * 1e6:.1f}us;speedup=x{t_loop / t_batch:.2f}")


def kernels_bench():
    """Per-kernel shape sweep: correctness delta + arithmetic intensity (the
    TPU-roofline characterization; wall-time on CPU interpret mode is not
    meaningful for the TPU target and is reported only as a sanity check)."""
    cases = [
        ("ttm_mode0", (512, 64, 64), 0, 32),
        ("ttm_interior", (64, 512, 64), 1, 32),
        ("ttm_last", (64, 64, 512), 2, 32),
    ]
    for name, shape, mode, r in cases:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(shape), jnp.float32)
        u = jnp.asarray(np.random.default_rng(1).standard_normal((r, shape[mode])), jnp.float32)
        got = kops.ttm(x, u, mode)
        want = kref.ttm_full_ref(x, u, mode)
        err = float(jnp.abs(got - want).max())
        flops = 2 * math.prod(shape) * r
        bytes_ = 4 * (math.prod(shape) + r * shape[mode]
                      + math.prod(shape) // shape[mode] * r)
        emit(f"kernels/{name}", 0.0,
             f"maxerr={err:.2e};AI={flops / bytes_:.1f}flops_per_byte")
    # gram
    x = jnp.asarray(np.random.default_rng(2).standard_normal((128, 256, 64)), jnp.float32)
    err = float(jnp.abs(kops.gram(x, 1) - kref.gram_full_ref(x, 1)).max())
    emit("kernels/gram", 0.0, f"maxerr={err:.2e}")


def grad_compress_bench():
    """Wire bytes for each assigned arch's scanned-gradient pytree."""
    from repro import configs
    from repro.models import build
    cfg_comp = CompressionConfig(rank_fraction=0.125, max_rank=128,
                                 min_size=1 << 16, refresh_every=20)
    for arch in ("mixtral_8x22b", "gemma2_9b", "falcon_mamba_7b"):
        cfg = configs.get(arch)
        bundle = build(cfg)
        abs_params = bundle.abstract_params()
        dense = comp = 0
        for leaf in jax.tree.leaves(abs_params):
            d, c = compressed_bytes(cfg_comp, tuple(leaf.shape))
            dense += d
            comp += c
        emit(f"grad_compress/{arch}", 0.0,
             f"dense={dense/2**30:.2f}GiB;wire={comp/2**30:.2f}GiB;"
             f"ratio=x{dense/comp:.1f}")


def tiny_train_bench(steps: int = 10):
    """Measured steps/s of the end-to-end driver on the smoke config."""
    from repro import configs
    from repro.data.pipeline import DataConfig, make_source
    from repro.models import build
    from repro.models.config import ShapeConfig
    from repro.optim.adamw import AdamW
    from repro.train.train_step import init_state, make_train_step

    cfg = configs.get_smoke("phi3_mini_3p8b").with_(remat=False)
    bundle = build(cfg)
    shape = ShapeConfig("bench", seq_len=64, global_batch=8, kind="train")
    src = make_source(DataConfig(seed=0), cfg, shape)
    opt = AdamW(lr=1e-3)
    state = init_state(bundle, opt, jax.random.PRNGKey(0))
    step = make_train_step(bundle, opt)
    state, _ = step(state, src.batch_at(0))      # compile
    t0 = time.perf_counter()
    for i in range(1, steps + 1):
        state, m = step(state, src.batch_at(i))
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    tok_s = shape.global_batch * shape.seq_len / dt
    emit("train/tiny_steps", dt, f"tokens_per_s={tok_s:.0f}")
