"""Mode-parallel sweep bench: sequential vs grouped Grams vs the DP's "auto".

Forces 8 virtual host devices (before jax initializes) and, per asymmetric
shape, times one planned st-HOSVD sweep for each ``mode_parallel`` arm:
``off`` (sequential shrink), ``2`` (leading 2-mode group, sharded over the
mode outside it), ``3`` (all-modes group, replicated), and ``auto`` (the
latency-priced grouping DP picks).  On one physical CPU the virtual devices
share silicon, so the signal is DISPATCH STRUCTURE: a group fuses N Gram
shard_maps + N truncation reshards into one psum program + one multi-TTM —
exactly the barrier count a latency-bound shape is dominated by.

The trailing check mirrors the acceptance gate: ``auto`` must keep within
``AUTO_TOL`` of the best fixed arm on at least 2 of the 3 shapes (its
schedule IS one of the fixed arms — only timing noise separates them).

Usage:  python -m benchmarks.modepar_bench [--smoke | --full]
                                           [--out BENCH_modepar.json]
"""

from __future__ import annotations

import os

# must precede jax init; append so externally-set flags survive
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import json
import platform as _platform
from pathlib import Path

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import TuckerConfig, plan

from .common import emit, lowrank_tensor, time_call

# three ASYMMETRIC shapes (dims divide by 8): one long mode, two-big-one-
# small, and mixed — the regimes where grouping vs shrinking genuinely trade
SHAPES = {False: [((64, 16, 16), (4, 4, 4)),
                  ((32, 32, 8), (4, 4, 4)),
                  ((24, 16, 40), (4, 4, 4))],
          True: [((256, 64, 64), (8, 8, 8)),
                 ((128, 128, 32), (8, 8, 8)),
                 ((96, 64, 160), (8, 8, 8))]}

ARMS = ("off", 2, 3, "auto")

#: "auto" must stay within this factor of the best FIXED arm per shape —
#: same compiled programs, so only timing noise separates them
AUTO_TOL = 1.4


def bench_modepar(full: bool = False, reps: int = 5) -> list[dict]:
    devices = jax.devices()
    if len(devices) < 8:
        print(f"# modepar: need 8 devices, have {len(devices)} — skipping")
        return []
    mesh = Mesh(np.array(devices[:8]), ("data",))
    rows: list[dict] = []

    for dims, ranks in SHAPES[full]:
        x = lowrank_tensor(dims, ranks, noise=0.05)
        tag = "x".join(map(str, dims))
        for mp in ARMS:
            p = plan(x.shape, x.dtype,
                     TuckerConfig(ranks=ranks, methods="eig", impl="sharded",
                                  mesh=mesh, mode_parallel=mp))
            t = time_call(
                lambda: jax.block_until_ready(p.execute(x).tucker.core),
                reps=reps)
            err = float(p.execute(x).tucker.rel_error(x))
            groups = sorted({s.group for s in p.schedule
                             if s.group is not None})
            grouped = sum(1 for s in p.schedule if s.group is not None)
            emit(f"modepar/{mp}/{tag}", t,
                 f"rel_err={err:.4f} grouped_modes={grouped}")
            rows.append({"bench": "modepar", "backend": p.backend,
                         "n_devices": 8, "mode_par": str(mp),
                         "methods": "eig", "shape": list(dims),
                         "ranks": list(ranks), "us_per_call": t * 1e6,
                         "rel_err": err, "grouped_modes": grouped,
                         "n_groups": len(groups)})
        seq = next(r for r in rows[-len(ARMS):] if r["mode_par"] == "off")
        for r in rows[-len(ARMS):]:
            r["speedup_vs_seq"] = seq["us_per_call"] / r["us_per_call"]
    return rows


def check_rows(rows: list[dict]) -> list[str]:
    """The bench's own acceptance gates; returns failure strings (empty =
    pass).  Kept importable so CI can re-assert from the written JSON."""
    fails: list[str] = []
    shapes = sorted({tuple(r["shape"]) for r in rows})
    auto_ok = 0
    any_group_win = False
    for shp in shapes:
        arm = {r["mode_par"]: r for r in rows if tuple(r["shape"]) == shp}
        fixed = [arm[k] for k in ("off", "2", "3") if k in arm]
        best_fixed = min(r["us_per_call"] for r in fixed)
        if arm["auto"]["us_per_call"] <= best_fixed * AUTO_TOL:
            auto_ok += 1
        if any(r["us_per_call"] < arm["off"]["us_per_call"]
               for r in fixed if r["mode_par"] != "off"):
            any_group_win = True
    if shapes and auto_ok < 2:
        fails.append(f"auto within {AUTO_TOL}x of best fixed arm on only "
                     f"{auto_ok}/{len(shapes)} shapes")
    if shapes and not any_group_win:
        fails.append("mode-parallel beat sequential on 0 shapes "
                     "(expected >= 1 latency-bound win)")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, few reps (CI tier)")
    ap.add_argument("--full", action="store_true",
                    help="larger tensors (more FLOPs per barrier)")
    ap.add_argument("--out", default="BENCH_modepar.json",
                    help="JSON row file path ('' to skip writing)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    full = args.full and not args.smoke
    rows = bench_modepar(full=full, reps=3 if args.smoke else 5)
    if args.out:
        doc = {"bench": "modepar", "jax_backend": jax.default_backend(),
               "host": _platform.machine(), "full": full,
               "n_devices_available": len(jax.devices()), "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1))
        print(f"wrote {args.out} ({len(rows)} rows)")
    fails = check_rows(rows)
    for f in fails:
        print(f"CHECK FAILED: {f}")
    if fails:
        raise SystemExit(1)
    if rows:
        print("checks passed: auto tracks best fixed arm; grouping wins "
              "on >= 1 shape")


if __name__ == "__main__":
    main()
