"""Markdown table of BENCH_*.json rows — the per-run perf trajectory.

Reads one or more row files written by the benches (backend_bench,
sharded_bench, system benches) and prints a GitHub-flavoured markdown
table to stdout; CI appends it to ``$GITHUB_STEP_SUMMARY`` so the numbers
are visible on every run without downloading artifacts.

Usage:  python -m benchmarks.summary_md [BENCH_a.json BENCH_b.json ...]
        (no args: globs BENCH_*.json in the working directory)
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

#: columns shown first, in this order, when any row carries them; remaining
#: keys are folded into a trailing ``notes`` column
PREFERRED = ("source", "bench", "backend", "op", "methods", "selector",
             "mode_order", "mode_par", "n_devices", "shape", "ranks",
             "us_per_call",
             "peak_mb", "rel_err", "throughput_rps", "p95_ms", "pad_waste")
SKIP = {"mode", "r", "native", "order"}   # low-signal noise in a cross-bench table


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:,.1f}" if abs(v) >= 10 else f"{v:.4g}"
    if isinstance(v, list):
        return "×".join(str(i) for i in v)
    return "" if v is None else str(v)


def load_rows(paths: list[Path]) -> list[dict]:
    rows = []
    for path in paths:
        doc = json.loads(path.read_text())
        for r in doc.get("rows", []):
            rows.append({"source": path.name,
                         "bench": f'{doc.get("bench", "?")}/{r.get("bench", "?")}',
                         **{k: v for k, v in r.items() if k != "bench"}})
    return rows


def to_markdown(rows: list[dict]) -> str:
    if not rows:
        return "_no BENCH_*.json files found_"
    cols = [c for c in PREFERRED if any(c in r for r in rows)]
    extras = sorted({k for r in rows for k in r}
                    - set(cols) - SKIP)
    out = ["### Bench trajectory (" + f"{len(rows)} rows)", ""]
    out.append("| " + " | ".join(cols + ["notes"]) + " |")
    out.append("|" + "---|" * (len(cols) + 1))
    for r in rows:
        notes = ", ".join(f"{k}={_fmt(r[k])}" for k in extras if k in r)
        out.append("| " + " | ".join(_fmt(r.get(c)) for c in cols)
                   + f" | {notes} |")
    return "\n".join(out)


def main() -> None:
    paths = [Path(p) for p in sys.argv[1:]] or sorted(Path().glob("BENCH_*.json"))
    print(to_markdown(load_rows([p for p in paths if p.exists()])))


if __name__ == "__main__":
    main()
