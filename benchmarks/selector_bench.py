"""Selector benchmark: adaptive vs coarse-grained sweeps + selection overhead.

Reproduces the paper's two selector claims on this box (Figs. 7/9):

  * the *sweep* rows time a full planned st-HOSVD with ``methods="auto"``
    (the trained/analytic selector picks per mode) against the coarse
    ``"eig"``-everywhere and ``"als"``-everywhere baselines — the adaptive
    schedule should match or beat the better baseline per shape;
  * the *select_overhead* rows time a single selector query (tree walk +
    feature extraction vs the analytic cost model) — the paper reports
    23–90 µs per mode, negligible against any mode solve.

Writes ``BENCH_selector.json`` rows (folded into the step-summary table by
``benchmarks.summary_md``).

Usage:  python -m benchmarks.selector_bench [--full] [--out BENCH_selector.json]
"""

from __future__ import annotations

import argparse
import json
import platform as _platform
import time
from pathlib import Path

import jax

from repro.core import TuckerConfig, plan
from repro.core.selector import Selector, default_selector

from .common import emit, lowrank_tensor, time_call

# asymmetric shapes straddle the EIG/ALS crossover: one dominant mode
# (EIG's Gram explodes) vs balanced small modes (EIG wins)
CASES = {
    False: [((96, 24, 16), (8, 6, 4)), ((16, 96, 24), (4, 8, 6)),
            ((32, 32, 32), (8, 8, 8))],
    True: [((512, 64, 48), (16, 12, 8)), ((48, 512, 64), (8, 16, 12)),
           ((128, 128, 128), (16, 16, 16))],
}

#: per-query overhead probes: (i_n, r_n, j_n)
QUERIES = [(96, 8, 384), (512, 16, 3072), (32, 8, 1024)]


def bench_sweeps(full: bool, reps: int = 3) -> list[dict]:
    sel = default_selector()
    model = "tree" if sel.tree is not None else "cost_model"
    rows: list[dict] = []
    for dims, ranks in CASES[full]:
        x = lowrank_tensor(dims, ranks, noise=0.05)
        for methods in ("auto", "eig", "als"):
            cfg = TuckerConfig(ranks=ranks, methods=methods)
            p = plan(x.shape, x.dtype, cfg)
            t = time_call(lambda: jax.block_until_ready(
                p.execute(x).tucker.core), reps=reps)
            err = float(p.execute(x).tucker.rel_error(x))
            tag = "x".join(map(str, dims))
            emit(f"selector/sweep/{methods}/{tag}", t,
                 f"schedule={'+'.join(p.methods)} rel_err={err:.4f}")
            rows.append({"bench": "sweep", "methods": methods,
                         "selector": model if methods == "auto" else None,
                         "shape": list(dims), "ranks": list(ranks),
                         "us_per_call": t * 1e6, "rel_err": err,
                         "schedule": "+".join(p.methods),
                         "select_us": p.select_seconds * 1e6})
    return rows


def bench_selection_overhead(reps: int = 2000) -> list[dict]:
    """Per-query selector cost: trained tree vs analytic cost model (paper
    Fig. 7: 23–90 µs per mode)."""
    trained = default_selector()
    probes = [("cost_model", Selector(platform=trained.platform))]
    if trained.tree is not None:
        probes.insert(0, ("tree", trained))
    rows = []
    for name, sel in probes:
        t0 = time.perf_counter()
        for _ in range(reps):
            for i, r, j in QUERIES:
                sel(i_n=i, r_n=r, j_n=j)
        per_call = (time.perf_counter() - t0) / (reps * len(QUERIES))
        emit(f"selector/query/{name}", per_call)
        rows.append({"bench": "select_overhead", "selector": name,
                     "us_per_call": per_call * 1e6})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger shapes")
    ap.add_argument("--out", default="BENCH_selector.json",
                    help="JSON row file path ('' to skip writing)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    rows = bench_sweeps(full=args.full) + bench_selection_overhead()
    if args.out:
        sel = default_selector()
        doc = {"bench": "selector", "jax_backend": jax.default_backend(),
               "host": _platform.machine(), "full": args.full,
               "model": ("tree" if sel.tree is not None else "cost_model"),
               "model_meta": {k: sel.meta[k] for k in
                              ("test_accuracy", "cv_accuracy", "n_examples",
                               "store_digest") if k in sel.meta},
               "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1))
        print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
