"""Streaming-serve bench: continuous batching vs one-shot engine runs.

Simulates a Poisson-arrival stream of mixed-shape Tucker requests (shapes
cluster around a few bucket anchors with per-request jitter, like real
traffic) and pushes the SAME arrival schedule through two arms:

  * ``oneshot`` — the pre-service serving story: each request is handed to
    ``TuckerBatchEngine.run([req])`` the moment it arrives.  No cross-
    request batching, and every distinct jittered shape pays its own
    selector pass + XLA compile.
  * ``service`` — ``TuckerService`` with a background worker: requests are
    bucketed (mask pad mode, pow2 lane fill), so the whole stream runs
    through a handful of warm vmapped programs with continuous wave refill.

Both arms get one generic warmup execute so baseline jax/jit overhead is
excluded; the per-odd-shape planning + compile the bucket design avoids is
deliberately left IN the measurement — that amortization is the subsystem
under test.  Reports end-to-end throughput and per-request latency
percentiles (arrival → result), plus per-bucket p95 / pad-waste /
occupancy rows from ``service.stats()``.

Usage:  python -m benchmarks.serve_bench [--smoke | --full]
                                         [--out BENCH_serve.json]
                                         [--perfetto trace.json]
                                         [--drift-report drift.json]

``--perfetto PATH`` replays a small traced slice of the stream through
fresh services with :mod:`repro.obs` span tracing on and writes a Chrome
trace-event file (open in Perfetto: submit → wave → compiles → per-mode
solves on one timeline).  ``--drift-report PATH`` dumps the process
drift monitor (predicted-vs-actual per platform/backend/solver, fed by
both arms' traffic) as JSON.
"""

from __future__ import annotations

import argparse
import json
import platform as _platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TuckerConfig
from repro.core.api import plan as make_plan
from repro.serve import (
    BucketPolicy,
    TuckerBatchEngine,
    TuckerRequest,
    TuckerService,
)
from repro.serve.metrics import LatencyWindow

from .common import emit

RANKS = (4, 4, 4)
#: bucket anchors the stream's shape clusters hug (all multiples of grid=8)
CLUSTERS = {False: ((16, 16, 16), (24, 16, 16), (16, 24, 8)),
            True: ((48, 40, 32), (64, 48, 32), (40, 40, 40))}
N_REQUESTS = {False: 32, True: 200}
#: arrival rate as a multiple of the single-request service rate — fast
#: enough that an unbatched arm falls behind, slow enough to be a stream
RATE_FACTOR = 3.0
JITTER = 6   # dims are drawn from [anchor - JITTER, anchor]


def make_stream(full: bool, seed: int = 0):
    """(arrival_s, tensor) pairs: Poisson arrivals, clustered jittered shapes."""
    rng = np.random.default_rng(seed)
    clusters = CLUSTERS[full]
    n = N_REQUESTS[full]

    # calibrate the arrival rate against a warm singleton execute on the
    # first anchor (also serves as the generic jit warmup for both arms)
    cfg = TuckerConfig(ranks=RANKS, methods="eig")
    anchor = clusters[0]
    x0 = jnp.asarray(rng.standard_normal(anchor), jnp.float32)
    p = make_plan(anchor, x0.dtype, cfg)
    jax.block_until_ready(p.execute(x0).tucker.core)
    t0 = time.perf_counter()
    jax.block_until_ready(p.execute(x0).tucker.core)
    t_single = max(time.perf_counter() - t0, 1e-4)
    rate = RATE_FACTOR / t_single

    stream, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        base = clusters[int(rng.integers(len(clusters)))]
        dims = tuple(max(int(b - rng.integers(0, JITTER)), r + 1)
                     for b, r in zip(base, RANKS))
        x = jnp.asarray(rng.standard_normal(dims), jnp.float32)
        stream.append((t, x))
    return stream, cfg, rate


def _replay(stream, submit_fn):
    """Feed the stream at its arrival times; returns total wall seconds."""
    t0 = time.perf_counter()
    for arrival, x in stream:
        lag = arrival - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        submit_fn(arrival, x, t0)
    return t0


def run_oneshot(stream, cfg) -> dict:
    eng = TuckerBatchEngine()
    lat = LatencyWindow()

    def submit(arrival, x, t0):
        eng.run([TuckerRequest(x=x, config=cfg)])
        lat.add(time.perf_counter() - t0 - arrival)

    t0 = _replay(stream, submit)
    total = time.perf_counter() - t0
    return {"bench": "serve_stream", "arm": "oneshot", "n": len(stream),
            "plans_built": eng.stats["plans_built"],
            "throughput_rps": len(stream) / total, **lat.snapshot_ms()}


def run_service(stream, cfg) -> tuple[dict, list[dict]]:
    svc = TuckerService(
        policy=BucketPolicy(grid=8, max_pad_ratio=8.0, pad_mode="mask",
                            wave_slots=8),
        max_queue=4 * len(stream), backpressure="block",
        max_inflight_waves=3)
    svc.start()
    tickets = []

    def submit(arrival, x, t0):
        tickets.append(svc.submit(x, cfg))

    t0 = _replay(stream, submit)
    for t in tickets:
        svc.wait(t, timeout=600)
    total = time.perf_counter() - t0
    stats = svc.stats()
    svc.stop()
    row = {"bench": "serve_stream", "arm": "service", "n": len(stream),
           "plans_built": stats["plans_built"],
           "throughput_rps": len(stream) / total,
           "pad_waste": stats["pad_waste"],
           "max_inflight_waves": stats["max_inflight_waves"],
           **stats["latency"]}
    bucket_rows = [
        {"bench": "bucket", "arm": "service", "bucket": label,
         "completed": b["completed"], "waves": b["waves"],
         "pad_waste": b["pad_waste"], "occupancy": b["occupancy"],
         "pipeline_occupancy": b["pipeline_occupancy"],
         "p95_ms": b["latency"]["p95_ms"]}
        for label, b in stats["buckets"].items()]
    return row, bucket_rows


def bench_serve(full: bool = False, seed: int = 0) -> list[dict]:
    stream, cfg, rate = make_stream(full, seed=seed)
    return bench_serve_stream(stream, cfg, rate)


def bench_serve_stream(stream, cfg, rate) -> list[dict]:
    one = run_oneshot(stream, cfg)
    # fresh arrival clock, same schedule/tensors, for the service arm
    srv, bucket_rows = run_service(stream, cfg)
    srv["win"] = srv["throughput_rps"] / one["throughput_rps"]
    for r in (one, srv):
        r["arrival_rps"] = rate
        emit(f"serve/{r['arm']}", 1.0 / r["throughput_rps"],
             f"p95_ms={r['p95_ms']:.1f}")
    for b in bucket_rows:
        emit(f"serve/bucket/{b['bucket']}", b["p95_ms"] / 1e3,
             f"pad_waste={b['pad_waste']:.3f}")
    print(f"# continuous batching throughput win: {srv['win']:.2f}x "
          f"({srv['throughput_rps']:.1f} vs {one['throughput_rps']:.1f} rps)")
    return [one, srv, *bucket_rows]


def export_perfetto(stream, cfg, path: str, n: int = 6) -> None:
    """Replay the first ``n`` stream tensors through fresh services with
    tracing on and write the capture as one Perfetto-loadable Chrome
    trace.  Two passes share the capture so the trace carries the full
    story: a fused pass against a cleared sweep cache (cache-miss +
    compile spans on the wave timeline) and a recorded pass (per-mode
    ``solve`` spans with solver/backend/rank attributes)."""
    from repro import obs
    from repro.core.api import _SWEEP_CACHE

    policy = BucketPolicy(grid=8, max_pad_ratio=8.0, pad_mode="mask",
                          wave_slots=4)
    with obs.capture() as buf:
        _SWEEP_CACHE.clear()   # cold start: the slice shows the real compile
        for record in (False, True):
            with TuckerService(policy=policy, record=record) as svc:
                for _, x in stream[:n]:
                    svc.submit(x, cfg)
                svc.drain()
    doc = obs.write_chrome(buf.events(), path)
    names = {e["name"].split(" ")[0] for e in doc["traceEvents"]}
    print(f"# perfetto trace: {len(doc['traceEvents'])} events "
          f"({', '.join(sorted(names))}) -> {path}")


def export_drift(path: str) -> None:
    """Dump the process drift monitor (fed by this run's traffic)."""
    from repro.obs.drift import MONITOR

    report = MONITOR.report()
    Path(path).write_text(json.dumps(report, indent=1, default=str))
    print(f"# drift report: {len(report['cells'])} cells, "
          f"{len(report['stale'])} stale -> {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized stream (the default size)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale stream (minutes on 1 CPU core)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="JSON row file path ('' to skip writing)")
    ap.add_argument("--seed", type=int, default=0,
                    help="stream RNG seed (arrivals, shapes, tensor data) — "
                         "vary for run-to-run noise estimates")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="also export a traced replay slice as a Chrome "
                         "trace-event file (Perfetto-loadable)")
    ap.add_argument("--drift-report", default=None, metavar="PATH",
                    help="also dump the predicted-vs-actual drift report "
                         "as JSON")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    stream, cfg, rate = make_stream(full=args.full and not args.smoke,
                                    seed=args.seed)
    rows = bench_serve_stream(stream, cfg, rate)
    if args.out:
        doc = {"bench": "serve", "jax_backend": jax.default_backend(),
               "host": _platform.machine(), "full": args.full, "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1))
        print(f"wrote {args.out} ({len(rows)} rows)")
    if args.perfetto:
        export_perfetto(stream, cfg, args.perfetto)
    if args.drift_report:
        export_drift(args.drift_report)


if __name__ == "__main__":
    main()
