"""Streaming-serve bench: continuous batching vs one-shot engine runs.

Simulates a Poisson-arrival stream of mixed-shape Tucker requests (shapes
cluster around a few bucket anchors with per-request jitter, like real
traffic) and pushes the SAME arrival schedule through two arms:

  * ``oneshot`` — the pre-service serving story: each request is handed to
    ``TuckerBatchEngine.run([req])`` the moment it arrives.  No cross-
    request batching, and every distinct jittered shape pays its own
    selector pass + XLA compile.
  * ``service`` — ``TuckerService`` with a background worker: requests are
    bucketed (mask pad mode, pow2 lane fill), so the whole stream runs
    through a handful of warm vmapped programs with continuous wave refill.

Both arms get one generic warmup execute so baseline jax/jit overhead is
excluded; the per-odd-shape planning + compile the bucket design avoids is
deliberately left IN the measurement — that amortization is the subsystem
under test.  Reports end-to-end throughput and per-request latency
percentiles (arrival → result), plus per-bucket p95 / pad-waste /
occupancy rows from ``service.stats()``.

Usage:  python -m benchmarks.serve_bench [--smoke | --full]
                                         [--out BENCH_serve.json]
                                         [--perfetto trace.json]
                                         [--drift-report drift.json]

``--perfetto PATH`` replays a small traced slice of the stream through
fresh services with :mod:`repro.obs` span tracing on and writes a Chrome
trace-event file (open in Perfetto: submit → wave → compiles → per-mode
solves on one timeline).  ``--drift-report PATH`` dumps the process
drift monitor (predicted-vs-actual per platform/backend/solver, fed by
both arms' traffic) as JSON.
"""

from __future__ import annotations

import argparse
import json
import platform as _platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TuckerConfig
from repro.core.api import plan as make_plan
from repro.serve import (
    BucketPolicy,
    TuckerBatchEngine,
    TuckerRequest,
    TuckerService,
)
from repro.serve.metrics import LatencyWindow

from .common import emit

RANKS = (4, 4, 4)
#: bucket anchors the stream's shape clusters hug (all multiples of grid=8)
CLUSTERS = {False: ((16, 16, 16), (24, 16, 16), (16, 24, 8)),
            True: ((48, 40, 32), (64, 48, 32), (40, 40, 40))}
N_REQUESTS = {False: 32, True: 200}
#: arrival rate as a multiple of the single-request service rate — fast
#: enough that an unbatched arm falls behind, slow enough to be a stream
RATE_FACTOR = 3.0
JITTER = 6   # dims are drawn from [anchor - JITTER, anchor]


def make_stream(full: bool, seed: int = 0):
    """(arrival_s, tensor) pairs: Poisson arrivals, clustered jittered shapes."""
    rng = np.random.default_rng(seed)
    clusters = CLUSTERS[full]
    n = N_REQUESTS[full]

    # calibrate the arrival rate against a warm singleton execute on the
    # first anchor (also serves as the generic jit warmup for both arms)
    cfg = TuckerConfig(ranks=RANKS, methods="eig")
    anchor = clusters[0]
    x0 = jnp.asarray(rng.standard_normal(anchor), jnp.float32)
    p = make_plan(anchor, x0.dtype, cfg)
    jax.block_until_ready(p.execute(x0).tucker.core)
    t0 = time.perf_counter()
    jax.block_until_ready(p.execute(x0).tucker.core)
    t_single = max(time.perf_counter() - t0, 1e-4)
    rate = RATE_FACTOR / t_single

    stream, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        base = clusters[int(rng.integers(len(clusters)))]
        dims = tuple(max(int(b - rng.integers(0, JITTER)), r + 1)
                     for b, r in zip(base, RANKS))
        x = jnp.asarray(rng.standard_normal(dims), jnp.float32)
        stream.append((t, x))
    return stream, cfg, rate


def _replay(stream, submit_fn):
    """Feed the stream at its arrival times; returns total wall seconds."""
    t0 = time.perf_counter()
    for arrival, x in stream:
        lag = arrival - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        submit_fn(arrival, x, t0)
    return t0


def run_oneshot(stream, cfg) -> dict:
    eng = TuckerBatchEngine()
    lat = LatencyWindow()

    def submit(arrival, x, t0):
        eng.run([TuckerRequest(x=x, config=cfg)])
        lat.add(time.perf_counter() - t0 - arrival)

    t0 = _replay(stream, submit)
    total = time.perf_counter() - t0
    return {"bench": "serve_stream", "arm": "oneshot", "n": len(stream),
            "plans_built": eng.stats["plans_built"],
            "throughput_rps": len(stream) / total, **lat.snapshot_ms()}


def run_service(stream, cfg, *, arm: str = "service",
                submit_kw: dict | None = None) -> tuple[dict, list[dict]]:
    submit_kw = submit_kw or {}
    svc = TuckerService(
        policy=BucketPolicy(grid=8, max_pad_ratio=8.0, pad_mode="mask",
                            wave_slots=8),
        max_queue=4 * len(stream), backpressure="block",
        max_inflight_waves=3)
    svc.start()
    tickets = []

    def submit(arrival, x, t0):
        tickets.append(svc.submit(x, cfg, **submit_kw))

    t0 = _replay(stream, submit)
    for t in tickets:
        svc.wait(t, timeout=600)
    total = time.perf_counter() - t0
    stats = svc.stats()
    svc.stop()
    row = {"bench": "serve_stream", "arm": arm, "n": len(stream),
           "plans_built": stats["plans_built"],
           "throughput_rps": len(stream) / total,
           "pad_waste": stats["pad_waste"],
           "max_inflight_waves": stats["max_inflight_waves"],
           **stats["latency"]}
    bucket_rows = [
        {"bench": "bucket", "arm": arm, "bucket": label,
         "completed": b["completed"], "waves": b["waves"],
         "pad_waste": b["pad_waste"], "occupancy": b["occupancy"],
         "pipeline_occupancy": b["pipeline_occupancy"],
         "p95_ms": b["latency"]["p95_ms"]}
        for label, b in stats["buckets"].items()]
    return row, bucket_rows


def bench_serve(full: bool = False, seed: int = 0) -> list[dict]:
    stream, cfg, rate = make_stream(full, seed=seed)
    return bench_serve_stream(stream, cfg, rate)


def bench_serve_stream(stream, cfg, rate) -> list[dict]:
    one = run_oneshot(stream, cfg)
    # fresh arrival clock, same schedule/tensors, for the service arm
    srv, bucket_rows = run_service(stream, cfg)
    srv["win"] = srv["throughput_rps"] / one["throughput_rps"]
    for r in (one, srv):
        r["arrival_rps"] = rate
        emit(f"serve/{r['arm']}", 1.0 / r["throughput_rps"],
             f"p95_ms={r['p95_ms']:.1f}")
    for b in bucket_rows:
        emit(f"serve/bucket/{b['bucket']}", b["p95_ms"] / 1e3,
             f"pad_waste={b['pad_waste']:.3f}")
    print(f"# continuous batching throughput win: {srv['win']:.2f}x "
          f"({srv['throughput_rps']:.1f} vs {one['throughput_rps']:.1f} rps)")
    return [one, srv, *bucket_rows]


#: clean-path overhead budget for the resilience machinery (acceptance:
#: guarded throughput within 3% of bare on the same stream)
RESILIENCE_BUDGET = 0.03
#: per-request guards the "guarded" arm turns on — a generous deadline and
#: a retry budget cost bookkeeping only on the clean path; the admission
#: finite-check is the one real extra device op per request
GUARDED_SUBMIT = {"validate": "finite", "deadline_s": 600.0, "retries": 1}


def bench_resilience(full: bool = False, seed: int = 0) -> list[dict]:
    """Clean-path cost of the resilience machinery.

    The SAME arrival schedule runs through the service twice: ``bare``
    (``validate="none"``, no deadline, no retries — the machinery is
    compiled in but every guard is off) and ``guarded`` (admission
    finite-check, a deadline, a retry budget).  A guarded warmup pass runs
    first and is discarded, so neither measured arm pays first-touch
    planning or compiles.  The emitted row asserts the guarded arm keeps
    within ``RESILIENCE_BUDGET`` of bare throughput.
    """
    stream, cfg, rate = make_stream(full, seed=seed)
    run_service(stream, cfg, arm="warmup", submit_kw=GUARDED_SUBMIT)
    bare, _ = run_service(stream, cfg, arm="bare",
                          submit_kw={"validate": "none"})
    guarded, _ = run_service(stream, cfg, arm="guarded",
                             submit_kw=GUARDED_SUBMIT)
    regression = 1.0 - guarded["throughput_rps"] / bare["throughput_rps"]
    row = {"bench": "serve_resilience", "arm": "guarded_vs_bare",
           "n": len(stream), "arrival_rps": rate,
           "bare_rps": bare["throughput_rps"],
           "guarded_rps": guarded["throughput_rps"],
           "bare_p95_ms": bare["p95_ms"], "guarded_p95_ms": guarded["p95_ms"],
           "regression_pct": round(100.0 * regression, 3),
           "budget_pct": 100.0 * RESILIENCE_BUDGET,
           "pass": regression < RESILIENCE_BUDGET}
    for r in (bare, guarded):
        emit(f"serve/resilience/{r['arm']}", 1.0 / r["throughput_rps"],
             f"p95_ms={r['p95_ms']:.1f}")
    print(f"# resilience clean-path regression: {row['regression_pct']:.2f}% "
          f"(budget {row['budget_pct']:.0f}%) -> "
          f"{'PASS' if row['pass'] else 'FAIL'}")
    return [bare, guarded, row]


def export_perfetto(stream, cfg, path: str, n: int = 6) -> None:
    """Replay the first ``n`` stream tensors through fresh services with
    tracing on and write the capture as one Perfetto-loadable Chrome
    trace.  Two passes share the capture so the trace carries the full
    story: a fused pass against a cleared sweep cache (cache-miss +
    compile spans on the wave timeline) and a recorded pass (per-mode
    ``solve`` spans with solver/backend/rank attributes)."""
    from repro import obs
    from repro.core.api import _SWEEP_CACHE

    policy = BucketPolicy(grid=8, max_pad_ratio=8.0, pad_mode="mask",
                          wave_slots=4)
    with obs.capture() as buf:
        _SWEEP_CACHE.clear()   # cold start: the slice shows the real compile
        for record in (False, True):
            with TuckerService(policy=policy, record=record) as svc:
                for _, x in stream[:n]:
                    svc.submit(x, cfg)
                svc.drain()
    doc = obs.write_chrome(buf.events(), path)
    names = {e["name"].split(" ")[0] for e in doc["traceEvents"]}
    print(f"# perfetto trace: {len(doc['traceEvents'])} events "
          f"({', '.join(sorted(names))}) -> {path}")


def export_drift(path: str) -> None:
    """Dump the process drift monitor (fed by this run's traffic)."""
    from repro.obs.drift import MONITOR

    report = MONITOR.report()
    Path(path).write_text(json.dumps(report, indent=1, default=str))
    print(f"# drift report: {len(report['cells'])} cells, "
          f"{len(report['stale'])} stale -> {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized stream (the default size)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale stream (minutes on 1 CPU core)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="JSON row file path ('' to skip writing)")
    ap.add_argument("--resilience", action="store_true",
                    help="measure the clean-path cost of the resilience "
                         "machinery (guarded vs bare submissions) instead "
                         "of the oneshot-vs-service stream comparison; "
                         "exits nonzero if the regression budget is blown")
    ap.add_argument("--seed", type=int, default=0,
                    help="stream RNG seed (arrivals, shapes, tensor data) — "
                         "vary for run-to-run noise estimates")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="also export a traced replay slice as a Chrome "
                         "trace-event file (Perfetto-loadable)")
    ap.add_argument("--drift-report", default=None, metavar="PATH",
                    help="also dump the predicted-vs-actual drift report "
                         "as JSON")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    full = args.full and not args.smoke
    if args.resilience:
        rows = bench_resilience(full=full, seed=args.seed)
        stream = cfg = None
    else:
        stream, cfg, rate = make_stream(full=full, seed=args.seed)
        rows = bench_serve_stream(stream, cfg, rate)
    if args.out:
        doc = {"bench": "serve_resilience" if args.resilience else "serve",
               "jax_backend": jax.default_backend(),
               "host": _platform.machine(), "full": args.full, "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1))
        print(f"wrote {args.out} ({len(rows)} rows)")
    if args.perfetto and stream is not None:
        export_perfetto(stream, cfg, args.perfetto)
    if args.drift_report:
        export_drift(args.drift_report)
    if args.resilience and not all(r.get("pass", True) for r in rows):
        raise SystemExit("resilience clean-path regression budget blown "
                         "(see the serve_resilience row)")


if __name__ == "__main__":
    main()
