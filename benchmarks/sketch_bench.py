"""Randomized-sketch vs fixed-rank eig bench: the rank-adaptive payoff.

For each asymmetric case the bench plans an error-targeted job
(``TuckerConfig(error_target=ε, methods="rand")``), executes it — the
sketch pass resolves per-mode ranks AND produces the decomposition — and
then plans a fixed-rank EIG sweep at exactly the ranks the policy chose,
so both arms land at (essentially) the same achieved reconstruction error.
Timing the two at equal accuracy answers the acceptance question directly:
does the matricization-free sketch (linear in I_n) beat the eig sweep
(quadratic Gram in I_n) once the big-mode shapes arrive?  The adaptive arm
is timed END TO END — rank resolution included — while the eig arm gets
its best case, the cached compiled sweep.

A second row family checks the error contract: for a grid of targets ε the
achieved error and the certified bound (``SthosvdResult.error_bound``)
must both sit at or below ε.

Usage:  python -m benchmarks.sketch_bench [--full | --smoke]
                                          [--out BENCH_sketch.json]
"""

from __future__ import annotations

import argparse
import json
import platform as _platform
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import TuckerConfig, plan

from .common import emit, lowrank_tensor, time_call

# one huge mode + small true ranks: where the sketch's linear-in-I_n range
# finder dominates eig's I_n² Gram.  smoke = CI-sized, full = paper-adjacent
CASES = {
    "smoke": [((240, 48, 32), (10, 6, 5)),
              ((32, 200, 24), (5, 8, 4))],
    "default": [((600, 80, 40), (12, 8, 6)),
                ((96, 512, 48), (10, 14, 8)),
                ((720, 48, 64), (16, 6, 10))],
    "full": [((1200, 160, 80), (16, 12, 8)),
             ((160, 1024, 96), (12, 20, 10)),
             ((1536, 96, 128), (24, 8, 16))],
}

ERROR_GRID = (0.02, 0.05, 0.1)


def bench_sketch(tier: str = "default", reps: int = 3) -> list[dict]:
    rows: list[dict] = []
    for dims, true_ranks in CASES[tier]:
        tag = "x".join(map(str, dims))
        x = lowrank_tensor(dims, true_ranks, noise=0.01)
        eps = 0.05

        p_rand = plan(x.shape, x.dtype,
                      TuckerConfig(error_target=eps, methods="rand",
                                   mode_order="opt"))
        res = p_rand.execute(x)
        chosen = res.tucker.ranks
        rand_err = float(res.tucker.rel_error(x))
        t_rand = time_call(lambda: p_rand.execute(x).tucker.core, reps=reps)

        p_eig = plan(x.shape, x.dtype,
                     TuckerConfig(ranks=chosen, methods="eig",
                                  mode_order="opt", donate_input=False))
        eig_err = float(p_eig.execute(x).tucker.rel_error(x))
        t_eig = time_call(lambda: p_eig.execute(x).tucker.core, reps=reps)

        emit(f"sketch/adaptive/{tag}", t_rand,
             f"ranks={chosen} err={rand_err:.4f} bound={res.error_bound:.4f}")
        emit(f"sketch/eig_fixed/{tag}", t_eig, f"err={eig_err:.4f}")
        rows.append({
            "bench": "sketch_vs_eig", "shape": list(dims),
            "error_target": eps, "ranks": list(chosen),
            "us_per_call": t_rand * 1e6, "eig_us_per_call": t_eig * 1e6,
            "rel_err": rand_err, "eig_rel_err": eig_err,
            "error_bound": float(res.error_bound),
            "speedup_vs_eig": t_eig / t_rand,
            "rand_wins": t_rand < t_eig,
            "within_target": rand_err <= eps and res.error_bound <= eps,
        })

    # error contract: achieved error and certified bound ≤ ε across targets
    dims, true_ranks = CASES[tier][0]
    x = lowrank_tensor(dims, true_ranks, noise=0.005)
    for eps in ERROR_GRID:
        p = plan(x.shape, x.dtype, TuckerConfig(error_target=eps,
                                                methods="rand"))
        res = p.execute(x)
        err = float(res.tucker.rel_error(x))
        ok = err <= eps and res.error_bound <= eps
        emit(f"sketch/budget/{'x'.join(map(str, dims))}/eps={eps}", 0.0,
             f"ranks={res.tucker.ranks} err={err:.4f} "
             f"bound={res.error_bound:.4f} ok={ok}")
        rows.append({"bench": "sketch_budget", "shape": list(dims),
                     "error_target": eps,
                     "ranks": list(res.tucker.ranks), "rel_err": err,
                     "error_bound": float(res.error_bound),
                     "within_target": ok})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-adjacent dims (minutes on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized dims (seconds; used by the schedule-opt "
                    "CI tier)")
    ap.add_argument("--out", default="BENCH_sketch.json",
                    help="JSON row file path ('' to skip writing)")
    args = ap.parse_args()
    tier = "full" if args.full else "smoke" if args.smoke else "default"
    print("name,us_per_call,derived")
    rows = bench_sketch(tier=tier)
    bad = [r for r in rows if not r["within_target"]]
    if args.out:
        doc = {"bench": "sketch", "platform": jax.default_backend(),
               "host": _platform.node(), "tier": tier, "rows": rows}
        Path(args.out).write_text(json.dumps(doc, indent=1))
        print(f"wrote {args.out} ({len(rows)} rows)")
    if bad:
        raise SystemExit(f"error budget violated in {len(bad)} rows")


if __name__ == "__main__":
    main()
